"""L2 model composition + AOT lowering round-trip tests."""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from compile import model
from compile.aot import hlo_stats, lower_variant, to_hlo_text
from compile.kernels import ref
from compile.kernels.ref import K


def make_fit_predict_inputs(rng, t, c, s):
    n_obs = rng.integers(1, 17, size=(t, s)).astype(np.float32)
    x = np.zeros((t, s, K), np.float32)
    for i in range(t):
        x[i] = np.asarray(ref.ernest_basis(n_obs[i], 1.0, 1.0))
    true_theta = rng.uniform(0.0, 10.0, size=(t, K)).astype(np.float32)
    y = np.einsum("tsk,tk->ts", x, true_theta)
    phi = rng.uniform(0.0, 4.0, size=(c, K)).astype(np.float32)
    usl = np.stack(
        [
            rng.uniform(1.0, 100.0, size=t),
            rng.uniform(0.0, 1.0, size=t),
            rng.uniform(0.0, 0.3, size=t),
            rng.uniform(0.0, 1.0, size=t),
        ],
        axis=1,
    ).astype(np.float32)
    n = rng.integers(1, 33, size=c).astype(np.float32)
    return x, y, phi, usl, n


def test_fit_predict_matches_ref():
    rng = np.random.default_rng(0)
    x, y, phi, usl, n = make_fit_predict_inputs(rng, 8, 16, 8)
    grid, theta = model.fit_predict(x, y, phi, usl, n)
    grid_r, theta_r = model.fit_predict_ref(x, y, phi, usl, n)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(theta_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(grid), np.asarray(grid_r), rtol=1e-4, atol=1e-4)


def test_predict_entry_is_tuple():
    rng = np.random.default_rng(1)
    _, _, phi, usl, n = make_fit_predict_inputs(rng, 4, 8, 4)
    theta = rng.uniform(0, 5, size=(4, K)).astype(np.float32)
    out = model.predict(theta, phi, usl, n)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (4, 8)


def test_variants_table():
    for name, (t, c, s) in model.VARIANTS.items():
        assert t > 0 and c > 0 and s > 0, name
    assert "small" in model.VARIANTS and "large" in model.VARIANTS


def test_lower_variant_small_produces_hlo_text():
    arts = lower_variant("small")
    assert set(arts) == {"predict_small", "fit_predict_small"}
    for name, (text, entry) in arts.items():
        assert text.startswith("HloModule"), f"{name} not HLO text"
        assert "ENTRY" in text
        # shape sanity: the output grid [T, C] appears in the module
        t, c = entry["tasks"], entry["configs"]
        assert f"f32[{t},{c}]" in text
        ops = hlo_stats(text)
        assert sum(ops.values()) > 0


def test_fit_predict_hlo_contains_rolled_loop():
    """lax.scan must lower to a while loop, not 300 unrolled iterations —
    keeps the artifact compact (EXPERIMENTS.md §Perf L2)."""
    arts = lower_variant("small")
    text = arts["fit_predict_small"][0]
    assert "while(" in text or "while (" in text.replace("  ", " ")
    assert len(text) < 4_000_000


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--variants",
            "small",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["k"] == K
    assert "predict_small" in manifest["artifacts"]
    for name, entry in manifest["artifacts"].items():
        assert (out / f"{name}.hlo.txt").exists()
