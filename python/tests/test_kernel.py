"""L1 Pallas kernel vs pure-jnp oracle — the core correctness signal.

hypothesis sweeps shapes, tile sizes, dtypes and parameter ranges; every
case asserts allclose between ``predict_grid`` (Pallas, interpret=True) and
``predict_grid_ref`` (straight jnp).
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.predict_grid import predict_grid, vmem_bytes, mxu_flops
from compile.kernels.ref import K

hypothesis.settings.register_profile(
    "agora", settings(max_examples=25, deadline=None, derandomize=True)
)
hypothesis.settings.load_profile("agora")


def make_inputs(rng, t, c):
    theta = rng.uniform(0.0, 50.0, size=(t, K)).astype(np.float32)
    phi = rng.uniform(0.0, 4.0, size=(c, K)).astype(np.float32)
    usl = np.stack(
        [
            rng.uniform(1.0, 500.0, size=t),  # gamma: single-node runtime
            rng.uniform(0.0, 1.0, size=t),  # alpha: contention
            rng.uniform(0.0, 1.0, size=t),  # beta: coherency
            rng.uniform(0.0, 1.0, size=t),  # mix
        ],
        axis=1,
    ).astype(np.float32)
    n = rng.integers(1, 65, size=c).astype(np.float32)
    return theta, phi, usl, n


@given(
    t=st.sampled_from([1, 2, 3, 8, 16, 32, 64]),
    c=st.sampled_from([1, 2, 5, 16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref(t, c, seed):
    rng = np.random.default_rng(seed)
    theta, phi, usl, n = make_inputs(rng, t, c)
    got = np.asarray(predict_grid(theta, phi, usl, n))
    want = np.asarray(ref.predict_grid_ref(theta, phi, usl, n))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    bt=st.sampled_from([1, 2, 7, 16, 32, 128]),
    bc=st.sampled_from([1, 3, 8, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tile_sizes_do_not_change_result(bt, bc, seed):
    """Tiling is an implementation detail: every tile shape agrees."""
    rng = np.random.default_rng(seed)
    theta, phi, usl, n = make_inputs(rng, 32, 64)
    base = np.asarray(predict_grid(theta, phi, usl, n))
    tiled = np.asarray(predict_grid(theta, phi, usl, n, bt=bt, bc=bc))
    np.testing.assert_allclose(tiled, base, rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_accepts_f64_inputs(seed):
    """Inputs of wider dtype are downcast, not rejected."""
    rng = np.random.default_rng(seed)
    theta, phi, usl, n = make_inputs(rng, 8, 16)
    got = np.asarray(
        predict_grid(
            theta.astype(np.float64),
            phi.astype(np.float64),
            usl.astype(np.float64),
            n.astype(np.float64),
        )
    )
    want = np.asarray(ref.predict_grid_ref(theta, phi, usl, n))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert got.dtype == np.float32


def test_output_floor():
    """Zero models still predict EPS, never 0/negative/NaN."""
    theta = np.zeros((4, K), np.float32)
    phi = np.zeros((8, K), np.float32)
    usl = np.zeros((4, 4), np.float32)
    usl[:, 3] = 1.0  # mix=1: pure (zero) Ernest model
    n = np.ones(8, np.float32)
    out = np.asarray(predict_grid(theta, phi, usl, n))
    assert np.all(out == ref.EPS)


def test_usl_negative_scaling_shape():
    """beta > 0 reproduces the paper's Fig. 2 negative-scaling curve:
    runtime decreases then increases with n."""
    t = 1
    usl = np.array([[100.0, 0.05, 0.02, 0.0]], np.float32)  # pure USL
    theta = np.zeros((t, K), np.float32)
    ns = np.array([1, 2, 4, 8, 16, 32, 64], np.float32)
    phi = np.zeros((len(ns), K), np.float32)
    out = np.asarray(predict_grid(theta, phi, usl, ns))[0]
    assert out[1] < out[0]  # initial speedup
    assert out[-1] > out.min()  # eventual slowdown


def test_mix_blends_models():
    rng = np.random.default_rng(0)
    theta, phi, usl, n = make_inputs(rng, 8, 16)
    usl_e = usl.copy()
    usl_e[:, 3] = 1.0
    usl_u = usl.copy()
    usl_u[:, 3] = 0.0
    usl_h = usl.copy()
    usl_h[:, 3] = 0.5
    e = np.asarray(predict_grid(theta, phi, usl_e, n))
    u = np.asarray(predict_grid(theta, phi, usl_u, n))
    h = np.asarray(predict_grid(theta, phi, usl_h, n))
    np.testing.assert_allclose(h, np.maximum(0.5 * e + 0.5 * u, ref.EPS), rtol=1e-4, atol=1e-4)


def test_rejects_bad_basis_dim():
    with pytest.raises(ValueError):
        predict_grid(
            np.zeros((4, K + 1), np.float32),
            np.zeros((8, K + 1), np.float32),
            np.zeros((4, 4), np.float32),
            np.ones(8, np.float32),
        )


def test_rejects_mismatched_usl():
    with pytest.raises(ValueError):
        predict_grid(
            np.zeros((4, K), np.float32),
            np.zeros((8, K), np.float32),
            np.zeros((5, 4), np.float32),
            np.ones(8, np.float32),
        )


def test_vmem_estimate_within_budget():
    """Default tiles must fit VMEM with double-buffering headroom."""
    assert vmem_bytes(128, 128) < 2 * 1024 * 1024


def test_mxu_flops_positive():
    assert mxu_flops(128, 512) == 2 * 128 * 512 * K


def test_ernest_basis_matches_rust_convention():
    """Pin the basis layout — rust/src/predictor/ernest.rs mirrors this."""
    b = np.asarray(ref.ernest_basis(np.array([4.0]), 1.5, 2.0))[0]
    np.testing.assert_allclose(
        b,
        [1.0, 0.25, np.log2(5.0), 4.0 / 64.0, 1.5, 2.0, 0.0, 0.0],
        rtol=1e-6,
    )


def test_usl_penalty_is_one_at_n1():
    p = np.asarray(ref.usl_penalty(jnp.array([1.0]), 0.3, 0.2))
    np.testing.assert_allclose(p, [1.0], rtol=1e-6)
