"""L2 fit (projected-gradient NNLS) vs oracle + recovery properties."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.fit import batched_grad, batched_loss, fit_theta
from compile.kernels.ref import K


def make_problem(rng, t, s, noise=0.0):
    """Samples drawn from a ground-truth non-negative theta."""
    n = rng.integers(1, 33, size=(t, s)).astype(np.float32)
    x = np.zeros((t, s, K), np.float32)
    for i in range(t):
        x[i] = np.asarray(ref.ernest_basis(n[i], 1.0, 1.0))
    true_theta = rng.uniform(0.0, 20.0, size=(t, K)).astype(np.float32)
    true_theta[:, 6:] = 0.0  # padding features carry no signal
    y = np.einsum("tsk,tk->ts", x, true_theta)
    y += noise * rng.standard_normal(y.shape).astype(np.float32)
    return x, y, true_theta


@settings(max_examples=15, deadline=None, derandomize=True)
@given(
    t=st.sampled_from([1, 2, 8, 32]),
    s=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fit_matches_ref(t, s, seed):
    rng = np.random.default_rng(seed)
    x, y, _ = make_problem(rng, t, s)
    got = np.asarray(fit_theta(x, y))
    want = np.asarray(ref.fit_theta_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2**31 - 1))
def test_fit_is_nonnegative(seed):
    rng = np.random.default_rng(seed)
    x, y, _ = make_problem(rng, 8, 8, noise=5.0)
    theta = np.asarray(fit_theta(x, y))
    assert np.all(theta >= 0.0)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2**31 - 1))
def test_fit_reduces_loss(seed):
    """Fitted theta must beat the zero initializer on the training loss."""
    rng = np.random.default_rng(seed)
    x, y, _ = make_problem(rng, 4, 12, noise=1.0)
    theta = fit_theta(x, y)
    l_fit = float(batched_loss(theta, jnp.asarray(x), jnp.asarray(y)))
    l_zero = float(batched_loss(jnp.zeros_like(theta), jnp.asarray(x), jnp.asarray(y)))
    assert l_fit < l_zero


def test_fit_predictions_recover_noiseless_targets():
    """On clean data the fitted model reproduces observed runtimes well."""
    rng = np.random.default_rng(7)
    x, y, _ = make_problem(rng, 8, 16)
    theta = np.asarray(fit_theta(x, y, iters=2000))
    pred = np.einsum("tsk,tk->ts", x, theta)
    # relative error on the predictions (not the coefficients: the basis is
    # collinear, so theta itself is not identifiable — predictions are).
    rel = np.abs(pred - y) / np.maximum(np.abs(y), 1e-3)
    assert np.median(rel) < 0.05


def test_grad_matches_manual():
    rng = np.random.default_rng(3)
    x, y, _ = make_problem(rng, 3, 5)
    theta = jnp.asarray(rng.uniform(0, 5, size=(3, K)).astype(np.float32))
    g = np.asarray(batched_grad(theta, jnp.asarray(x), jnp.asarray(y)))
    gram = np.einsum("tsk,tsl->tkl", x, x)
    xty = np.einsum("tsk,ts->tk", x, y)
    manual = np.einsum("tkl,tl->tk", gram, np.asarray(theta)) - xty
    np.testing.assert_allclose(g, manual, rtol=1e-4, atol=1e-3)


def test_zero_padded_samples_are_inert():
    """Padding rows with zeros must not change the fit (rust relies on it)."""
    rng = np.random.default_rng(11)
    x, y, _ = make_problem(rng, 4, 8)
    xp = np.concatenate([x, np.zeros((4, 8, K), np.float32)], axis=1)
    yp = np.concatenate([y, np.zeros((4, 8), np.float32)], axis=1)
    a = np.asarray(fit_theta(x, y))
    b = np.asarray(fit_theta(xp, yp))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
