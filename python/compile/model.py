"""L2: the AGORA Predictor compute graph.

Composes the L1 kernels into the two entry points the Rust coordinator
calls through PJRT:

  * ``predict``      — theta/usl already known, produce the [T, C] grid.
  * ``fit_predict``  — ingest raw event-log samples, fit Ernest
                       coefficients (projected-gradient NNLS), then produce
                       the grid. One fused module: XLA keeps the fitted
                       theta on-device between the two phases, so there is
                       no fit->host->predict round trip.

Shapes are static per artifact variant (PJRT AOT requirement); the Rust
side zero-pads tasks/configs/samples up to the variant size and slices the
result. Padding is semantically inert by construction:
  - a zero theta row + mix=1 predicts EPS everywhere,
  - zero sample rows contribute nothing to the NNLS fit.
"""

from __future__ import annotations

from .kernels.fit import fit_theta
from .kernels.predict_grid import predict_grid
from .kernels import ref

# Artifact variants: name -> (T, C, S). Chosen so the small variant covers
# the paper's micro-benchmarks (DAG1/DAG2: <= 16 tasks, 32 configs) and the
# large variant covers a macro scheduling round (Fig. 10/11 scale).
VARIANTS = {
    "small": (32, 64, 16),
    "large": (128, 512, 16),
}


def predict(theta, phi, usl, n):
    """[T, K], [C, K], [T, 4], [C] -> [T, C] runtime grid (L1 kernel)."""
    return (predict_grid(theta, phi, usl, n),)


def fit_predict(x, y, phi, usl, n):
    """Event-log samples -> fitted theta -> runtime grid, fused.

    Args:
      x:   [T, S, K] sample basis features from prior runs.
      y:   [T, S]    observed runtimes.
      phi: [C, K]    candidate-config basis features.
      usl: [T, 4]    (gamma, alpha, beta, mix) per task.
      n:   [C]       effective parallelism per config.

    Returns (grid [T, C], theta [T, K]).
    """
    theta = fit_theta(x, y)
    grid = predict_grid(theta, phi, usl, n)
    return grid, theta


def fit_predict_ref(x, y, phi, usl, n):
    """Pure-jnp oracle for ``fit_predict`` (pytest cross-check)."""
    theta = ref.fit_theta_ref(x, y, iters=300)
    grid = ref.predict_grid_ref(theta, phi, usl, n)
    return grid, theta
