"""L1/L2 kernel: batched non-negative least-squares fit of Ernest models.

The AGORA Predictor refits per-task Ernest coefficients every time a new
event log arrives (the §4.1 adaptive feedback loop). Fitting is a batched
NNLS solved by projected gradient descent:

    theta <- max(0, theta - eta * (X^T X theta - X^T y))

The Gram matrices are tiny ([K, K] with K = 8) so the interesting structure
is the batch dimension: one fused computation fits every task at once.

The gradient is produced by ``jax.grad`` of the batched loss — this is the
L2 "fwd/bwd" pair — and the iteration loop is a ``lax.scan`` so the lowered
HLO contains a single rolled loop instead of 300 unrolled copies (keeps the
artifact small and the XLA compile fast; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import K

DEFAULT_ITERS = 300


def batched_loss(theta, x, y):
    """0.5*||X theta - y||^2 summed over tasks. fwd half of the fit."""
    resid = jnp.einsum("tsk,tk->ts", x, theta) - y
    return 0.5 * jnp.sum(resid * resid)


# bwd half: d(loss)/d(theta), batched. Precomputing grad once and closing
# over (gram, xty) inside the scan would be equivalent; jax.grad keeps the
# code shape honest to "fwd/bwd".
batched_grad = jax.grad(batched_loss, argnums=0)


@functools.partial(jax.jit, static_argnames=("iters",))
def fit_theta(x, y, *, iters: int = DEFAULT_ITERS):
    """Fit non-negative Ernest coefficients for a batch of tasks.

    Args:
      x: [T, S, K] f32 — basis features of the S observed samples per task.
      y: [T, S]    f32 — observed runtimes.
      iters: projected-gradient iterations (static).

    Returns theta [T, K] f32, elementwise >= 0.

    Step size is 1/trace(X^T X) per task — an upper bound on the Lipschitz
    constant of the gradient, so the iteration never diverges; zero-padded
    sample rows contribute nothing to either the Gram matrix or X^T y, so
    callers may pad S freely.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if x.shape[-1] != K:
        raise ValueError(f"basis dim must be {K}, got {x.shape[-1]}")

    gram = jnp.einsum("tsk,tsl->tkl", x, x)
    trace = jnp.trace(gram, axis1=-2, axis2=-1)
    step = (1.0 / jnp.maximum(trace, 1e-6))[:, None]

    theta0 = jnp.zeros((x.shape[0], K), dtype=jnp.float32)

    def body(theta, _):
        g = batched_grad(theta, x, y)
        theta = jnp.maximum(theta - step * g, 0.0)
        return theta, ()

    theta, _ = jax.lax.scan(body, theta0, xs=None, length=iters)
    return theta
