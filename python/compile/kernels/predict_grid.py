"""L1 Pallas kernel: batched runtime-grid prediction for the AGORA solver.

This is the numeric hot spot of the AGORA Predictor: for every task t and
every candidate resource configuration c, evaluate

    d[t, c] = mix_t * (theta_t . phi_c)
            + (1 - mix_t) * gamma_t * (1 + a_t*(n_c-1) + b_t*n_c*(n_c-1)) / n_c

i.e. an Ernest basis matmul fused with a USL (Eq. 9) rational epilogue.
The simulated-annealing outer loop consumes this grid on every proposal, so
the whole [T, C] surface is produced by a single kernel launch.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * The Ernest term is a [T, K] x [K, C] matmul with K = 8 — the MXU does
    the contraction while the USL epilogue runs on the VPU in the same
    kernel, so the grid never round-trips HBM between the two terms.
  * BlockSpec tiles: one (BT, K) theta tile and its (BT, 4) USL row stay
    resident in VMEM while (BC, K) phi tiles stream; the output tile is
    (BT, BC).
  * interpret=True everywhere in this repo: the CPU PJRT plugin cannot run
    Mosaic custom-calls; the lowered HLO is plain ops and runs anywhere.

VMEM footprint per program instance (f32):
    theta  BT*K*4   + usl BT*4*4 + phi BC*K*4 + n BC*4 + out BT*BC*4
With BT = BC = 128, K = 8: 4 KiB + 2 KiB + 4 KiB + 0.5 KiB + 64 KiB
≈ 75 KiB — comfortably inside a 16 MiB VMEM budget, leaving room for
double-buffering the streamed phi/out tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS, K

# Default tile sizes. BT rows of the grid are produced per program instance;
# BC columns. Both multiples of the 8x128 VPU lanes when the problem is
# large enough; clamped for small problem variants.
DEFAULT_BT = 128
DEFAULT_BC = 128


def _predict_kernel(theta_ref, usl_ref, phi_ref, n_ref, out_ref):
    """Pallas kernel body: one (BT, BC) output tile.

    theta_ref: [BT, K]  usl_ref: [BT, 4]  phi_ref: [BC, K]  n_ref: [1, BC]
    out_ref:   [BT, BC]
    """
    theta = theta_ref[...]
    phi = phi_ref[...]
    usl = usl_ref[...]
    n = jnp.maximum(n_ref[...], 1.0)  # [1, BC]

    # MXU part: Ernest basis contraction. Accumulate in f32 regardless of
    # the input dtype (bf16-ready on real hardware).
    ernest = jax.lax.dot_general(
        theta,
        phi,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [BT, BC]

    # VPU epilogue: USL rational penalty, fused in the same tile.
    gamma = usl[:, 0:1]
    alpha = usl[:, 1:2]
    beta = usl[:, 2:3]
    mix = usl[:, 3:4]
    denom = 1.0 + alpha * (n - 1.0) + beta * n * (n - 1.0)  # [BT, BC]
    usl_rt = gamma * denom / n

    out = mix * ernest + (1.0 - mix) * usl_rt
    out_ref[...] = jnp.maximum(out, EPS)


def _tile(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (tile size picker)."""
    t = min(dim, preferred)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bt", "bc"))
def predict_grid(theta, phi, usl, n, *, bt: int = DEFAULT_BT, bc: int = DEFAULT_BC):
    """Predict the [T, C] runtime grid with the Pallas kernel.

    Args:
      theta: [T, K] f32 Ernest coefficients.
      phi:   [C, K] f32 config basis features.
      usl:   [T, 4] f32 (gamma, alpha, beta, mix) per task.
      n:     [C]    f32 effective parallelism per config.
      bt/bc: requested tile sizes (clamped to divisors of T / C).

    Returns [T, C] f32 predicted runtimes, >= EPS.
    """
    theta = theta.astype(jnp.float32)
    phi = phi.astype(jnp.float32)
    usl = usl.astype(jnp.float32)
    n2 = n.astype(jnp.float32).reshape(1, -1)

    t, k = theta.shape
    c, k2 = phi.shape
    if k != K or k2 != K:
        raise ValueError(f"basis dim must be {K}, got theta K={k} phi K={k2}")
    if usl.shape != (t, 4):
        raise ValueError(f"usl must be [{t}, 4], got {usl.shape}")
    if n2.shape[1] != c:
        raise ValueError(f"n must have {c} entries, got {n2.shape[1]}")

    bt = _tile(t, bt)
    bc = _tile(c, bc)
    grid = (t // bt, c // bc)

    return pl.pallas_call(
        _predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, K), lambda i, j: (i, 0)),  # theta: row tile
            pl.BlockSpec((bt, 4), lambda i, j: (i, 0)),  # usl:   row tile
            pl.BlockSpec((bc, K), lambda i, j: (j, 0)),  # phi:   col tile
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),  # n:     col tile
        ],
        out_specs=pl.BlockSpec((bt, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, c), jnp.float32),
        interpret=True,
    )(theta, usl, phi, n2)


def vmem_bytes(bt: int = DEFAULT_BT, bc: int = DEFAULT_BC, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one program instance."""
    theta = bt * K * dtype_bytes
    usl = bt * 4 * dtype_bytes
    phi = bc * K * dtype_bytes
    n = bc * dtype_bytes
    out = bt * bc * dtype_bytes
    return theta + usl + phi + n + out


def mxu_flops(t: int, c: int) -> int:
    """MXU FLOPs of the Ernest contraction for a [T, C] grid."""
    return 2 * t * c * K
