"""Pure-jnp oracle for the AGORA Predictor kernels.

This module is the CORE correctness signal for the L1 Pallas kernel and the
L2 fit model: everything here is straight-line jax.numpy with no Pallas, no
tiling, no tricks. pytest (``python/tests/``) asserts allclose between these
references and the optimized implementations across a hypothesis sweep of
shapes / dtypes / parameter ranges.

The canonical AGORA predictor model (mirrored in ``rust/src/predictor/``):

    d[t, c] = mix_t * (theta_t . phi_c)                        # Ernest part
            + (1 - mix_t) * gamma_t * penalty(n_c; alpha_t, beta_t)

    penalty(n; a, b) = (1 + a*(n - 1) + b*n*(n - 1)) / n       # USL, Eq. 9

- ``theta``  [T, K]  non-negative Ernest basis coefficients per task
- ``phi``    [C, K]  basis features per candidate configuration
- ``usl``    [T, 4]  columns = (gamma, alpha, beta, mix)
- ``n``      [C]     effective parallelism of each configuration
- result     [T, C]  predicted runtime (seconds), clamped to >= EPS
"""

from __future__ import annotations

import jax.numpy as jnp

# Floor for predicted runtimes: a prediction of zero/negative seconds is
# always a model artifact, never a real task.
EPS = 1e-3

# Number of Ernest basis features. The basis is (1, 1/n, log2(n+1), n/64)
# padded with zeros to K=8 so the matmul contraction dim is MXU-aligned.
K = 8


def ernest_basis(n, cpu_factor, mem_factor):
    """Ernest feature vector for effective parallelism ``n`` (vectorized).

    Mirrors ``rust/src/predictor/ernest.rs::basis``. Features 0..3 are the
    classic Ernest terms (serial, communication, aggregation, per-node
    overhead); 4..5 carry the instance-type speed factors; 6..7 are zero
    padding up to K=8.
    """
    n = jnp.asarray(n, dtype=jnp.float32)
    one = jnp.ones_like(n)
    feats = [
        one,
        1.0 / jnp.maximum(n, 1.0),
        jnp.log2(n + 1.0),
        n / 64.0,
        jnp.asarray(cpu_factor, dtype=jnp.float32) * one,
        jnp.asarray(mem_factor, dtype=jnp.float32) * one,
        jnp.zeros_like(n),
        jnp.zeros_like(n),
    ]
    return jnp.stack(feats, axis=-1)


def usl_penalty(n, alpha, beta):
    """Relative USL runtime penalty at parallelism ``n`` (Eq. 9 inverted).

    X(N) = N / (1 + alpha*(N-1) + beta*N*(N-1)); penalty = 1 / X. Penalty is
    1.0 at N=1 and grows again for large N when beta > 0 (negative scaling —
    the Sentiment Analysis curve in the paper's Fig. 2).
    """
    n = jnp.maximum(jnp.asarray(n, dtype=jnp.float32), 1.0)
    denom = 1.0 + alpha * (n - 1.0) + beta * n * (n - 1.0)
    return denom / n


def predict_grid_ref(theta, phi, usl, n):
    """Reference [T, C] runtime-grid prediction. See module docstring."""
    theta = jnp.asarray(theta, dtype=jnp.float32)
    phi = jnp.asarray(phi, dtype=jnp.float32)
    usl = jnp.asarray(usl, dtype=jnp.float32)
    n = jnp.asarray(n, dtype=jnp.float32)

    gamma = usl[:, 0:1]  # [T, 1]
    alpha = usl[:, 1:2]
    beta = usl[:, 2:3]
    mix = usl[:, 3:4]

    ernest = theta @ phi.T  # [T, C]
    pen = usl_penalty(n[None, :], alpha, beta)  # [T, C]
    out = mix * ernest + (1.0 - mix) * gamma * pen
    return jnp.maximum(out, EPS)


def fit_theta_ref(x, y, iters=300):
    """Reference batched NNLS fit of Ernest coefficients.

    Projected-gradient descent on 0.5*||X theta - y||^2 with theta >= 0,
    batched over tasks. ``x`` is [T, S, K] sample bases, ``y`` is [T, S]
    observed runtimes. Step size is 1/L per task with L = trace(X^T X)
    (a cheap upper bound on the spectral norm, so the iteration is stable
    for every well-formed input).

    Returns theta [T, K] >= 0.
    """
    x = jnp.asarray(x, dtype=jnp.float32)
    y = jnp.asarray(y, dtype=jnp.float32)
    gram = jnp.einsum("tsk,tsl->tkl", x, x)  # [T, K, K]
    xty = jnp.einsum("tsk,ts->tk", x, y)  # [T, K]
    trace = jnp.trace(gram, axis1=-2, axis2=-1)  # [T]
    step = (1.0 / jnp.maximum(trace, 1e-6))[:, None]  # [T, 1]

    theta = jnp.zeros(x.shape[0:1] + x.shape[2:3], dtype=jnp.float32)
    for _ in range(iters):
        grad = jnp.einsum("tkl,tl->tk", gram, theta) - xty
        theta = jnp.maximum(theta - step * grad, 0.0)
    return theta


def fit_loss_ref(theta, x, y):
    """0.5 * ||X theta - y||^2 summed over the batch (for grad checks)."""
    resid = jnp.einsum("tsk,tk->ts", x, theta) - y
    return 0.5 * jnp.sum(resid * resid)
