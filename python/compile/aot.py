"""AOT compile path: lower the L2 Predictor graphs to HLO text artifacts.

Run once at build time (``make artifacts``); Python never runs on the
request path. The Rust runtime (``rust/src/runtime/``) loads the emitted
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and executes
them on the PJRT CPU client.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. Lowered with ``return_tuple=True``; the Rust side
unwraps with ``to_tuple1`` / element extraction.

Artifacts (per variant v in {small, large}; shapes in manifest.json):
  predict_<v>.hlo.txt      (theta[T,K], phi[C,K], usl[T,4], n[C]) -> (grid[T,C],)
  fit_predict_<v>.hlo.txt  (x[T,S,K], y[T,S], phi[C,K], usl[T,4], n[C])
                           -> (grid[T,C], theta[T,K])

``--report`` additionally prints the L1 VMEM/MXU estimates and HLO op
statistics used by EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import predict_grid as pg
from .kernels.ref import K


def to_hlo_text(lowered) -> str:
    """jax lowering -> XLA HLO text (see module docstring for why text)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_variant(name: str):
    """Lower both entry points for one shape variant.

    Returns {artifact_name: (hlo_text, manifest_entry)}.
    """
    t, c, s = model.VARIANTS[name]
    out = {}

    lowered = jax.jit(model.predict).lower(
        spec(t, K), spec(c, K), spec(t, 4), spec(c)
    )
    out[f"predict_{name}"] = (
        to_hlo_text(lowered),
        {
            "entry": "predict",
            "variant": name,
            "tasks": t,
            "configs": c,
            "samples": 0,
            "k": K,
            "inputs": [[t, K], [c, K], [t, 4], [c]],
            "outputs": [[t, c]],
        },
    )

    lowered = jax.jit(model.fit_predict).lower(
        spec(t, s, K), spec(t, s), spec(c, K), spec(t, 4), spec(c)
    )
    out[f"fit_predict_{name}"] = (
        to_hlo_text(lowered),
        {
            "entry": "fit_predict",
            "variant": name,
            "tasks": t,
            "configs": c,
            "samples": s,
            "k": K,
            "inputs": [[t, s, K], [t, s], [c, K], [t, 4], [c]],
            "outputs": [[t, c], [t, K]],
        },
    )
    return out


def hlo_stats(text: str) -> dict:
    """Cheap HLO op census for the perf report."""
    ops = {}
    for line in text.splitlines():
        line = line.strip()
        if line.startswith("ROOT "):
            line = line[5:]
        # instruction lines: "name = TYPE[shape]{layout} op(args), ..."
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1].strip()
        parts = rhs.split(" ", 1)
        if len(parts) < 2 or "[" not in parts[0]:
            continue
        op = parts[1].split("(", 1)[0].strip()
        if op and op.replace("-", "").isalnum():
            ops[op] = ops.get(op, 0) + 1
    return ops


def report(manifest: dict, texts: dict) -> None:
    print("== L1 kernel static profile (predict_grid) ==")
    for bt, bc in [(32, 64), (128, 128), (128, 512)]:
        vmem = pg.vmem_bytes(bt, bc)
        print(f"  tile ({bt:>3} x {bc:>3}): VMEM/instance = {vmem/1024:8.1f} KiB")
    for name, (t, c, s) in model.VARIANTS.items():
        flops = pg.mxu_flops(t, c)
        bytes_moved = 4 * (t * K + c * K + t * 4 + c + t * c)
        print(
            f"  variant {name:<6} grid [{t:>3} x {c:>3}]: "
            f"MXU FLOPs = {flops:>9,}  HBM bytes = {bytes_moved:>9,}  "
            f"arith intensity = {flops/bytes_moved:5.2f} flop/B (memory-bound epilogue fusion)"
        )
    print("== HLO op census ==")
    for name, text in texts.items():
        ops = hlo_stats(text)
        top = sorted(ops.items(), key=lambda kv: -kv[1])[:8]
        total = sum(ops.values())
        print(f"  {name}: {total} ops; top: " + ", ".join(f"{k}={v}" for k, v in top))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--report", action="store_true", help="print perf estimates")
    ap.add_argument(
        "--variants", default="small,large", help="comma-separated variant names"
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"k": K, "artifacts": {}}
    texts = {}
    for v in args.variants.split(","):
        if v not in model.VARIANTS:
            sys.exit(f"unknown variant {v!r}; have {sorted(model.VARIANTS)}")
        for name, (text, entry) in lower_variant(v).items():
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"][name] = entry
            texts[name] = text
            print(f"wrote {path} ({len(text):,} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")

    if args.report:
        report(manifest, texts)


if __name__ == "__main__":
    main()
