//! END-TO-END VALIDATION DRIVER (see EXPERIMENTS.md §E2E).
//!
//! Run: `cargo run --release --example alibaba_replay [-- jobs=N seed=S]`
//!
//! Replays an Alibaba-like production trace (the §5.5 macro-benchmark)
//! through the full system — trace generation, the trigger-driven
//! multi-tenant coordinator, the Predictor with its adaptive event-log
//! feedback, Algorithm 1 co-optimization per round, and simulated
//! execution — for both default Airflow and AGORA, and reports the
//! paper's headline metric: total cost and total DAG completion time
//! reduction, plus the per-DAG improvement CDF (Fig. 11).

use agora::cluster::ConfigSpace;
use agora::coordinator::{improvement_cdf, BatchRunner, MacroSummary, Strategy};
use agora::solver::Goal;
use agora::trace::{generate, TraceParams};
use agora::util::{fmt_cost, fmt_duration, Rng};

fn arg(name: &str, default: u64) -> u64 {
    std::env::args()
        .find_map(|a| a.strip_prefix(&format!("{name}=")).map(String::from))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let jobs_n = arg("jobs", 60) as usize;
    let seed = arg("seed", 2022);

    // Contended batch slice (see rust/benches/fig11_alibaba.rs): the
    // macro gains are queueing-dominated, like the production trace.
    let params = TraceParams {
        jobs: jobs_n,
        window: 4.0 * 3600.0,
        machines: 12,
        ..TraceParams::default()
    };
    let mut rng = Rng::new(seed);
    let jobs = generate(&params, &mut rng);
    let tasks: usize = jobs.iter().map(|j| j.dag.len()).sum();
    println!(
        "trace: {} DAGs / {} tasks over {}; batch capacity {:.0} cores, {:.0} GiB",
        jobs.len(),
        tasks,
        fmt_duration(params.window),
        params.batch_capacity().vcpus,
        params.batch_capacity().memory_gb,
    );
    println!("triggers: every 15 min or queue demand > 3x cluster cores\n");

    let space = ConfigSpace::standard();
    let t0 = std::time::Instant::now();
    let mut base_runner = BatchRunner::new(
        params.batch_capacity(),
        space.clone(),
        Strategy::Airflow,
        seed,
    );
    let base = base_runner.run(&jobs)?;
    println!(
        "airflow : {} rounds, cost {}, total completion {} ({:?})",
        base.rounds,
        fmt_cost(base.total_cost),
        fmt_duration(base.total_completion),
        t0.elapsed()
    );

    let t1 = std::time::Instant::now();
    let mut agora_runner = BatchRunner::new(
        params.batch_capacity(),
        space,
        Strategy::Agora(Goal::Balanced),
        seed,
    );
    let run = agora_runner.run(&jobs)?;
    println!(
        "agora   : {} rounds, cost {}, total completion {} ({:?}, optimizer {:?})",
        run.rounds,
        fmt_cost(run.total_cost),
        fmt_duration(run.total_completion),
        t1.elapsed(),
        run.optimizer_overhead
    );

    let s = MacroSummary::against(&base, &run);
    println!("\n== Fig. 11 headline (paper: cost -65%, completion -57%) ==");
    println!(
        "cost reduction       : {:.0}%  (normalized cost {:.2})",
        (1.0 - s.normalized_cost) * 100.0,
        s.normalized_cost
    );
    println!(
        "completion reduction : {:.0}%  (normalized completion {:.2})",
        (1.0 - s.normalized_completion) * 100.0,
        s.normalized_completion
    );
    println!(
        "DAGs improved        : {:.0}%  (paper: 87%)",
        s.improved_fraction * 100.0
    );
    println!(
        "DAGs improved >=95%  : {:.0}%  (paper: 45% near-100%)",
        s.near_total_fraction * 100.0
    );

    println!("\n== per-DAG completion improvement CDF ==");
    let cdf = improvement_cdf(&base, &run);
    for q in [5, 25, 50, 75, 90, 95] {
        let idx = (cdf.len().saturating_sub(1)) * q / 100;
        println!("  p{q:<3} improvement: {:>6.1}%", cdf[idx] * 100.0);
    }
    Ok(())
}
