//! Quickstart: co-optimize one DAG end to end with the public API.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Walks the full AGORA flow on the paper's Fig. 1 pipeline (data
//! pre-processing feeding three ML jobs):
//!   1. gather event-log history for each task (one profiling run set),
//!   2. fit the Predictor and build the runtime grid,
//!   3. co-optimize configurations + schedule (Algorithm 1),
//!   4. execute the plan on the simulated cluster and compare.

use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::dag::workloads::fig1_dag;
use agora::predictor::{bootstrap_history, default_profiling_configs, EventLog};
use agora::solver::{Agora, AgoraOptions, Goal};
use agora::util::{fmt_cost, fmt_duration, Rng};

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);

    // 1. The workload: Fig. 1's pipeline, and its (simulated) history.
    let dag = fig1_dag();
    println!("workload: {} with {} tasks", dag.name, dag.len());
    let logs: Vec<EventLog> = dag
        .tasks
        .iter()
        .map(|t| bootstrap_history(&t.name, &t.profile, &default_profiling_configs(), &mut rng))
        .collect();

    // 2. Predictor + extended-RCPSP problem over the standard config
    //    space (Table 1 instances x node ladder x Spark presets).
    let dags = vec![dag];
    let problem = Agora::build_problem(
        &dags,
        &[0.0],
        &logs,
        Capacity::micro(),
        ConfigSpace::standard(),
        CostModel::OnDemand,
    );
    println!(
        "problem: {} tasks, {} candidate configs, {} precedence edges",
        problem.len(),
        problem.space.len(),
        problem.precedence.len()
    );

    // 3. Co-optimize for a balanced cost/runtime goal.
    let agora = Agora::new(AgoraOptions {
        goal: Goal::Balanced,
        ..Default::default()
    });
    let plan = agora.optimize(&problem);
    println!(
        "\nplan: predicted makespan {}  cost {}  ({} annealing iterations in {:?})",
        fmt_duration(plan.makespan),
        fmt_cost(plan.cost),
        plan.anneal.as_ref().map_or(0, |a| a.stats.iterations),
        plan.overhead
    );
    println!("\n{}", plan.schedule.render(&problem));

    // 4. Execute against ground truth.
    let report = agora::sim::execute(&problem, &dags, &plan.schedule, &CostModel::OnDemand, &mut rng);
    println!(
        "executed: actual makespan {}  cost {}  prediction error {:.1}%",
        fmt_duration(report.makespan),
        fmt_cost(report.cost),
        report.prediction_mape * 100.0
    );
    Ok(())
}
