//! Domain example: why co-optimization matters for a production ETL+ML
//! pipeline — the §3 motivational study as a runnable program.
//!
//! Run: `cargo run --release --example pipeline_cooptimize`
//!
//! Compares four ways of running DAG1 + DAG2 (the paper's Fig. 6
//! evaluation DAGs) and prints the runtime/cost frontier:
//!   * default Airflow (no optimization),
//!   * Ernest VM selection + Critical-Path scheduling (separate),
//!   * Ernest VM selection + MILP scheduling (separate),
//!   * AGORA co-optimization at all three goals.
//!
//! Uses the AOT/PJRT predictor path when `artifacts/` exists, otherwise
//! falls back to the host predictor (identical numerics).

use agora::baselines::{
    AirflowScheduler, CriticalPathScheduler, ErnestGoal, MilpScheduler, Scheduler,
    StratusScheduler,
};
use agora::cluster::{Capacity, ConfigSpace, CostModel};
use agora::dag::workloads::{dag1, dag2};
use agora::predictor::{bootstrap_history, default_profiling_configs, EventLog};
use agora::runtime::{Engine, PjrtPredictor};
use agora::solver::{Agora, AgoraOptions, Goal, Problem};
use agora::util::{fmt_cost, fmt_duration, Rng};
use agora::{LearnedPredictor, Predictor};

fn build_problem(use_pjrt: bool, rng: &mut Rng) -> anyhow::Result<(Problem, Vec<agora::Dag>)> {
    let dags = vec![dag1(), dag2()];
    let space = ConfigSpace::standard();
    let logs: Vec<EventLog> = dags
        .iter()
        .flat_map(|d| {
            d.tasks
                .iter()
                .map(|t| bootstrap_history(&t.name, &t.profile, &default_profiling_configs(), rng))
                .collect::<Vec<_>>()
        })
        .collect();

    let grid = if use_pjrt {
        let engine = Engine::new(&agora::runtime::ArtifactManifest::default_dir())?;
        println!("(predictor running through PJRT: {})", engine.platform());
        PjrtPredictor::new(&engine).fit_predict(&logs, &space)?.0
    } else {
        println!("(predictor running on host; run `make artifacts` for the PJRT path)");
        LearnedPredictor::fit(&logs).predict(&space)
    };

    let p = Agora::build_problem_with_grid(
        &dags,
        &[0.0, 0.0],
        grid,
        Capacity::micro(),
        space,
        CostModel::OnDemand,
    );
    Ok((p, dags))
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);
    let use_pjrt = agora::runtime::ArtifactManifest::default_dir()
        .join("manifest.json")
        .exists();
    let (p, dags) = build_problem(use_pjrt, &mut rng)?;

    println!(
        "pipeline: {} tasks across {} DAGs, {} candidate configurations\n",
        p.len(),
        dags.len(),
        p.space.len()
    );

    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    let mut run = |name: String, schedule: agora::Schedule| {
        let mut rng = Rng::new(99); // same execution noise for everyone
        let rep = agora::sim::execute(&p, &dags, &schedule, &CostModel::OnDemand, &mut rng);
        rows.push((name, rep.makespan, rep.cost));
    };

    run("airflow (default)".into(), AirflowScheduler::default().schedule(&p)?);
    run(
        "ernest+cp (separate)".into(),
        CriticalPathScheduler::with_ernest(ErnestGoal(Goal::Balanced)).schedule(&p)?,
    );
    run(
        "ernest+milp (separate)".into(),
        MilpScheduler::with_ernest(ErnestGoal(Goal::Balanced)).schedule(&p)?,
    );
    run("stratus (cost-aware)".into(), StratusScheduler::default().schedule(&p)?);

    for goal in [Goal::Cost, Goal::Balanced, Goal::Runtime] {
        let agora_opt = Agora::new(AgoraOptions {
            goal,
            ..Default::default()
        });
        let plan = agora_opt.optimize(&p);
        run(format!("AGORA ({})", goal.name()), plan.schedule);
    }

    println!("{:<24} {:>12} {:>10}", "policy", "makespan", "cost");
    println!("{}", "-".repeat(48));
    let base = rows[0].clone();
    for (name, makespan, cost) in &rows {
        println!(
            "{:<24} {:>12} {:>10}   ({} runtime, {} cost vs airflow)",
            name,
            fmt_duration(*makespan),
            fmt_cost(*cost),
            agora::bench::pct(base.1, *makespan),
            agora::bench::pct(base.2, *cost),
        );
    }
    Ok(())
}
