//! Domain example: AGORA as a multi-tenant scheduling service.
//!
//! Run: `cargo run --release --example multi_tenant_service`
//!
//! Spawns the threaded coordinator service and three tenant threads that
//! submit pipelines concurrently (the serverless-like experience the
//! paper's conclusion sketches). The coordinator batches submissions per
//! the trigger policy, co-optimizes each batch as one multi-DAG problem,
//! executes on the simulated cluster, and answers every tenant.

use std::time::Duration;

use agora::coordinator::service::{Service, ServiceConfig};
use agora::dag::workloads::{dag1, dag2, fig1_dag};
use agora::solver::Goal;
use agora::util::{fmt_cost, fmt_duration};

fn main() -> anyhow::Result<()> {
    let service = Service::start(ServiceConfig {
        goal: Goal::Balanced,
        batch_window: Duration::from_millis(100),
        max_queue: 4,
        ..Default::default()
    });

    // Three tenants submit from their own threads, like Airflow clients.
    let mut joins = Vec::new();
    for (tenant, dag, delay_ms) in [
        ("analytics", dag1(), 0u64),
        ("ml-platform", dag2(), 20),
        ("reporting", fig1_dag(), 40),
    ] {
        let handle = service.handle();
        joins.push(std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(delay_ms));
            let ticket = handle.submit(tenant, dag).expect("admitted");
            ticket
                .recv_timeout(Duration::from_secs(180))
                .expect("coordinator answers")
        }));
    }

    println!("{:<12} {:<6} {:>6} {:>12} {:>10}", "tenant", "dag", "round", "completion", "cost");
    println!("{}", "-".repeat(52));
    let mut results: Vec<_> = joins
        .into_iter()
        .map(|j| j.join().expect("tenant thread"))
        .collect();
    results.sort_by_key(|r| r.round);
    for r in &results {
        println!(
            "{:<12} {:<6} {:>6} {:>12} {:>10}",
            r.tenant,
            r.dag_name,
            r.round,
            fmt_duration(r.completion),
            fmt_cost(r.cost)
        );
    }

    println!("\n{}", service.status().render());
    let rounds = service.shutdown()?;
    println!("coordinator served {} optimization round(s)", rounds);

    // Tenants batched into the same round were co-optimized as ONE
    // multi-DAG problem — the multi-tenant benefit of §4.1.
    let batched = results.windows(2).filter(|w| w[0].round == w[1].round).count();
    if batched > 0 {
        println!("{batched} adjacent submissions shared a co-optimization round");
    }
    Ok(())
}
