//! Alibaba-like production trace (§5.5 macro-benchmark substrate).
//!
//! The 2018 Alibaba cluster trace itself is not redistributable, so we
//! generate a statistically shaped synthetic equivalent with the
//! properties the paper uses:
//!   * batch jobs are DAGs; task counts are heavy-tailed (most DAGs are
//!     small, a few are large) per the published analyses [29];
//!   * machines have 96 cores, memory given as a fraction of machine
//!     memory;
//!   * the DAG-batch share of the cluster is 20% of CPU and 40% of memory
//!     (online services own the rest, per [22] — the same reduction the
//!     paper applies);
//!   * per-task scaling curves follow the USL (Eq. 9) with alpha, beta
//!     drawn uniformly from [0, 1) ranges and gamma fitted to the traced
//!     demand/runtime, exactly the paper's §5.5.1 methodology;
//!   * jobs arrive over a submission window (Poisson-ish inter-arrival).

use crate::cluster::Capacity;
use crate::dag::{Dag, Task, TaskProfile};
use crate::util::Rng;

/// One traced job: a DAG plus its submission time.
#[derive(Debug, Clone)]
pub struct TracedJob {
    /// The job's workflow DAG.
    pub dag: Dag,
    /// Submission instant in virtual seconds from trace start.
    pub submit_time: f64,
}

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceParams {
    /// Number of DAG jobs in the trace.
    pub jobs: usize,
    /// Submission window in seconds.
    pub window: f64,
    /// Machines in the (scaled-down) cluster.
    pub machines: usize,
    /// Cores per machine (Alibaba: 96).
    pub cores_per_machine: u32,
    /// Memory per machine in GiB (undisclosed in the trace; we follow the
    /// common 4 GiB/core assumption used in trace analyses).
    pub mem_per_core_gb: f64,
    /// Fraction of cluster CPU available to batch DAGs (paper: 20%).
    pub cpu_fraction: f64,
    /// Fraction of cluster memory available to batch DAGs (paper: 40%).
    pub mem_fraction: f64,
}

impl Default for TraceParams {
    fn default() -> Self {
        TraceParams {
            jobs: 200,
            window: 4.0 * 3600.0,
            machines: 48,
            cores_per_machine: 96,
            mem_per_core_gb: 4.0,
            cpu_fraction: 0.20,
            mem_fraction: 0.40,
        }
    }
}

impl TraceParams {
    /// The batch-workload capacity after the online-services reduction.
    pub fn batch_capacity(&self) -> Capacity {
        let cores = self.machines as f64 * self.cores_per_machine as f64;
        let mem = cores * self.mem_per_core_gb;
        Capacity::new(cores * self.cpu_fraction, mem * self.mem_fraction)
    }

    /// Small preset for tests and CI.
    pub fn tiny() -> Self {
        TraceParams {
            jobs: 12,
            window: 1800.0,
            machines: 8,
            ..Default::default()
        }
    }

    /// A deliberately contended slice of the cluster — the macro-bench
    /// setting: the paper's macro gains are dominated by queueing (and
    /// continuous admission's by round overlap), so the batch share must
    /// be small relative to the offered load, like the production trace.
    pub fn contended(jobs: usize) -> Self {
        TraceParams {
            jobs,
            machines: 12,
            ..Default::default()
        }
    }

    /// Admission-stress preset for the round-barrier vs continuous
    /// comparison: the full default slice (several default-config tasks
    /// fit side by side, so round tails leave reclaimable gaps) offered
    /// its load in an 8x-compressed window, so triggered rounds overlap
    /// and the bulk-synchronous barrier's head-of-line blocking becomes
    /// visible.
    pub fn admission_stress(jobs: usize) -> Self {
        TraceParams {
            jobs,
            window: 1800.0,
            ..Default::default()
        }
    }
}

/// Mean DAG arrival rate of a generated trace in jobs per hour — the
/// offered-load axis quoted alongside cluster utilization by the macro
/// benchmarks. 0.0 for traces with fewer than two distinct submit times.
pub fn arrival_rate_per_hour(jobs: &[TracedJob]) -> f64 {
    if jobs.len() < 2 {
        return 0.0;
    }
    let first = jobs
        .iter()
        .map(|j| j.submit_time)
        .fold(f64::INFINITY, f64::min);
    let last = jobs.iter().map(|j| j.submit_time).fold(0.0f64, f64::max);
    let span = last - first;
    if span <= 0.0 {
        return 0.0;
    }
    jobs.len() as f64 / span * 3600.0
}

/// Heavy-tailed task-count draw: ~70% of DAGs have <= 5 tasks, tail up to
/// `cap` (shape from the published Alibaba DAG analyses).
fn task_count(rng: &mut Rng, cap: usize) -> usize {
    let x = rng.pareto(1.0, 1.6);
    (1.0 + x).min(cap as f64) as usize
}

/// Random USL-per-Eq.-9 profile for a traced task: alpha, beta in [0, 1)
/// bounded as the paper specifies; gamma (we carry it as `work`) fitted
/// to the traced runtime scale.
fn traced_profile(rng: &mut Rng) -> TaskProfile {
    TaskProfile {
        // traced batch tasks: seconds to tens of minutes, heavy tail
        work: (rng.lognormal(4.5, 1.1)).clamp(10.0, 7200.0),
        alpha: rng.uniform(0.0, 0.6),
        beta: rng.uniform(0.0, 0.05),
        mem_gb: rng.uniform(4.0, 128.0),
        spark_affinity: rng.uniform(-1.0, 1.0),
        noise_sigma: rng.uniform(0.01, 0.08),
    }
}

/// A traced DAG: layered, mostly chains/small fans like production ETL.
fn traced_dag(rng: &mut Rng, id: usize, max_tasks: usize) -> Dag {
    let n = task_count(rng, max_tasks);
    let tasks: Vec<Task> = (0..n)
        .map(|i| Task {
            name: format!("j{id}t{i}"),
            profile: traced_profile(rng),
        })
        .collect();
    // Chain-with-skips topology: each task depends on a recent earlier
    // task with high probability (production DAGs are mostly deep-ish).
    let mut edges = Vec::new();
    for i in 1..n {
        if rng.chance(0.85) {
            let back = rng.range(1, i.min(3));
            edges.push((i - back, i));
        }
        if rng.chance(0.25) && i >= 2 {
            let extra = rng.below(i - 1);
            if extra != i {
                edges.push((extra, i));
            }
        }
    }
    edges.sort_unstable();
    edges.dedup();
    Dag::new(&format!("job{id}"), tasks, edges).expect("forward edges are acyclic")
}

/// Generate the full synthetic trace, sorted by submission time.
pub fn generate(params: &TraceParams, rng: &mut Rng) -> Vec<TracedJob> {
    let mut jobs: Vec<TracedJob> = (0..params.jobs)
        .map(|id| TracedJob {
            dag: traced_dag(rng, id, 20),
            submit_time: rng.uniform(0.0, params.window),
        })
        .collect();
    // NaN-safe total order: a degenerate submit time must never panic the
    // trace generator (total_cmp sorts NaN last instead of unwrapping).
    jobs.sort_by(|a, b| a.submit_time.total_cmp(&b.submit_time));
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_reduction_matches_paper() {
        let p = TraceParams::default();
        let cap = p.batch_capacity();
        let total_cores = 48.0 * 96.0;
        assert!((cap.vcpus - total_cores * 0.20).abs() < 1e-9);
        assert!((cap.memory_gb - total_cores * 4.0 * 0.40).abs() < 1e-9);
    }

    #[test]
    fn trace_is_sorted_and_sized() {
        let mut rng = Rng::new(1);
        let jobs = generate(&TraceParams::tiny(), &mut rng);
        assert_eq!(jobs.len(), 12);
        for w in jobs.windows(2) {
            assert!(w[0].submit_time <= w[1].submit_time);
        }
    }

    #[test]
    fn task_counts_are_heavy_tailed() {
        let mut rng = Rng::new(2);
        let counts: Vec<usize> = (0..600).map(|_| task_count(&mut rng, 20)).collect();
        let small = counts.iter().filter(|&&c| c <= 5).count();
        let large = counts.iter().filter(|&&c| c >= 15).count();
        assert!(small > 350, "most DAGs should be small: {small}");
        assert!(large >= 4, "a tail of large DAGs must exist: {large}");
    }

    #[test]
    fn all_dags_valid_and_within_bounds() {
        let mut rng = Rng::new(3);
        for job in generate(&TraceParams::tiny(), &mut rng) {
            assert!(job.dag.topo_order().is_ok());
            assert!(job.dag.len() >= 1 && job.dag.len() <= 20);
            for t in &job.dag.tasks {
                assert!(t.profile.alpha < 1.0 && t.profile.beta < 1.0);
            }
        }
    }

    #[test]
    fn arrival_rate_reflects_window() {
        let mut rng = Rng::new(5);
        let jobs = generate(&TraceParams::tiny(), &mut rng);
        let rate = arrival_rate_per_hour(&jobs);
        // 12 jobs over a 1800 s window: about 24/h (submit times are
        // uniform draws, so allow generous slack).
        assert!(rate > 10.0 && rate < 60.0, "rate {rate}");
        assert_eq!(arrival_rate_per_hour(&jobs[..1]), 0.0);
        assert_eq!(arrival_rate_per_hour(&[]), 0.0);
    }

    #[test]
    fn contended_preset_shrinks_the_batch_slice() {
        let p = TraceParams::contended(48);
        assert_eq!(p.jobs, 48);
        assert!(p.batch_capacity().vcpus < TraceParams::default().batch_capacity().vcpus);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&TraceParams::tiny(), &mut Rng::new(9));
        let b = generate(&TraceParams::tiny(), &mut Rng::new(9));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.dag.len(), y.dag.len());
            assert_eq!(x.submit_time, y.submit_time);
        }
    }
}
