//! # AGORA — globally co-optimized resource allocation + DAG scheduling
//!
//! Reproduction of *"Global Optimization of Data Pipelines in
//! Heterogeneous Cloud Environments"* (Lin et al., 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: DAG ingestion, the Predictor
//!   orchestration, the simulated-annealing ⊗ CP co-optimizer, baseline
//!   schedulers, the cluster execution simulator, and the multi-tenant
//!   service loop.
//! * **L2/L1 (python/compile)** — the Predictor's batched fit + grid
//!   prediction, AOT-lowered to `artifacts/*.hlo.txt` and executed from
//!   Rust through PJRT (`runtime` module). Python never runs at request
//!   time.
//!
//! Quickstart: see `examples/quickstart.rs`; architecture: DESIGN.md.
//!
//! The multi-tenant coordinator ([`coordinator`]) serves triggered
//! batches either bulk-synchronously or with continuous admission onto
//! the occupied-cluster timeline
//! ([`coordinator::Admission`]); the occupancy mechanism itself is a
//! first-class input of the optimization problem
//! ([`solver::Problem::with_occupancy`]).

#![warn(missing_docs)]

pub mod baselines;
pub mod bench;
pub mod cluster;
pub mod config;
pub mod dag;
pub mod coordinator;
pub mod predictor;
pub mod runtime;
pub mod sim;
pub mod solver;
pub mod trace;
pub mod util;

pub use cluster::{Capacity, Config, ConfigSpace, CostModel};
pub use dag::{Dag, Task, TaskProfile};
pub use predictor::{Grid, LearnedPredictor, OraclePredictor, Predictor};
pub use solver::{Agora, AgoraOptions, Goal, Problem, Schedule};
