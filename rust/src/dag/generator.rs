//! Random DAG generators for the scalability (Fig. 10) and ablation
//! experiments, plus arbitrary layered DAGs for property tests.

use super::{Dag, Task, TaskProfile};
use crate::util::Rng;

/// Parameters for the layered random generator.
#[derive(Debug, Clone)]
pub struct GenParams {
    /// Max tasks per layer.
    pub width: usize,
    /// Minimum layer count (inclusive).
    pub depth_min: usize,
    /// Maximum layer count (inclusive).
    pub depth_max: usize,
    /// Total task budget (generation stops when reached).
    pub tasks: usize,
    /// Probability of an edge between consecutive-layer task pairs.
    pub edge_prob: f64,
}

impl GenParams {
    /// The paper's Fig. 10 setup: "randomly generated DAGs with a width of
    /// 4 and a depth of 3-5 consisting of 10 tasks each".
    pub fn fig10() -> GenParams {
        GenParams {
            width: 4,
            depth_min: 3,
            depth_max: 5,
            tasks: 10,
            edge_prob: 0.5,
        }
    }
}

/// Random task profile spanning the realistic ranges of the workload
/// library (work 5 min .. 1 h, USL parameters in [0, 1] like §5.5.1).
pub fn random_profile(rng: &mut Rng) -> TaskProfile {
    TaskProfile {
        work: rng.uniform(300.0, 3600.0),
        alpha: rng.uniform(0.01, 0.35),
        beta: rng.uniform(0.0, 0.02),
        mem_gb: rng.uniform(16.0, 256.0),
        spark_affinity: rng.uniform(-1.0, 1.0),
        noise_sigma: rng.uniform(0.01, 0.06),
    }
}

/// Layered random DAG. Every non-first-layer task gets at least one
/// predecessor in the previous layer so the graph is connected forward;
/// extra edges appear with `edge_prob`.
pub fn random_dag(rng: &mut Rng, name: &str, p: &GenParams) -> Dag {
    assert!(p.tasks >= 1 && p.width >= 1 && p.depth_min >= 1 && p.depth_max >= p.depth_min);
    let depth = rng.range(p.depth_min, p.depth_max);

    // Distribute the task budget across layers (>= 1 per layer).
    let mut layer_sizes = vec![1usize; depth];
    let mut remaining = p.tasks.saturating_sub(depth);
    while remaining > 0 {
        let l = rng.below(depth);
        if layer_sizes[l] < p.width {
            layer_sizes[l] += 1;
            remaining -= 1;
        } else if layer_sizes.iter().all(|&s| s >= p.width) {
            break; // budget exceeds width*depth; cap
        }
    }

    let mut tasks = Vec::new();
    let mut layers: Vec<Vec<usize>> = Vec::new();
    for (li, &size) in layer_sizes.iter().enumerate() {
        let mut layer = Vec::new();
        for s in 0..size {
            layer.push(tasks.len());
            tasks.push(Task {
                name: format!("{name}-l{li}t{s}"),
                profile: random_profile(rng),
            });
        }
        layers.push(layer);
    }

    let mut edges = Vec::new();
    for w in 1..layers.len() {
        for &t in &layers[w] {
            let mut any = false;
            for &prev in &layers[w - 1] {
                if rng.chance(p.edge_prob) {
                    edges.push((prev, t));
                    any = true;
                }
            }
            if !any {
                // guarantee connectivity to the previous layer
                let prev = *rng.choice(&layers[w - 1]);
                edges.push((prev, t));
            }
        }
    }

    Dag::new(name, tasks, edges).expect("layered construction is acyclic")
}

/// A batch of Fig. 10-style DAGs (10 tasks each).
pub fn fig10_batch(rng: &mut Rng, count: usize) -> Vec<Dag> {
    (0..count)
        .map(|i| random_dag(rng, &format!("rand{i}"), &GenParams::fig10()))
        .collect()
}

/// Large-scale workflow preset (Alibaba-trace shapes at production
/// scale): a sequence of alternating **wide fan-out stages** (a source
/// task spraying into 8-24 parallel map tasks that re-join at a barrier,
/// like a shuffle boundary) and **deep chains** (5-15 sequential reduce
/// / ETL steps), stitched end to end until the task budget is spent.
/// Defaults to ~1000 tasks via [`large_scale_dag`]; the scaling
/// benchmark (`benches/scaling_timeline.rs`) sweeps it from 50 up to
/// 100_000 tasks (production-trace scale — generation is O(n) and the
/// edge list stays ~1.9 edges/task, so even the 100k instance builds in
/// milliseconds) and `agora trace --trace-large N` appends N of them to
/// the macro trace.
///
/// Acyclic by construction: every edge points from a lower to a higher
/// task index.
pub fn large_scale_dag(rng: &mut Rng, name: &str, tasks: usize) -> Dag {
    let tasks = tasks.max(3);
    let mut all: Vec<Task> = Vec::with_capacity(tasks);
    let mut edges: Vec<(usize, usize)> = Vec::new();
    let push = |all: &mut Vec<Task>, rng: &mut Rng| {
        let id = all.len();
        all.push(Task {
            name: format!("{name}-t{id}"),
            profile: random_profile(rng),
        });
        id
    };

    // A single source keeps the DAG connected.
    let mut tail = push(&mut all, rng);
    while all.len() < tasks {
        let remaining = tasks - all.len();
        if rng.chance(0.5) && remaining >= 3 {
            // Fan-out stage: tail -> k parallel tasks -> join barrier.
            // k <= remaining - 1 always leaves budget for the join.
            let k = rng.range(8, 24).min(remaining - 1);
            let fan: Vec<usize> = (0..k)
                .map(|_| {
                    let t = push(&mut all, rng);
                    edges.push((tail, t));
                    t
                })
                .collect();
            let join = push(&mut all, rng);
            for &t in &fan {
                edges.push((t, join));
            }
            tail = join;
        } else {
            // Deep chain hanging off the current tail.
            let c = rng.range(5, 15).min(remaining);
            for _ in 0..c {
                let t = push(&mut all, rng);
                edges.push((tail, t));
                tail = t;
            }
        }
    }

    Dag::new(name, all, edges).expect("index-increasing edges are acyclic")
}

/// Fully random DAG for property tests: arbitrary edge density over a
/// random topological order (always acyclic by construction).
pub fn arbitrary_dag(rng: &mut Rng, max_tasks: usize) -> Dag {
    let n = rng.range(1, max_tasks.max(1));
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let tasks = (0..n)
        .map(|i| Task {
            name: format!("t{i}"),
            profile: random_profile(rng),
        })
        .collect();
    let mut edges = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.chance(0.25) {
                edges.push((order[i], order[j]));
            }
        }
    }
    Dag::new("arbitrary", tasks, edges).expect("order-respecting edges are acyclic")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_dags_have_ten_tasks() {
        let mut rng = Rng::new(42);
        for d in fig10_batch(&mut rng, 20) {
            assert_eq!(d.len(), 10, "paper: 10 tasks per random DAG");
            assert!(d.width() <= 4, "paper: width 4");
            let depth = d.depth();
            assert!((3..=5).contains(&depth), "paper: depth 3-5, got {depth}");
        }
    }

    #[test]
    fn random_dags_are_valid() {
        let mut rng = Rng::new(7);
        for i in 0..50 {
            let d = arbitrary_dag(&mut rng, 20);
            assert!(d.topo_order().is_ok(), "dag {i} has a cycle");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let d1 = random_dag(&mut Rng::new(5), "x", &GenParams::fig10());
        let d2 = random_dag(&mut Rng::new(5), "x", &GenParams::fig10());
        assert_eq!(d1.edges, d2.edges);
        assert_eq!(d1.len(), d2.len());
    }

    #[test]
    fn profiles_are_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let p = random_profile(&mut rng);
            assert!(p.work >= 300.0 && p.work <= 3600.0);
            assert!(p.alpha >= 0.0 && p.alpha <= 1.0);
            assert!(p.beta >= 0.0 && p.beta <= 1.0);
            assert!(p.spark_affinity >= -1.0 && p.spark_affinity <= 1.0);
        }
    }

    #[test]
    fn large_scale_dag_hits_the_task_budget_and_stays_acyclic() {
        let mut rng = Rng::new(9);
        for &n in &[50usize, 200, 1000] {
            let d = large_scale_dag(&mut rng, "big", n);
            assert_eq!(d.len(), n, "budget must be spent exactly");
            assert!(d.topo_order().is_ok(), "large-scale DAG has a cycle");
            // Connected forward: exactly one root (the source).
            let roots: Vec<usize> =
                (0..d.len()).filter(|&t| d.preds(t).is_empty()).collect();
            assert_eq!(roots, vec![0], "the source is the only root");
        }
    }

    #[test]
    fn large_scale_dag_mixes_fan_out_and_chains() {
        // Over a ~1000-task instance both stage shapes must appear:
        // some task fans out to >= 8 successors, and some chain of
        // single-successor tasks runs >= 5 deep.
        let d = large_scale_dag(&mut Rng::new(4), "mix", 1000);
        let max_fan = (0..d.len()).map(|t| d.succs(t).len()).max().unwrap();
        assert!(max_fan >= 8, "no wide fan-out stage (max fan {max_fan})");
        let mut longest_chain = 0usize;
        for start in 0..d.len() {
            let mut t = start;
            let mut depth = 0usize;
            while d.succs(t).len() == 1 && d.preds(d.succs(t)[0]).len() == 1 {
                t = d.succs(t)[0];
                depth += 1;
            }
            longest_chain = longest_chain.max(depth);
        }
        assert!(longest_chain >= 5, "no deep chain (longest {longest_chain})");
    }

    #[test]
    fn large_scale_dag_scales_to_ten_thousand_tasks() {
        // The 10k-100k bench sizes lean on generation staying O(n): the
        // structure invariants (exact budget, acyclic, single source,
        // bounded fan-in from the stage construction) must hold at the
        // first bench size beyond the historical 2000-task ceiling.
        let d = large_scale_dag(&mut Rng::new(0xA11B), "huge", 10_000);
        assert_eq!(d.len(), 10_000);
        assert!(d.topo_order().is_ok());
        let roots: Vec<usize> = (0..d.len()).filter(|&t| d.preds(t).is_empty()).collect();
        assert_eq!(roots, vec![0], "the source is the only root");
        // Stage construction: fan-in is bounded by the widest join (24).
        let max_fan_in = (0..d.len()).map(|t| d.preds(t).len()).max().unwrap();
        assert!(max_fan_in <= 24, "join wider than the stage cap: {max_fan_in}");
        // Sparse by construction: ~1.9 edges per task keeps 100k viable.
        assert!(d.edges.len() < 3 * d.len(), "edge list no longer sparse");
    }

    #[test]
    fn large_scale_generation_is_deterministic() {
        let a = large_scale_dag(&mut Rng::new(7), "d", 300);
        let b = large_scale_dag(&mut Rng::new(7), "d", 300);
        assert_eq!(a.edges, b.edges);
        assert_eq!(a.len(), b.len());
    }

    #[test]
    fn every_non_root_task_has_a_predecessor() {
        let mut rng = Rng::new(11);
        let d = random_dag(&mut rng, "conn", &GenParams::fig10());
        // layer-0 tasks have no preds; all others must have at least one
        let roots: Vec<usize> = (0..d.len()).filter(|&t| d.preds(t).is_empty()).collect();
        assert!(!roots.is_empty());
        assert!(roots.len() < d.len());
    }
}
