//! The paper's real-world workload library and evaluation DAGs.
//!
//! Four production-pipeline jobs (§3): Index Analysis (data
//! pre-processing), Sentiment Analysis (NLP), Airline Delay (ML
//! prediction) and Movie Recommendation (collaborative filtering), plus
//! the three DAGs built from them: the Fig. 1 motivational DAG and the
//! Fig. 6 evaluation DAGs (DAG1: fan-in bottlenecks; DAG2: parallel
//! chains converging on a final analysis).
//!
//! Profiles are synthetic but shaped to the paper's Fig. 2 measurements:
//! every job shows diminishing returns with node count and Sentiment
//! Analysis shows *negative* scaling on large m5.4xlarge clusters
//! (beta high enough that 16 nodes is slower than 8).

use super::{Dag, Task, TaskProfile};

/// The four real-world jobs of §3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobKind {
    /// Reads raw data, extracts features, writes back to S3.
    IndexAnalysis,
    /// Text sentiment analysis with NLP — negative scaling at high N.
    SentimentAnalysis,
    /// Predicts airline delays; moderately memory-hungry training.
    AirlineDelay,
    /// ALS-style recommender; shuffle-heavy.
    MovieRecommendation,
}

/// Every job of the library, in §3 order.
pub const ALL_JOBS: &[JobKind] = &[
    JobKind::IndexAnalysis,
    JobKind::SentimentAnalysis,
    JobKind::AirlineDelay,
    JobKind::MovieRecommendation,
];

impl JobKind {
    /// Kebab-case job name used in reports and task names.
    pub fn name(&self) -> &'static str {
        match self {
            JobKind::IndexAnalysis => "index-analysis",
            JobKind::SentimentAnalysis => "sentiment-analysis",
            JobKind::AirlineDelay => "airline-delay",
            JobKind::MovieRecommendation => "movie-recommendation",
        }
    }

    /// Ground-truth profile for the job.
    pub fn profile(&self) -> TaskProfile {
        match self {
            JobKind::IndexAnalysis => TaskProfile {
                work: 900.0,
                alpha: 0.12,
                beta: 0.002,
                mem_gb: 48.0,
                spark_affinity: 0.6,
                noise_sigma: 0.03,
            },
            JobKind::SentimentAnalysis => TaskProfile {
                work: 1500.0,
                alpha: 0.05,
                // High coherency: crosstalk between NLP shuffle partitions
                // dominates past ~8 m5.4xlarge nodes (paper Fig. 2 shows
                // negative scaling for this job).
                beta: 0.018,
                mem_gb: 80.0,
                spark_affinity: -0.4,
                noise_sigma: 0.04,
            },
            JobKind::AirlineDelay => TaskProfile {
                work: 1100.0,
                alpha: 0.10,
                beta: 0.005,
                mem_gb: 120.0,
                spark_affinity: 0.0,
                noise_sigma: 0.03,
            },
            JobKind::MovieRecommendation => TaskProfile {
                work: 1800.0,
                alpha: 0.15,
                beta: 0.004,
                mem_gb: 160.0,
                spark_affinity: -0.9,
                noise_sigma: 0.05,
            },
        }
    }

    /// A single task of this job (name + profile).
    pub fn task(&self) -> Task {
        Task {
            name: self.name().to_string(),
            profile: self.profile(),
        }
    }

    fn task_named(&self, suffix: &str) -> Task {
        Task {
            name: format!("{}-{suffix}", self.name()),
            profile: self.profile(),
        }
    }
}

/// Fig. 1: the motivational DAG — data pre-processing feeding three ML
/// jobs ("a typical data analytic pipeline: three ML jobs after data
/// pre-processing").
pub fn fig1_dag() -> Dag {
    Dag::new(
        "fig1",
        vec![
            JobKind::IndexAnalysis.task(),
            JobKind::AirlineDelay.task(),
            JobKind::SentimentAnalysis.task(),
            JobKind::MovieRecommendation.task(),
        ],
        vec![(0, 1), (0, 2), (0, 3)],
    )
    .expect("static DAG is valid")
}

/// Fig. 6, DAG1: pre-processing, then ML workloads that build on each
/// other with fan-in bottlenecks — "tasks that are waiting for a single
/// task to finish before the other tasks begin (the top and second to
/// last tasks)". Lower parallelism, longer critical path.
pub fn dag1() -> Dag {
    Dag::new(
        "DAG1",
        vec![
            JobKind::IndexAnalysis.task_named("ingest"), // 0 (top bottleneck)
            JobKind::AirlineDelay.task_named("train-a"), // 1
            JobKind::SentimentAnalysis.task_named("nlp"), // 2
            JobKind::MovieRecommendation.task_named("als"), // 3
            JobKind::AirlineDelay.task_named("combine"), // 4
            JobKind::IndexAnalysis.task_named("merge"),  // 5 (2nd-to-last bottleneck)
            JobKind::SentimentAnalysis.task_named("report"), // 6
            JobKind::MovieRecommendation.task_named("publish"), // 7
        ],
        vec![
            (0, 1),
            (0, 2),
            (0, 3),
            (1, 4),
            (2, 4),
            (3, 5),
            (4, 5),
            (5, 6),
            (5, 7),
        ],
    )
    .expect("static DAG is valid")
}

/// Fig. 6, DAG2: independent ML chains converging in one final analysis —
/// "many tasks can run in parallel and the only bottleneck is the final
/// task". Higher parallelism, more room for runtime optimization.
pub fn dag2() -> Dag {
    Dag::new(
        "DAG2",
        vec![
            JobKind::IndexAnalysis.task_named("ingest-a"), // 0
            JobKind::AirlineDelay.task_named("train-a"),   // 1
            JobKind::IndexAnalysis.task_named("ingest-b"), // 2
            JobKind::SentimentAnalysis.task_named("nlp-b"), // 3
            JobKind::IndexAnalysis.task_named("ingest-c"), // 4
            JobKind::MovieRecommendation.task_named("als-c"), // 5
            JobKind::SentimentAnalysis.task_named("nlp-d"), // 6
            JobKind::AirlineDelay.task_named("analyze"),   // 7 (only bottleneck)
        ],
        vec![
            (0, 1),
            (2, 3),
            (4, 5),
            (1, 7),
            (3, 7),
            (5, 7),
            (6, 7),
        ],
    )
    .expect("static DAG is valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Config;

    #[test]
    fn fig1_shape() {
        let d = fig1_dag();
        assert_eq!(d.len(), 4);
        assert_eq!(d.depth(), 2);
        assert_eq!(d.succs(0).len(), 3);
    }

    #[test]
    fn dag1_has_bottlenecks() {
        let d = dag1();
        assert_eq!(d.len(), 8);
        // top task fans out, task 5 fans in then out
        assert_eq!(d.succs(0).len(), 3);
        assert_eq!(d.preds(5).len(), 2);
        assert_eq!(d.succs(5).len(), 2);
        assert!(d.depth() >= 5, "DAG1 is deep (low parallelism)");
    }

    #[test]
    fn dag2_converges_on_final_task() {
        let d = dag2();
        assert_eq!(d.len(), 8);
        assert_eq!(d.preds(7).len(), 4);
        assert!(d.width() >= 4, "DAG2 is wide (high parallelism)");
        assert!(d.depth() < dag1().depth());
    }

    #[test]
    fn sentiment_shows_negative_scaling_on_m54xlarge() {
        // The paper's Fig. 2 signature behaviour.
        let p = JobKind::SentimentAnalysis.profile();
        let r8 = p.runtime(&Config {
            instance: 0,
            nodes: 8,
            spark: 1,
        });
        let r16 = p.runtime(&Config {
            instance: 0,
            nodes: 16,
            spark: 1,
        });
        assert!(r16 > r8, "16 nodes ({r16}) should be slower than 8 ({r8})");
    }

    #[test]
    fn all_jobs_show_diminishing_returns() {
        for kind in ALL_JOBS {
            let p = kind.profile();
            let r1 = p.runtime(&Config {
                instance: 0,
                nodes: 1,
                spark: 1,
            });
            let r2 = p.runtime(&Config {
                instance: 0,
                nodes: 2,
                spark: 1,
            });
            let r4 = p.runtime(&Config {
                instance: 0,
                nodes: 4,
                spark: 1,
            });
            let s2 = r1 / r2;
            let s4 = r1 / r4;
            assert!(s2 > 1.0, "{kind:?} should speed up 1->2");
            assert!(s4 < 4.0, "{kind:?} should be sublinear");
        }
    }

    #[test]
    fn job_names_unique() {
        let names: std::collections::BTreeSet<_> = ALL_JOBS.iter().map(|j| j.name()).collect();
        assert_eq!(names.len(), ALL_JOBS.len());
    }
}
