//! DAG workload model: tasks, precedence, topological/critical-path
//! analysis, and (de)serialization of DAG specs.

pub mod generator;
pub mod profile;
pub mod workloads;

use anyhow::{bail, Result};

pub use profile::TaskProfile;

use crate::util::Json;

/// One task (vertex) of a pipeline DAG.
#[derive(Debug, Clone)]
pub struct Task {
    /// Task name, unique within its DAG.
    pub name: String,
    /// Ground-truth scaling characteristics (hidden from the optimizer;
    /// observed only through event logs, like the real system).
    pub profile: TaskProfile,
}

/// A directed acyclic workflow graph.
#[derive(Debug, Clone)]
pub struct Dag {
    /// DAG (job) name.
    pub name: String,
    /// Tasks, indexed by position.
    pub tasks: Vec<Task>,
    /// Edges as (predecessor, successor) task-index pairs.
    pub edges: Vec<(usize, usize)>,
    preds: Vec<Vec<usize>>,
    succs: Vec<Vec<usize>>,
}

impl Dag {
    /// Build and validate a DAG. Errors on out-of-range edges, self-loops
    /// and cycles.
    pub fn new(name: &str, tasks: Vec<Task>, edges: Vec<(usize, usize)>) -> Result<Dag> {
        let n = tasks.len();
        let mut preds = vec![Vec::new(); n];
        let mut succs = vec![Vec::new(); n];
        for &(a, b) in &edges {
            if a >= n || b >= n {
                bail!("edge ({a}, {b}) out of range for {n} tasks");
            }
            if a == b {
                bail!("self-loop on task {a}");
            }
            succs[a].push(b);
            preds[b].push(a);
        }
        let dag = Dag {
            name: name.to_string(),
            tasks,
            edges,
            preds,
            succs,
        };
        // Cycle check via topo sort.
        dag.topo_order()?;
        Ok(dag)
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Whether the DAG has no tasks.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Direct predecessors of a task.
    pub fn preds(&self, task: usize) -> &[usize] {
        &self.preds[task]
    }

    /// Direct successors of a task.
    pub fn succs(&self, task: usize) -> &[usize] {
        &self.succs[task]
    }

    /// Kahn topological order; error if the graph has a cycle.
    pub fn topo_order(&self) -> Result<Vec<usize>> {
        let n = self.len();
        let mut indeg: Vec<usize> = (0..n).map(|i| self.preds[i].len()).collect();
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for &v in &self.succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if order.len() != n {
            bail!("cycle detected in DAG {:?}", self.name);
        }
        Ok(order)
    }

    /// Length of the longest path in task count (the DAG "depth").
    pub fn depth(&self) -> usize {
        let order = self.topo_order().expect("validated at construction");
        let mut d = vec![1usize; self.len()];
        for &u in &order {
            for &v in &self.succs[u] {
                d[v] = d[v].max(d[u] + 1);
            }
        }
        d.into_iter().max().unwrap_or(0)
    }

    /// Maximum antichain width estimate: max number of tasks at the same
    /// topological level.
    pub fn width(&self) -> usize {
        let order = self.topo_order().expect("validated at construction");
        let mut level = vec![0usize; self.len()];
        for &u in &order {
            for &v in &self.succs[u] {
                level[v] = level[v].max(level[u] + 1);
            }
        }
        let mut counts = std::collections::BTreeMap::new();
        for l in level {
            *counts.entry(l).or_insert(0usize) += 1;
        }
        counts.values().copied().max().unwrap_or(0)
    }

    /// Critical-path length under the given per-task durations; also the
    /// classic makespan lower bound used by the CP solver.
    pub fn critical_path(&self, durations: &[f64]) -> f64 {
        assert_eq!(durations.len(), self.len());
        let order = self.topo_order().expect("validated at construction");
        let mut finish = vec![0.0f64; self.len()];
        for &u in &order {
            let start = self
                .preds[u]
                .iter()
                .map(|&p| finish[p])
                .fold(0.0f64, f64::max);
            finish[u] = start + durations[u];
        }
        finish.into_iter().fold(0.0, f64::max)
    }

    /// Per-task "criticality": length of the longest path through the task,
    /// computed as top level + bottom level, where the top level is the
    /// longest path ending at the task's *start* (own duration excluded)
    /// and the bottom level is the longest path starting at the task (own
    /// duration included) — so the task's duration is counted exactly
    /// once. Used by CP-list baseline and by the solver's branching order.
    ///
    /// ```
    /// use agora::dag::{Dag, Task, TaskProfile};
    /// let task = |n: &str| Task {
    ///     name: n.to_string(),
    ///     profile: TaskProfile::example(),
    /// };
    /// // Diamond 0 -> {1, 2} -> 3 with durations [1, 5, 2, 1]: the
    /// // critical path 0 -> 1 -> 3 has length 7, so every task on it
    /// // scores 7 and the off-path task 2 scores 1 + 2 + 1 = 4.
    /// let d = Dag::new(
    ///     "diamond",
    ///     vec![task("a"), task("b"), task("c"), task("d")],
    ///     vec![(0, 1), (0, 2), (1, 3), (2, 3)],
    /// )
    /// .unwrap();
    /// assert_eq!(d.criticality(&[1.0, 5.0, 2.0, 1.0]), vec![7.0, 7.0, 4.0, 7.0]);
    /// ```
    pub fn criticality(&self, durations: &[f64]) -> Vec<f64> {
        let order = self.topo_order().expect("validated at construction");
        let n = self.len();
        let mut top = vec![0.0f64; n]; // longest path ending at start of u
        for &u in &order {
            for &v in &self.succs[u] {
                top[v] = top[v].max(top[u] + durations[u]);
            }
        }
        let mut bottom = vec![0.0f64; n]; // longest path from start of u
        for &u in order.iter().rev() {
            bottom[u] = durations[u]
                + self.succs[u]
                    .iter()
                    .map(|&v| bottom[v])
                    .fold(0.0f64, f64::max);
        }
        (0..n).map(|u| top[u] + bottom[u]).collect()
    }

    /// Transitive closure of the precedence relation as a boolean matrix
    /// (row r reaches column c). Used by schedule-invariant checks.
    ///
    /// Rows are packed into `u64` bitset words internally so each edge
    /// merges its successor's row with word-wise ORs (64 columns per
    /// operation, no per-edge row allocation), which keeps the closure
    /// cheap on 10k-task DAGs; the expanded `Vec<Vec<bool>>` form is
    /// materialized once at the end.
    pub fn reachability(&self) -> Vec<Vec<bool>> {
        let n = self.len();
        let order = self.topo_order().expect("validated at construction");
        // Flat n x words bit matrix: row u occupies words [u*w, (u+1)*w).
        // Walking tasks in reverse topological order means every
        // successor's row is final before it is OR-ed into a predecessor.
        let w = n.div_ceil(64);
        let mut bits = vec![0u64; n * w];
        for &u in order.iter().rev() {
            for &v in &self.succs[u] {
                bits[u * w + v / 64] |= 1u64 << (v % 64);
                for k in 0..w {
                    let word = bits[v * w + k];
                    bits[u * w + k] |= word;
                }
            }
        }
        (0..n)
            .map(|u| {
                (0..n)
                    .map(|c| (bits[u * w + c / 64] >> (c % 64)) & 1 == 1)
                    .collect()
            })
            .collect()
    }

    // -- JSON spec ----------------------------------------------------------

    /// Serialize to the on-disk DAG spec consumed by the CLI.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            (
                "tasks",
                Json::arr(self.tasks.iter().map(|t| {
                    Json::obj(vec![
                        ("name", Json::str(&t.name)),
                        ("profile", t.profile.to_json()),
                    ])
                })),
            ),
            (
                "edges",
                Json::arr(self.edges.iter().map(|&(a, b)| {
                    Json::arr(vec![Json::num(a as f64), Json::num(b as f64)])
                })),
            ),
        ])
    }

    /// Parse a DAG from its [`Dag::to_json`] spec form.
    pub fn from_json(v: &Json) -> Result<Dag> {
        let name = v.get("name")?.as_str()?;
        let tasks = v
            .get("tasks")?
            .as_arr()?
            .iter()
            .map(|t| {
                Ok(Task {
                    name: t.get("name")?.as_str()?.to_string(),
                    profile: TaskProfile::from_json(t.get("profile")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let edges = v
            .get("edges")?
            .as_arr()?
            .iter()
            .map(|e| {
                let pair = e.as_arr()?;
                if pair.len() != 2 {
                    bail!("edge must be a 2-array");
                }
                Ok((pair[0].as_usize()?, pair[1].as_usize()?))
            })
            .collect::<Result<Vec<_>>>()?;
        Dag::new(name, tasks, edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(name: &str) -> Task {
        Task {
            name: name.to_string(),
            profile: TaskProfile::example(),
        }
    }

    fn diamond() -> Dag {
        // 0 -> {1, 2} -> 3
        Dag::new(
            "diamond",
            vec![task("a"), task("b"), task("c"), task("d")],
            vec![(0, 1), (0, 2), (1, 3), (2, 3)],
        )
        .unwrap()
    }

    #[test]
    fn topo_order_respects_edges() {
        let d = diamond();
        let order = d.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; 4];
            for (i, &t) in order.iter().enumerate() {
                p[t] = i;
            }
            p
        };
        for &(a, b) in &d.edges {
            assert!(pos[a] < pos[b]);
        }
    }

    #[test]
    fn cycle_is_rejected() {
        let r = Dag::new(
            "cyc",
            vec![task("a"), task("b")],
            vec![(0, 1), (1, 0)],
        );
        assert!(r.is_err());
    }

    #[test]
    fn self_loop_rejected() {
        assert!(Dag::new("l", vec![task("a")], vec![(0, 0)]).is_err());
    }

    #[test]
    fn out_of_range_edge_rejected() {
        assert!(Dag::new("o", vec![task("a")], vec![(0, 3)]).is_err());
    }

    #[test]
    fn critical_path_of_diamond() {
        let d = diamond();
        // durations: a=1, b=5, c=2, d=1 -> cp = 1+5+1 = 7
        assert_eq!(d.critical_path(&[1.0, 5.0, 2.0, 1.0]), 7.0);
    }

    #[test]
    fn depth_and_width() {
        let d = diamond();
        assert_eq!(d.depth(), 3);
        assert_eq!(d.width(), 2);
    }

    #[test]
    fn criticality_peaks_on_critical_path() {
        let d = diamond();
        let cr = d.criticality(&[1.0, 5.0, 2.0, 1.0]);
        assert_eq!(cr[1], 7.0); // b is on the critical path
        assert_eq!(cr[2], 4.0);
        assert_eq!(cr[0], 7.0);
    }

    #[test]
    fn reachability_transitive() {
        let d = diamond();
        let r = d.reachability();
        assert!(r[0][3]);
        assert!(r[0][1] && r[1][3]);
        assert!(!r[1][2]);
        assert!(!r[3][0]);
    }

    /// The pre-bitset `reachability` implementation (successor row cloned
    /// per edge), kept verbatim as the behavioural reference for the
    /// word-wise rewrite.
    fn reference_reachability(d: &Dag) -> Vec<Vec<bool>> {
        let n = d.len();
        let order = d.topo_order().expect("validated at construction");
        let mut reach = vec![vec![false; n]; n];
        for &u in order.iter().rev() {
            for &v in &d.succs[u] {
                reach[u][v] = true;
                let row = reach[v].clone();
                for (w, r) in row.into_iter().enumerate() {
                    if r {
                        reach[u][w] = true;
                    }
                }
            }
        }
        reach
    }

    #[test]
    fn reachability_matches_row_clone_reference_on_random_dags() {
        let mut rng = crate::util::Rng::new(0xB175E7);
        for _ in 0..60 {
            // Sizes straddle the 64-column word boundary so multi-word
            // rows and the final partial word both get exercised.
            let d = generator::arbitrary_dag(&mut rng, 90);
            assert_eq!(d.reachability(), reference_reachability(&d));
        }
    }

    #[test]
    fn reachability_empty_and_singleton() {
        let empty = Dag::new("e", vec![], vec![]).unwrap();
        assert!(empty.reachability().is_empty());
        let one = Dag::new("s", vec![task("a")], vec![]).unwrap();
        assert_eq!(one.reachability(), vec![vec![false]]);
    }

    #[test]
    fn json_roundtrip() {
        let d = diamond();
        let j = d.to_json();
        let d2 = Dag::from_json(&j).unwrap();
        assert_eq!(d2.name, d.name);
        assert_eq!(d2.len(), d.len());
        assert_eq!(d2.edges, d.edges);
        let j2 = d2.to_json();
        assert_eq!(j.to_string(), j2.to_string());
    }
}
