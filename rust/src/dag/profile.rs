//! Ground-truth task scaling characteristics.
//!
//! A `TaskProfile` is what the *simulated world* knows about a task; the
//! optimizer never reads it directly. It observes runtimes through event
//! logs (predictor/eventlog.rs) exactly as AGORA observes Spark history,
//! so predictor error is a first-class part of every experiment.
//!
//! The runtime law combines the Universal Scalability Law (paper Eq. 9)
//! with instance-granularity, Spark-preset and memory-pressure effects:
//!
//!   runtime(cfg) = work * usl_penalty(n_eff; alpha, beta)
//!                  / (spark_eff(cfg) * mem_eff(cfg) * speed(cfg))
//!
//! where n_eff is the configuration's m5.4xlarge-equivalent node count.

use anyhow::Result;

use crate::cluster::Config;
use crate::util::Json;

/// USL runtime penalty relative to n = 1 (mirrors python kernels/ref.py).
pub fn usl_penalty(n: f64, alpha: f64, beta: f64) -> f64 {
    let n = n.max(1.0);
    (1.0 + alpha * (n - 1.0) + beta * n * (n - 1.0)) / n
}

/// Ground truth for one task.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskProfile {
    /// Ideal runtime at n_eff = 1 (seconds on one m5.4xlarge).
    pub work: f64,
    /// USL contention parameter (serialization / queueing).
    pub alpha: f64,
    /// USL coherency parameter (crosstalk; > 0 gives negative scaling).
    pub beta: f64,
    /// Working-set size in GiB; if usable memory under a config is below
    /// this the task spills and slows down.
    pub mem_gb: f64,
    /// Spark executor-shape affinity in [-1, 1]: -1 prefers fat executors
    /// (shuffle-heavy), +1 prefers thin (embarrassingly parallel).
    pub spark_affinity: f64,
    /// Run-to-run noise (lognormal sigma) applied by the simulator.
    pub noise_sigma: f64,
}

impl TaskProfile {
    /// Deterministic ground-truth runtime (noise excluded — the simulator
    /// adds it per run).
    pub fn runtime(&self, cfg: &Config) -> f64 {
        let n_eff = cfg.n_eff();
        let base = self.work * usl_penalty(n_eff, self.alpha, self.beta);
        let eff = self.spark_eff(cfg) * self.mem_eff(cfg) * cfg.instance_type().speed_factor;
        (base / eff.max(1e-3)).max(1.0)
    }

    /// Spark preset efficiency: 1.0 at perfect affinity match, down to
    /// ~0.64 at the worst mismatch (fat executors on an embarrassingly
    /// parallel job, or thin executors on a shuffle-heavy one) — the
    /// magnitude practitioners report for executor-shape tuning and the
    /// reason the paper treats Spark parameters as first-class decision
    /// variables.
    pub fn spark_eff(&self, cfg: &Config) -> f64 {
        let bias = cfg.spark_params().parallel_bias;
        1.0 - 0.18 * (self.spark_affinity - bias).abs()
    }

    /// Memory-pressure efficiency: 1.0 when usable memory covers the
    /// working set, degrading towards 0.55 under heavy spill.
    pub fn mem_eff(&self, cfg: &Config) -> f64 {
        let usable = cfg.memory_gb() * cfg.spark_params().memory_fraction;
        if usable >= self.mem_gb {
            1.0
        } else {
            let ratio = (usable / self.mem_gb).max(0.1);
            0.55 + 0.45 * ratio
        }
    }

    /// A generic mid-sized profile for tests and docs.
    pub fn example() -> TaskProfile {
        TaskProfile {
            work: 1200.0,
            alpha: 0.08,
            beta: 0.004,
            mem_gb: 96.0,
            spark_affinity: 0.0,
            noise_sigma: 0.03,
        }
    }

    /// Serialize to the on-disk profile spec.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("work", Json::num(self.work)),
            ("alpha", Json::num(self.alpha)),
            ("beta", Json::num(self.beta)),
            ("mem_gb", Json::num(self.mem_gb)),
            ("spark_affinity", Json::num(self.spark_affinity)),
            ("noise_sigma", Json::num(self.noise_sigma)),
        ])
    }

    /// Parse a profile from its [`TaskProfile::to_json`] form.
    pub fn from_json(v: &Json) -> Result<TaskProfile> {
        Ok(TaskProfile {
            work: v.get("work")?.as_f64()?,
            alpha: v.get("alpha")?.as_f64()?,
            beta: v.get("beta")?.as_f64()?,
            mem_gb: v.get("mem_gb")?.as_f64()?,
            spark_affinity: v.get("spark_affinity")?.as_f64()?,
            noise_sigma: v.get("noise_sigma")?.as_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Config;

    fn cfg(instance: usize, nodes: u32, spark: usize) -> Config {
        Config {
            instance,
            nodes,
            spark,
        }
    }

    #[test]
    fn usl_penalty_at_one_is_one() {
        assert_eq!(usl_penalty(1.0, 0.3, 0.1), 1.0);
    }

    #[test]
    fn usl_negative_scaling_when_beta_positive() {
        // With beta > 0 runtime eventually grows with n.
        let p = |n: f64| usl_penalty(n, 0.05, 0.02);
        assert!(p(4.0) < p(1.0));
        assert!(p(64.0) > p(8.0));
    }

    #[test]
    fn runtime_diminishing_returns() {
        let prof = TaskProfile::example();
        let r1 = prof.runtime(&cfg(0, 1, 1));
        let r2 = prof.runtime(&cfg(0, 2, 1));
        let r16 = prof.runtime(&cfg(0, 16, 1));
        assert!(r2 < r1);
        assert!(r16 < r2);
        // speedup(16) far below 16x (diminishing returns, paper Fig. 2)
        assert!(r1 / r16 < 12.0);
    }

    #[test]
    fn bigger_instances_beat_more_nodes_at_equal_vcpus() {
        // 4 x m5.4xlarge vs 1 x m5.16xlarge: same vCPUs, same n_eff, but
        // the USL penalty applies to n_eff in both cases — equal here by
        // construction; memory pressure breaks the tie if mem_gb demands.
        let prof = TaskProfile::example();
        let small_nodes = prof.runtime(&cfg(0, 4, 1));
        let one_big = prof.runtime(&cfg(3, 1, 1));
        assert!((small_nodes - one_big).abs() < 1e-9);
    }

    #[test]
    fn memory_pressure_slows_down() {
        let mut prof = TaskProfile::example();
        prof.mem_gb = 200.0;
        let tight = prof.runtime(&cfg(0, 1, 1)); // 64 GB node, 200 GB set
        prof.mem_gb = 10.0;
        let roomy = prof.runtime(&cfg(0, 1, 1));
        assert!(tight > roomy);
    }

    #[test]
    fn spark_affinity_changes_preset_ranking() {
        let mut prof = TaskProfile::example();
        prof.spark_affinity = -1.0; // shuffle-heavy: fat executors win
        let fat = prof.runtime(&cfg(0, 4, 0));
        let thin = prof.runtime(&cfg(0, 4, 2));
        assert!(fat < thin);
        prof.spark_affinity = 1.0;
        let fat = prof.runtime(&cfg(0, 4, 0));
        let thin = prof.runtime(&cfg(0, 4, 2));
        assert!(thin < fat);
    }

    #[test]
    fn runtime_never_below_one_second() {
        let prof = TaskProfile {
            work: 0.01,
            ..TaskProfile::example()
        };
        assert!(prof.runtime(&cfg(3, 16, 1)) >= 1.0);
    }

    #[test]
    fn json_roundtrip() {
        let p = TaskProfile::example();
        let p2 = TaskProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(p, p2);
    }
}
