//! `agora` — the launcher binary.
//!
//! Subcommands:
//!   optimize   co-optimize DAG(s) and print the plan + Gantt chart
//!   execute    optimize then execute on the simulated cluster
//!   serve      run the multi-tenant service demo (threaded;
//!              --admission rounds|continuous)
//!   trace      macro-benchmark an Alibaba-like trace (AGORA vs Airflow,
//!              plus the round-barrier vs continuous admission columns)
//!   catalog    print the instance catalog (Table 1) and config space
//!   artifacts  verify the AOT artifacts load + run through PJRT
//!
//! DAG inputs: built-ins `fig1`, `dag1`, `dag2`, or a JSON spec path
//! (see `Dag::from_json`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use agora::cluster::ConfigSpace;
use agora::config::AppConfig;
use agora::coordinator::{Admission, AdmissionStats, BatchRunner, MacroSummary, Strategy};
use agora::dag::generator::large_scale_dag;
use agora::dag::workloads;
use agora::predictor::{bootstrap_history, default_profiling_configs, EventLog};
use agora::runtime::{Engine, PjrtPredictor};
use agora::solver::{Agora, AgoraOptions};
use agora::trace::{generate, TraceParams, TracedJob};
use agora::util::{fmt_cost, fmt_duration, Args, Json, Rng};
use agora::{Dag, LearnedPredictor, Predictor};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(AppConfig::FLAGS)?;
    let config = AppConfig::resolve(&args)?;
    if !config.market && config.replan.divergence.spot_rate > 0.0 {
        eprintln!(
            "warning: --spot-rate has no effect without --market \
             (the m5-only space sells no spot capacity)"
        );
    }
    match args.subcommand.as_deref() {
        Some("optimize") => cmd_optimize(&args, &config, false),
        Some("execute") => cmd_optimize(&args, &config, true),
        Some("serve") => cmd_serve(&config),
        Some("trace") => cmd_trace(&config),
        Some("catalog") => cmd_catalog(),
        Some("artifacts") => cmd_artifacts(&config),
        Some(other) => bail!("unknown subcommand {other:?}\n{}", usage()),
        None => {
            println!("{}", usage());
            Ok(())
        }
    }
}

fn usage() -> String {
    format!(
        "usage: agora <optimize|execute|serve|trace|catalog|artifacts> [dags...] [flags]\n{}",
        Args::usage_for(AppConfig::FLAGS)
    )
}

/// Resolve a DAG argument: built-in name or JSON file path.
fn load_dag(name: &str) -> Result<Dag> {
    match name {
        "fig1" => Ok(workloads::fig1_dag()),
        "dag1" => Ok(workloads::dag1()),
        "dag2" => Ok(workloads::dag2()),
        path => {
            let v = Json::parse_file(Path::new(path))
                .with_context(|| format!("loading DAG spec {path}"))?;
            Dag::from_json(&v)
        }
    }
}

fn cmd_optimize(args: &Args, config: &AppConfig, execute: bool) -> Result<()> {
    let names: Vec<String> = if args.positional.is_empty() {
        vec!["dag1".to_string()]
    } else {
        args.positional.clone()
    };
    let dags: Vec<Dag> = names.iter().map(|n| load_dag(n)).collect::<Result<_>>()?;
    let releases = vec![0.0; dags.len()];
    // --market swaps in the heterogeneous instance space + market
    // pricing (spot rows priced with the --spot-rate expectation).
    let space = config.space();
    let cost_model = config.cost_model();
    let mut rng = Rng::new(config.seed);

    // Histories: one bootstrap profiling set per task (the paper's
    // "triggered test run" when no prior log exists); market runs add
    // one anchor run per alternate family so cross-family extrapolation
    // is grounded.
    let profiling = agora::predictor::profiling_configs_for(&space);
    let logs: Vec<EventLog> = dags
        .iter()
        .flat_map(|d| {
            d.tasks
                .iter()
                .map(|t| bootstrap_history(&t.name, &t.profile, &profiling, &mut rng))
                .collect::<Vec<_>>()
        })
        .collect();

    // Predictor: PJRT path (AOT kernel) or host path.
    let grid = if config.use_pjrt {
        let engine = Engine::new(&config.artifacts_dir)?;
        println!("predictor: PJRT ({})", engine.platform());
        let (grid, _fits) = PjrtPredictor::new(&engine).fit_predict(&logs, &space)?;
        grid
    } else {
        LearnedPredictor::fit(&logs).predict(&space)
    };

    let p = Agora::build_problem_with_grid(
        &dags,
        &releases,
        grid,
        config.capacity,
        space,
        cost_model.clone(),
    );
    let agora = Agora::new(AgoraOptions {
        goal: config.goal,
        mode: config.mode,
        params: config.anneal.clone(),
        makespan_budget: config.makespan_budget,
        cost_budget: config.cost_budget,
        seed: config.seed,
        parallelism: config.parallelism,
    });
    let plan = agora.optimize(&p);

    println!(
        "plan [{} | goal={} | chains={}]: predicted makespan {}  cost {}  (optimizer overhead {:?})",
        config.mode.name(),
        config.goal.name(),
        config.parallelism,
        fmt_duration(plan.makespan),
        fmt_cost(plan.cost),
        plan.overhead
    );
    if let Some(a) = &plan.anneal {
        println!(
            "annealing: {} iterations, {} accepted, {} improvements, {} CP nodes",
            a.stats.iterations, a.stats.accepted, a.stats.improved, a.stats.inner_nodes
        );
        println!(
            "adaptive:  {} evaluations, {} restarts{}",
            a.stats.evaluations,
            a.stats.restarts,
            match a.stats.calibrated_t0 {
                Some(t0) => format!(", calibrated T0 {t0:.5}"),
                None => String::new(),
            }
        );
        if config.anneal.troublesome_seed {
            let host = if config.parallelism > 1 {
                "chain 1"
            } else {
                "the single chain"
            };
            println!("seeding:   DAGPS troublesome-first reseed active on {host}");
        }
    }
    println!("\n{}", plan.schedule.render(&p));

    if execute {
        let report = agora::sim::execute_with_policy(
            &p,
            &dags,
            &plan.schedule,
            &cost_model,
            &mut rng,
            &config.replan,
        );
        println!(
            "executed: actual makespan {}  cost {}  prediction MAPE {:.1}%",
            fmt_duration(report.makespan),
            fmt_cost(report.cost),
            report.prediction_mape * 100.0
        );
        let preempted: u32 = report.records.iter().map(|r| r.preemptions).sum();
        if preempted > 0 {
            println!("spot preemptions: {preempted} (lost in-flight work re-run)");
        }
        for r in &report.replans {
            println!(
                "replan {}: trigger {} at {} (divergence {:.0}%)  cone {} task(s), {} reassigned  projected {} -> {}",
                r.round,
                p.tasks[r.trigger_task].name,
                fmt_duration(r.at),
                r.divergence * 100.0,
                r.replanned,
                r.reassigned,
                fmt_duration(r.stale_makespan),
                fmt_duration(r.planned_makespan),
            );
        }
    }
    Ok(())
}

fn cmd_serve(config: &AppConfig) -> Result<()> {
    use agora::coordinator::service::{Service, ServiceConfig};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    println!("starting multi-tenant service (demo: three tenants submit DAGs)...");
    let service = Service::start(ServiceConfig {
        capacity: config.capacity,
        goal: config.goal,
        seed: config.seed,
        parallelism: config.parallelism,
        replan: config.replan.clone(),
        admission: config.admission,
        space: config.space(),
        cost_model: config.cost_model(),
        workers: config.workers,
        queue_bound: config.queue_bound,
        sla: config.sla(),
        ..Default::default()
    });
    let handle = service.handle();

    // --status-interval <ms>: a ticker thread printing live control-plane
    // snapshots (queue depths, in-flight rounds, latency digests) while
    // the demo submissions drain.
    let stop = Arc::new(AtomicBool::new(false));
    let ticker = if config.status_interval_ms > 0 {
        let h = handle.clone();
        let stop = stop.clone();
        let period = std::time::Duration::from_millis(config.status_interval_ms);
        Some(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(period);
                println!("{}", h.status().render());
            }
        }))
    } else {
        None
    };

    let tickets = vec![
        handle.submit("alice", workloads::dag1())?,
        handle.submit("bob", workloads::dag2())?,
        handle.submit("carol", workloads::fig1_dag())?,
    ];
    for ticket in tickets {
        let tenant = ticket.tenant().to_string();
        let r = ticket
            .recv_timeout(std::time::Duration::from_secs(120))
            .with_context(|| format!("waiting for {tenant}"))?;
        println!(
            "tenant {:<6} dag {:<5} round {}: completion {}  cost {}",
            r.tenant,
            r.dag_name,
            r.round,
            fmt_duration(r.completion),
            fmt_cost(r.cost)
        );
    }

    stop.store(true, Ordering::Relaxed);
    if let Some(t) = ticker {
        let _ = t.join();
    }
    println!("{}", handle.status().render());
    let rounds = service.shutdown()?;
    println!("service stopped after {rounds} round(s)");
    Ok(())
}

fn cmd_trace(config: &AppConfig) -> Result<()> {
    let params = TraceParams {
        jobs: 40,
        ..TraceParams::default()
    };
    let mut rng = Rng::new(config.seed);
    let mut jobs = generate(&params, &mut rng);
    // Optional large-scale jobs (--trace-large): ~1000-task wide-fan-out
    // + deep-chain DAGs spread over the submission window, exercising
    // the timeline kernel at the scale benches/scaling_timeline.rs
    // sweeps.
    if config.trace_large > 0 {
        for i in 0..config.trace_large {
            let dag = large_scale_dag(&mut rng, &format!("large{i}"), 1000);
            let submit_time = params.window * (i as f64 + 0.5) / config.trace_large as f64;
            jobs.push(TracedJob { dag, submit_time });
        }
        jobs.sort_by(|a, b| a.submit_time.total_cmp(&b.submit_time));
    }
    println!(
        "trace: {} DAG jobs over {} ({} large-scale), batch capacity {:.0} cores / {:.0} GiB",
        jobs.len(),
        fmt_duration(params.window),
        config.trace_large,
        params.batch_capacity().vcpus,
        params.batch_capacity().memory_gb
    );

    let mut base_runner = BatchRunner::new(
        params.batch_capacity(),
        config.space(),
        Strategy::Airflow,
        config.seed,
    )
    .with_cost_model(config.cost_model())
    .with_replan(config.replan.clone())
    .with_admission(config.admission)
    .with_sla(config.sla());
    let base = base_runner.run(&jobs)?;
    let mut agora_runner = BatchRunner::new(
        params.batch_capacity(),
        config.space(),
        Strategy::Agora(config.goal),
        config.seed,
    )
    .with_cost_model(config.cost_model())
    .with_parallelism(config.parallelism)
    .with_replan(config.replan.clone())
    .with_admission(config.admission)
    .with_sla(config.sla());
    let run = agora_runner.run(&jobs)?;
    let summary = MacroSummary::against(&base, &run);
    println!(
        "admission: {} (switch with --admission rounds|continuous)",
        config.admission.name()
    );
    println!(
        "airflow : cost {}  total completion {}",
        fmt_cost(base.total_cost),
        fmt_duration(base.total_completion)
    );
    println!(
        "agora   : cost {} ({:.0}% of baseline)  total completion {} ({:.0}%)",
        fmt_cost(run.total_cost),
        summary.normalized_cost * 100.0,
        fmt_duration(run.total_completion),
        summary.normalized_completion * 100.0
    );
    println!(
        "{:.0}% of DAGs improved; {:.0}% improved by >=95%; optimizer overhead {:?} over {} rounds",
        summary.improved_fraction * 100.0,
        summary.near_total_fraction * 100.0,
        run.optimizer_overhead,
        run.rounds
    );
    if !config.replan.is_off() {
        println!(
            "mid-flight replans: airflow {}  agora {}",
            base.replans, run.replans
        );
    }
    if base.preemptions + run.preemptions > 0 {
        println!(
            "spot preemptions: airflow {}  agora {}",
            base.preemptions, run.preemptions
        );
    }
    if config.deadline_frac > 0.0 {
        for (name, r) in [("airflow", &base), ("agora", &run)] {
            println!(
                "SLA ({name}): {} met, {} missed, {} rejected, penalty {}",
                r.sla_met,
                r.sla_missed,
                r.rejected,
                fmt_cost(r.penalty_cost)
            );
        }
    }

    // Round-barrier vs continuous admission at equal cost budget: the
    // same strategy and seed draw the same runtimes in both modes, so
    // the completion/utilization columns isolate the admission effect.
    // Measured on the admission-stress slice (multi-slot capacity +
    // compressed arrivals) where triggered rounds genuinely overlap.
    let stress = TraceParams::admission_stress(params.jobs);
    let stress_jobs = generate(&stress, &mut Rng::new(config.seed));
    println!(
        "\n-- admission: round-barrier vs continuous (airflow configs, equal cost; {} DAGs over {}) --",
        stress_jobs.len(),
        fmt_duration(stress.window)
    );
    println!(
        "{:<11} {:>10} {:>10} {:>10} {:>6} {:>10}",
        "mode", "mean", "p95", "queue", "util", "cost"
    );
    for admission in [Admission::Rounds, Admission::Continuous] {
        let mut runner = BatchRunner::new(
            stress.batch_capacity(),
            ConfigSpace::standard(),
            Strategy::Airflow,
            config.seed,
        )
        .with_admission(admission);
        let s = AdmissionStats::of(&runner.run(&stress_jobs)?);
        let row = s.row();
        println!(
            "{:<11} {:>10} {:>10} {:>10} {:>6} {:>10}",
            row[0], row[1], row[2], row[3], row[4], row[5]
        );
    }
    Ok(())
}

fn cmd_catalog() -> Result<()> {
    print!("{}", agora::cluster::catalog::table1());
    let space = ConfigSpace::standard();
    println!(
        "\nconfig space: {} candidates ({} instance types x {} node counts x {} Spark presets)",
        space.len(),
        agora::cluster::catalog::M5_CATALOG.len(),
        agora::cluster::config::NODE_LADDER.len(),
        agora::cluster::config::SPARK_PRESETS.len()
    );
    println!();
    print!("{}", agora::cluster::catalog::market_table());
    let market = ConfigSpace::market();
    println!(
        "\nmarket space (--market): {} candidates ({} catalog rows x {} node counts x {} Spark presets)",
        market.len(),
        agora::cluster::catalog::FULL_CATALOG.len(),
        agora::cluster::config::NODE_LADDER.len(),
        agora::cluster::config::SPARK_PRESETS.len()
    );
    Ok(())
}

fn cmd_artifacts(config: &AppConfig) -> Result<()> {
    let engine = Engine::new(&config.artifacts_dir)?;
    println!(
        "artifacts: {} entries from {} (platform {})",
        engine.manifest.entries.len(),
        config.artifacts_dir.display(),
        engine.platform()
    );
    // Smoke-run the small predict artifact against the host oracle.
    let space = ConfigSpace::standard();
    let mut rng = Rng::new(1);
    let logs: Vec<EventLog> = workloads::ALL_JOBS
        .iter()
        .map(|j| bootstrap_history(j.name(), &j.profile(), &default_profiling_configs(), &mut rng))
        .collect();
    let host = LearnedPredictor::fit(&logs);
    let host_grid = host.predict(&space);
    let pjrt = PjrtPredictor::new(&engine);
    let pjrt_grid = pjrt.predict_fitted(&host.fits, &space)?;
    let mut max_rel = 0.0f64;
    for t in 0..host_grid.tasks() {
        for c in 0..space.len() {
            let h = host_grid.get(t, c);
            let x = pjrt_grid.get(t, c);
            max_rel = max_rel.max((h - x).abs() / h.max(1e-9));
        }
    }
    println!("PJRT vs host predictor: max relative deviation {max_rel:.2e}");
    if max_rel > 1e-4 {
        bail!("PJRT and host predictor disagree (> 1e-4)");
    }
    println!("artifacts OK");
    Ok(())
}
