//! Spark-style event logs — the Predictor's only view of the world.
//!
//! The real AGORA reads Spark history-server event logs; our simulated
//! substrate produces the same information: per-run records of the
//! configuration used and the observed runtime, plus a stage breakdown
//! (read / compute / shuffle / write) whose proportions follow the task's
//! ground-truth profile. The optimizer never touches `TaskProfile`
//! directly — prediction error is real in every experiment.

use anyhow::{bail, Context, Result};

use crate::cluster::Config;
use crate::dag::TaskProfile;
use crate::util::{Json, Rng};

/// One observed execution of a task.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// Configuration the run executed under.
    pub config: Config,
    /// Observed wall-clock runtime in seconds (includes run noise).
    pub runtime: f64,
    /// Stage breakdown (seconds); sums to ~runtime.
    pub stages: Vec<(String, f64)>,
}

/// Event-log history for one task, newest last.
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    /// Scoped task name (see [`scoped_task_name`]).
    pub task: String,
    /// Observed runs, newest last.
    pub runs: Vec<RunRecord>,
}

impl EventLog {
    /// Empty history for a task.
    pub fn new(task: &str) -> Self {
        EventLog {
            task: task.to_string(),
            runs: Vec::new(),
        }
    }

    /// Append one observed run.
    pub fn record(&mut self, config: Config, runtime: f64, stages: Vec<(String, f64)>) {
        self.runs.push(RunRecord {
            config,
            runtime,
            stages,
        });
    }

    /// Number of recorded runs.
    pub fn len(&self) -> usize {
        self.runs.len()
    }

    /// Whether the history has no runs.
    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    /// Serialize for history export (see [`EventLog::from_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task", Json::str(&self.task)),
            (
                "runs",
                Json::arr(self.runs.iter().map(|r| {
                    Json::obj(vec![
                        ("instance", Json::num(r.config.instance as f64)),
                        ("nodes", Json::num(r.config.nodes as f64)),
                        ("spark", Json::num(r.config.spark as f64)),
                        ("runtime", Json::num(r.runtime)),
                        (
                            "stages",
                            Json::arr(r.stages.iter().map(|(name, secs)| {
                                Json::arr(vec![Json::str(name), Json::num(*secs)])
                            })),
                        ),
                    ])
                })),
            ),
        ])
    }

    /// Parse an event log from its [`EventLog::to_json`] form. Event logs
    /// cross the process boundary (history import, replayed experiments),
    /// so this is untrusted input: every field access is checked and
    /// errors carry the task/run they occurred in instead of panicking.
    pub fn from_json(v: &Json) -> Result<EventLog> {
        let task = v
            .get("task")
            .and_then(|t| t.as_str())
            .context("event log task name")?
            .to_string();
        let runs_json = v
            .get("runs")
            .and_then(|r| r.as_arr())
            .with_context(|| format!("runs of task {task:?}"))?;
        let mut runs = Vec::with_capacity(runs_json.len());
        for (i, r) in runs_json.iter().enumerate() {
            let ctx = || format!("run {i} of task {task:?}");
            let index_field = |key: &str| -> Result<usize> {
                r.get(key).and_then(|x| x.as_usize()).with_context(ctx)
            };
            // Range-check the config against the catalog: a config that
            // parses but indexes out of range would panic at first use.
            // Indexed against the full market catalog (m5 rows first, so
            // historical logs keep their meaning).
            let instance = index_field("instance")?;
            if instance >= crate::cluster::FULL_CATALOG.len() {
                bail!("instance index {instance} out of range in {}", ctx());
            }
            let nodes = index_field("nodes")?;
            if nodes == 0 || nodes > 4096 {
                bail!("invalid node count {nodes} in {}", ctx());
            }
            let spark = index_field("spark")?;
            if spark >= crate::cluster::SPARK_PRESETS.len() {
                bail!("spark preset index {spark} out of range in {}", ctx());
            }
            let config = Config {
                instance,
                nodes: nodes as u32,
                spark,
            };
            let runtime = r.get("runtime").and_then(|x| x.as_f64()).with_context(ctx)?;
            if !runtime.is_finite() || runtime < 0.0 {
                bail!("invalid runtime {runtime} in {}", ctx());
            }
            let mut stages = Vec::new();
            for s in r.get("stages").and_then(|s| s.as_arr()).with_context(ctx)? {
                let pair = s.as_arr().with_context(ctx)?;
                if pair.len() != 2 {
                    bail!("stage entry must be [name, seconds] in {}", ctx());
                }
                stages.push((
                    pair[0].as_str().with_context(ctx)?.to_string(),
                    pair[1].as_f64().with_context(ctx)?,
                ));
            }
            runs.push(RunRecord {
                config,
                runtime,
                stages,
            });
        }
        Ok(EventLog { task, runs })
    }
}

/// Canonical fully qualified task name — the single key scheme for the
/// coordinator's event-log database and for flat tasks in
/// [`Problem`](crate::solver::Problem). Bootstrap histories and realized
/// run write-backs both address `"{dag}/{task}"`; a bare task name must
/// never be used as a database key (task names are only unique within one
/// DAG, and a key mismatch silently starves the
/// [`LearnedPredictor`](crate::predictor::LearnedPredictor) of executed
/// rounds).
pub fn scoped_task_name(dag: &str, task: &str) -> String {
    format!("{dag}/{task}")
}

/// Simulate one run of a task under a configuration and log it.
/// Runtime = ground truth x lognormal(0, noise_sigma) noise.
pub fn simulate_run(
    profile: &TaskProfile,
    config: Config,
    rng: &mut Rng,
) -> (f64, Vec<(String, f64)>) {
    let truth = profile.runtime(&config);
    let noise = rng.lognormal(0.0, profile.noise_sigma);
    let runtime = (truth * noise).max(1.0);

    // Stage split: IO-ish tasks (positive spark_affinity) spend more time
    // reading/writing; shuffle-heavy (negative affinity) more in shuffle.
    let io_frac = 0.15 + 0.10 * profile.spark_affinity.max(0.0);
    let shuffle_frac = 0.10 + 0.20 * (-profile.spark_affinity).max(0.0);
    let compute_frac = (1.0 - io_frac - shuffle_frac).max(0.1);
    let stages = vec![
        ("read".to_string(), runtime * io_frac * 0.6),
        ("compute".to_string(), runtime * compute_frac),
        ("shuffle".to_string(), runtime * shuffle_frac),
        ("write".to_string(), runtime * io_frac * 0.4),
    ];
    (runtime, stages)
}

/// Produce the "one prior run" history the paper assumes users provide
/// (a single run at a default configuration), optionally plus a few
/// Ernest-style profiling runs at small scales.
pub fn bootstrap_history(
    task: &str,
    profile: &TaskProfile,
    profiling_runs: &[Config],
    rng: &mut Rng,
) -> EventLog {
    let mut log = EventLog::new(task);
    for &cfg in profiling_runs {
        let (runtime, stages) = simulate_run(profile, cfg, rng);
        log.record(cfg, runtime, stages);
    }
    log
}

/// Default profiling configs: Ernest-style sampling — small scales on
/// the smallest instance plus one mid-scale anchor and one alternate
/// instance type, so extrapolation to the full ladder is grounded
/// (Ernest's "few training runs at small scales" methodology).
pub fn default_profiling_configs() -> Vec<Config> {
    vec![
        Config { instance: 0, nodes: 1, spark: 1 },
        Config { instance: 0, nodes: 2, spark: 1 },
        Config { instance: 0, nodes: 4, spark: 1 },
        Config { instance: 0, nodes: 8, spark: 1 },
        Config { instance: 1, nodes: 4, spark: 1 },
        // Spark-preset variation: without it the preset axis of the
        // model is unidentified and the optimizer chases spurious minima
        // (AGORA "tunes Spark configurations based on the
        // characteristics from historical log" — it needs that signal).
        Config { instance: 0, nodes: 4, spark: 0 },
        Config { instance: 0, nodes: 4, spark: 2 },
    ]
}

/// Market profiling configs: the [`default_profiling_configs`] set plus
/// one balanced anchor run on each alternate family (c5, r5), so the
/// per-family multipliers of the [`LearnedPredictor`] are identified
/// before the optimizer is allowed to extrapolate across families.
/// Kept separate from the default set so m5-only experiments keep their
/// historical seeded RNG streams bit-for-bit.
///
/// [`LearnedPredictor`]: crate::predictor::LearnedPredictor
pub fn market_profiling_configs() -> Vec<Config> {
    let mut configs = default_profiling_configs();
    let c5 = crate::cluster::catalog::index_by_name("c5.4xlarge")
        .expect("c5.4xlarge is in the market catalog");
    let r5 = crate::cluster::catalog::index_by_name("r5.4xlarge")
        .expect("r5.4xlarge is in the market catalog");
    configs.push(Config { instance: c5, nodes: 4, spark: 1 });
    configs.push(Config { instance: r5, nodes: 4, spark: 1 });
    configs
}

/// The profiling bootstrap appropriate for a candidate space: the
/// m5-only Ernest set for m5-only spaces (bit-identical to the
/// historical coordinator), [`market_profiling_configs`] when the space
/// spans alternate families — so every front-end (CLI, `BatchRunner`,
/// `Service`) grounds cross-family extrapolation before optimizing over
/// it.
pub fn profiling_configs_for(space: &crate::cluster::ConfigSpace) -> Vec<Config> {
    if space.instance_count() > crate::cluster::M5_CATALOG.len() {
        market_profiling_configs()
    } else {
        default_profiling_configs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_runs_are_near_truth() {
        let profile = TaskProfile::example();
        let cfg = Config {
            instance: 0,
            nodes: 4,
            spark: 1,
        };
        let truth = profile.runtime(&cfg);
        let mut rng = Rng::new(1);
        let mut total = 0.0;
        let n = 200;
        for _ in 0..n {
            let (rt, _) = simulate_run(&profile, cfg, &mut rng);
            total += rt;
        }
        let mean = total / n as f64;
        assert!(
            (mean - truth).abs() / truth < 0.05,
            "mean {mean} vs truth {truth}"
        );
    }

    #[test]
    fn stages_sum_to_runtime() {
        let profile = TaskProfile::example();
        let cfg = Config {
            instance: 1,
            nodes: 2,
            spark: 0,
        };
        let mut rng = Rng::new(2);
        let (rt, stages) = simulate_run(&profile, cfg, &mut rng);
        let sum: f64 = stages.iter().map(|(_, s)| s).sum();
        assert!((sum - rt).abs() / rt < 0.2, "stages {sum} vs runtime {rt}");
    }

    #[test]
    fn bootstrap_produces_one_run_per_config() {
        let mut rng = Rng::new(3);
        let log = bootstrap_history(
            "t",
            &TaskProfile::example(),
            &default_profiling_configs(),
            &mut rng,
        );
        assert_eq!(log.len(), default_profiling_configs().len());
        assert!(log.runs.iter().all(|r| r.runtime > 0.0));
    }

    #[test]
    fn eventlog_json_round_trips() -> Result<()> {
        let mut rng = Rng::new(4);
        let log = bootstrap_history(
            "t",
            &TaskProfile::example(),
            &default_profiling_configs(),
            &mut rng,
        );
        let j = log.to_json();
        assert_eq!(
            j.get("runs")?.as_arr()?.len(),
            default_profiling_configs().len()
        );
        let back = EventLog::from_json(&j)?;
        assert_eq!(back.task, log.task);
        assert_eq!(back.len(), log.len());
        for (a, b) in back.runs.iter().zip(log.runs.iter()) {
            assert_eq!(a.config, b.config);
            assert!((a.runtime - b.runtime).abs() < 1e-12);
            assert_eq!(a.stages.len(), b.stages.len());
            for ((an, av), (bn, bv)) in a.stages.iter().zip(b.stages.iter()) {
                assert_eq!(an, bn);
                assert!((av - bv).abs() < 1e-12);
            }
        }
        Ok(())
    }

    #[test]
    fn eventlog_from_json_rejects_malformed_input_with_context() {
        // Missing field.
        let v = Json::parse(r#"{"task": "t"}"#).unwrap();
        let err = EventLog::from_json(&v).unwrap_err();
        assert!(format!("{err:#}").contains("runs"), "{err:#}");

        // Wrong type deep in a run record: the error names the run.
        let v = Json::parse(
            r#"{"task": "t", "runs": [{"instance": 0, "nodes": "two",
                "spark": 1, "runtime": 5.0, "stages": []}]}"#,
        )
        .unwrap();
        let err = EventLog::from_json(&v).unwrap_err();
        assert!(format!("{err:#}").contains("run 0"), "{err:#}");

        // Non-finite runtime rejected.
        let v = Json::parse(
            r#"{"task": "t", "runs": [{"instance": 0, "nodes": 2,
                "spark": 1, "runtime": -3.0, "stages": []}]}"#,
        )
        .unwrap();
        assert!(EventLog::from_json(&v).is_err());

        // Out-of-range catalog indices rejected up front (would panic at
        // first Config use otherwise).
        for bad in [
            r#"{"task": "t", "runs": [{"instance": 99, "nodes": 2,
                "spark": 1, "runtime": 5.0, "stages": []}]}"#,
            r#"{"task": "t", "runs": [{"instance": 0, "nodes": 0,
                "spark": 1, "runtime": 5.0, "stages": []}]}"#,
            r#"{"task": "t", "runs": [{"instance": 0, "nodes": 2,
                "spark": 7, "runtime": 5.0, "stages": []}]}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(EventLog::from_json(&v).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn shuffle_heavy_tasks_log_more_shuffle_time() {
        let mut shuffle_heavy = TaskProfile::example();
        shuffle_heavy.spark_affinity = -1.0;
        let mut io_heavy = TaskProfile::example();
        io_heavy.spark_affinity = 1.0;
        let cfg = Config {
            instance: 0,
            nodes: 1,
            spark: 1,
        };
        let mut rng = Rng::new(5);
        let (_, s1) = simulate_run(&shuffle_heavy, cfg, &mut rng);
        let (_, s2) = simulate_run(&io_heavy, cfg, &mut rng);
        let frac = |stages: &[(String, f64)], name: &str| {
            let total: f64 = stages.iter().map(|(_, s)| s).sum();
            stages.iter().find(|(n, _)| n == name).unwrap().1 / total
        };
        assert!(frac(&s1, "shuffle") > frac(&s2, "shuffle"));
    }
}
