//! Host-side batched NNLS (projected gradient) — the exact algorithm the
//! L2 `fit_theta` artifact implements, mirrored in Rust for two reasons:
//! (1) a CPU fallback when artifacts are absent, and (2) a cross-language
//! oracle: integration tests assert the PJRT path and this path agree.

use super::basis::K;

/// Default projected-gradient iteration budget of [`fit_one`].
pub const DEFAULT_ITERS: usize = 300;

/// Fit one task's non-negative coefficients from S (basis, runtime)
/// samples. `x` is row-major `[S][K]`; returns `theta[K] >= 0`.
pub fn fit_one(x: &[[f64; K]], y: &[f64], iters: usize) -> [f64; K] {
    assert_eq!(x.len(), y.len());
    // Gram = X^T X (K x K), xty = X^T y
    let mut gram = [[0.0f64; K]; K];
    let mut xty = [0.0f64; K];
    for (row, &yi) in x.iter().zip(y.iter()) {
        for a in 0..K {
            xty[a] += row[a] * yi;
            for b in 0..K {
                gram[a][b] += row[a] * row[b];
            }
        }
    }
    let trace: f64 = (0..K).map(|i| gram[i][i]).sum();
    let step = 1.0 / trace.max(1e-6);

    let mut theta = [0.0f64; K];
    for _ in 0..iters {
        // grad = Gram * theta - xty
        let mut grad = [0.0f64; K];
        for a in 0..K {
            let mut g = -xty[a];
            for b in 0..K {
                g += gram[a][b] * theta[b];
            }
            grad[a] = g;
        }
        for a in 0..K {
            theta[a] = (theta[a] - step * grad[a]).max(0.0);
        }
    }
    theta
}

/// Training loss 0.5*||X theta - y||^2 for convergence checks.
pub fn loss(x: &[[f64; K]], y: &[f64], theta: &[f64; K]) -> f64 {
    x.iter()
        .zip(y.iter())
        .map(|(row, &yi)| {
            let pred: f64 = row.iter().zip(theta.iter()).map(|(a, b)| a * b).sum();
            0.5 * (pred - yi) * (pred - yi)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::basis::ernest_basis;
    use crate::util::Rng;

    #[test]
    fn recovers_noiseless_predictions() {
        let mut rng = Rng::new(1);
        let mut true_theta = [0.0; K];
        for t in true_theta.iter_mut().take(4) {
            *t = rng.uniform(0.0, 20.0);
        }
        let ns = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
        let x: Vec<[f64; K]> = ns.iter().map(|&n| ernest_basis(n, 1.0, 1.0)).collect();
        let y: Vec<f64> = x
            .iter()
            .map(|row| row.iter().zip(true_theta.iter()).map(|(a, b)| a * b).sum())
            .collect();
        let theta = fit_one(&x, &y, 5000);
        for (row, &yi) in x.iter().zip(y.iter()) {
            let pred: f64 = row.iter().zip(theta.iter()).map(|(a, b)| a * b).sum();
            assert!(
                (pred - yi).abs() / yi.max(1e-6) < 0.05,
                "pred {pred} vs {yi}"
            );
        }
    }

    #[test]
    fn theta_is_nonnegative() {
        let mut rng = Rng::new(2);
        let x: Vec<[f64; K]> = (0..8)
            .map(|_| ernest_basis(rng.uniform(1.0, 32.0), 1.0, 1.0))
            .collect();
        let y: Vec<f64> = (0..8).map(|_| rng.uniform(-50.0, 50.0)).collect();
        let theta = fit_one(&x, &y, 500);
        assert!(theta.iter().all(|&t| t >= 0.0));
    }

    #[test]
    fn fit_reduces_loss_vs_zero() {
        let mut rng = Rng::new(3);
        let x: Vec<[f64; K]> = (0..6)
            .map(|_| ernest_basis(rng.uniform(1.0, 16.0), 1.0, 1.0))
            .collect();
        let y: Vec<f64> = (0..6).map(|_| rng.uniform(10.0, 100.0)).collect();
        let theta = fit_one(&x, &y, DEFAULT_ITERS);
        assert!(loss(&x, &y, &theta) < loss(&x, &y, &[0.0; K]));
    }

    #[test]
    fn single_sample_fit_matches_observation() {
        // The paper: "AGORA requires only one event log per task".
        let x = vec![ernest_basis(4.0, 1.0, 1.0)];
        let y = vec![120.0];
        let theta = fit_one(&x, &y, 5000);
        let pred: f64 = x[0].iter().zip(theta.iter()).map(|(a, b)| a * b).sum();
        assert!((pred - 120.0).abs() < 1.0, "pred={pred}");
    }
}
