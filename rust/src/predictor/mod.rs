//! The AGORA **Predictor** (§4.4): runtime prediction for every
//! (task, configuration) pair, learned from Spark event logs.
//!
//! Two implementations share one model family:
//! * [`LearnedPredictor`] — fits per-task Ernest coefficients (NNLS) and
//!   USL parameters from event logs; predictions run either on the host
//!   (this module) or through the AOT-compiled L1 kernel via PJRT
//!   (`runtime::PjrtPredictor`), bit-compatible by construction.
//! * [`OraclePredictor`] — reads ground truth directly; used by ablations
//!   (perfect-predictor bound) and by brute-force co-optimization.

pub mod basis;
pub mod eventlog;
pub mod nnls;

use crate::cluster::{Config, ConfigSpace, Family};
use crate::dag::profile::usl_penalty;
use crate::dag::TaskProfile;

pub use basis::{config_basis, ernest_basis, K};
pub use eventlog::{
    bootstrap_history, default_profiling_configs, market_profiling_configs,
    profiling_configs_for, scoped_task_name, simulate_run, EventLog,
};

/// Floor for predicted runtimes (mirrors python ref.EPS).
pub const EPS: f64 = 1e-3;

/// Predicted runtime surface: `durations[t][c]` seconds for task `t`
/// under configuration `c` of the space it was built against.
#[derive(Debug, Clone)]
pub struct Grid {
    /// `durations[t][c]` = predicted seconds for task `t` on config `c`.
    pub durations: Vec<Vec<f64>>,
}

impl Grid {
    /// Number of task rows.
    pub fn tasks(&self) -> usize {
        self.durations.len()
    }

    /// Predicted runtime of one (task, config) pair.
    pub fn get(&self, task: usize, config: usize) -> f64 {
        self.durations[task][config]
    }

    /// Index of the config minimizing predicted runtime for a task.
    pub fn fastest(&self, task: usize, feasible: &[usize]) -> usize {
        *feasible
            .iter()
            .min_by(|&&a, &&b| self.durations[task][a].total_cmp(&self.durations[task][b]))
            .expect("non-empty feasible set")
    }
}

/// Fitted per-task model parameters — exactly the tensors the L1 kernel
/// consumes (theta row, USL row), plus per-Spark-preset and per-family
/// multipliers.
///
/// The preset and family effects are multiplicative in runtime; because
/// the kernel is linear in (theta, gamma) jointly, such multipliers fold
/// exactly into a scaled (theta, gamma) row or an output scale — the
/// PJRT path expands each task into one row per preset, post-scales the
/// kernel output per config, and the kernel contract stays unchanged.
#[derive(Debug, Clone)]
pub struct FittedTask {
    /// Ernest NNLS coefficients over the config basis. The fit targets
    /// are **speed-normalized** (`runtime x speed_factor`), so theta
    /// models family-neutral work-time; the family speed divides back
    /// out at prediction time ([`model_runtime`]).
    pub theta: [f64; K],
    /// (gamma, alpha, beta, mix) — see python/compile/kernels/ref.py.
    pub usl: [f64; 4],
    /// Runtime multiplier per Spark preset (index = preset id),
    /// relative to the balanced preset the Ernest fit is trained on.
    pub preset_mult: [f64; 3],
    /// Residual runtime multiplier per instance family
    /// (index = [`Family::index`]), relative to the speed-scaled model:
    /// captures effects the speed factor alone misses (e.g. r5's extra
    /// memory relieving spill for memory-bound tasks). 1.0 when the
    /// history holds no runs of that family — bit-identical to the
    /// family-blind model on m5-only histories.
    pub family_mult: [f64; Family::COUNT],
}

/// Evaluate the canonical predictor model for one (task, config) pair.
/// The basis contraction MUST match `predict_grid_ref` in
/// python/compile/kernels/ref.py; the preset, family and speed scalings
/// are output multipliers (equivalent to scaling theta and gamma), which
/// is exactly how the PJRT path applies them around the kernel.
pub fn model_runtime(fit: &FittedTask, cfg: &Config) -> f64 {
    let phi = config_basis(cfg);
    let ernest = basis::dot(&fit.theta, &phi);
    let [gamma, alpha, beta, mix] = fit.usl;
    let pen = usl_penalty(cfg.n_eff(), alpha, beta);
    let it = cfg.instance_type();
    // The model predicts speed-normalized work-time; a faster family
    // divides it back out — mirroring how the simulated ground truth
    // applies `speed_factor` (dag/profile.rs). For m5 every multiplier
    // here is exactly 1.0 and the historical predictions are unchanged.
    let mult = fit.preset_mult[cfg.spark.min(2)] * fit.family_mult[it.family.index()]
        / it.speed_factor.max(1e-6);
    ((mix * ernest + (1.0 - mix) * gamma * pen) * mult).max(EPS)
}

/// A predictor produces a runtime grid over a configuration space.
pub trait Predictor {
    /// Predict the full (task, config) runtime grid for a space.
    fn predict(&self, space: &ConfigSpace) -> Grid;
    /// Human-readable name for experiment tables.
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------

/// Perfect predictor: reads ground-truth profiles. Upper-bounds what any
/// learned predictor could achieve; the paper's BF co-optimize motivation
/// study effectively assumes this.
#[derive(Debug, Clone)]
pub struct OraclePredictor {
    /// Ground-truth profile per task, in problem order.
    pub profiles: Vec<TaskProfile>,
}

impl Predictor for OraclePredictor {
    fn predict(&self, space: &ConfigSpace) -> Grid {
        let durations = self
            .profiles
            .iter()
            .map(|p| space.configs.iter().map(|c| p.runtime(c)).collect())
            .collect();
        Grid { durations }
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

// ---------------------------------------------------------------------------

/// Event-log-trained predictor (the real AGORA path).
#[derive(Debug, Clone)]
pub struct LearnedPredictor {
    /// Fitted model per task, in log order.
    pub fits: Vec<FittedTask>,
}

/// Prior USL parameters used when the history is too thin to identify
/// alpha/beta (single prior run — the paper's minimum requirement).
const PRIOR_ALPHA: f64 = 0.10;
const PRIOR_BETA: f64 = 0.005;

impl LearnedPredictor {
    /// Fit one task from its event log.
    ///
    /// Three-stage fit: (1) NNLS Ernest coefficients over the balanced-
    /// preset samples (scaling with nodes/instances), on **speed-
    /// normalized** targets (`runtime x speed_factor`) so one curve
    /// covers every family; (2) multiplicative preset factors from the
    /// preset-varied samples — the runtime ratio observed at matched
    /// (instance, nodes); (3) residual per-family multipliers from any
    /// c5/r5 samples (memory relief, cache effects — whatever the speed
    /// factor alone misses). Preset and family effects are multiplicative
    /// in the ground truth, so ratio estimates converge far faster than
    /// forcing the additive basis to absorb them. On m5-only histories
    /// every new multiplier is exactly 1.0 and the fit is bit-identical
    /// to the family-blind predictor.
    pub fn fit_task(log: &EventLog) -> FittedTask {
        assert!(!log.is_empty(), "predictor requires >= 1 prior run");
        // Stage 1: Ernest NNLS over balanced-preset samples (fall back
        // to all samples when the history has no balanced run).
        let balanced: Vec<&eventlog::RunRecord> =
            log.runs.iter().filter(|r| r.config.spark == 1).collect();
        let train: Vec<&eventlog::RunRecord> = if balanced.is_empty() {
            log.runs.iter().collect()
        } else {
            balanced
        };
        let x: Vec<[f64; K]> = train.iter().map(|r| config_basis(&r.config)).collect();
        let y: Vec<f64> = train
            .iter()
            .map(|r| r.runtime * r.config.instance_type().speed_factor)
            .collect();
        let theta = nnls::fit_one(&x, &y, nnls::DEFAULT_ITERS);

        // USL part: gamma chosen so the prior-shaped curve passes through
        // the most recent observation (speed-normalized like the Ernest
        // targets); alpha/beta from priors (they become identifiable only
        // through the Ernest term as history grows).
        let last = train.last().unwrap();
        let pen = usl_penalty(last.config.n_eff(), PRIOR_ALPHA, PRIOR_BETA);
        let gamma = last.runtime * last.config.instance_type().speed_factor / pen.max(1e-9);

        // Trust the Ernest fit more as history grows: mix = S / (S + 2).
        let s = train.len() as f64;
        let mix = s / (s + 2.0);

        // Stage 2: preset multipliers — geometric mean of observed /
        // predicted-balanced ratios at each sampled preset.
        let mut fit = FittedTask {
            theta,
            usl: [gamma, PRIOR_ALPHA, PRIOR_BETA, mix],
            preset_mult: [1.0; 3],
            family_mult: [1.0; Family::COUNT],
        };
        let mut preset_mult = [1.0f64; 3];
        for preset in [0usize, 2] {
            let ratios: Vec<f64> = log
                .runs
                .iter()
                .filter(|r| r.config.spark == preset)
                .map(|r| {
                    let mut balanced_cfg = r.config;
                    balanced_cfg.spark = 1;
                    r.runtime / model_runtime(&fit, &balanced_cfg).max(1e-9)
                })
                .collect();
            if !ratios.is_empty() {
                let g = (ratios.iter().map(|x| x.ln()).sum::<f64>() / ratios.len() as f64).exp();
                preset_mult[preset] = g.clamp(0.25, 4.0);
            }
        }
        fit.preset_mult = preset_mult;

        // Stage 3: residual family multipliers (m5 is the anchor at 1.0)
        // — geometric mean of observed / speed-scaled-model ratios over
        // that family's samples.
        for family in [Family::C5, Family::R5] {
            let ratios: Vec<f64> = log
                .runs
                .iter()
                .filter(|r| r.config.family() == family)
                .map(|r| r.runtime / model_runtime(&fit, &r.config).max(1e-9))
                .collect();
            if !ratios.is_empty() {
                let g = (ratios.iter().map(|x| x.ln()).sum::<f64>() / ratios.len() as f64).exp();
                fit.family_mult[family.index()] = g.clamp(0.25, 4.0);
            }
        }

        fit
    }

    /// Fit one model per event log, in order.
    pub fn fit(logs: &[EventLog]) -> LearnedPredictor {
        LearnedPredictor {
            fits: logs.iter().map(Self::fit_task).collect(),
        }
    }

    /// The tensors handed to the AOT kernel (theta [T,K], usl [T,4]).
    pub fn tensors(&self) -> (Vec<[f64; K]>, Vec<[f64; 4]>) {
        (
            self.fits.iter().map(|f| f.theta).collect(),
            self.fits.iter().map(|f| f.usl).collect(),
        )
    }
}

impl Predictor for LearnedPredictor {
    fn predict(&self, space: &ConfigSpace) -> Grid {
        let durations = self
            .fits
            .iter()
            .map(|f| {
                space
                    .configs
                    .iter()
                    .map(|c| model_runtime(f, c))
                    .collect()
            })
            .collect();
        Grid { durations }
    }

    fn name(&self) -> &'static str {
        "learned"
    }
}

/// Mean absolute percentage error of a grid against ground truth —
/// the paper quotes <20% for Ernest; our learned predictor is in the
/// same regime (asserted in tests).
pub fn mape(grid: &Grid, profiles: &[TaskProfile], space: &ConfigSpace) -> f64 {
    let mut total = 0.0;
    let mut count = 0usize;
    for (t, p) in profiles.iter().enumerate() {
        for (c, cfg) in space.configs.iter().enumerate() {
            let truth = p.runtime(cfg);
            total += (grid.get(t, c) - truth).abs() / truth.max(1e-9);
            count += 1;
        }
    }
    total / count.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::workloads::{JobKind, ALL_JOBS};
    use crate::util::Rng;

    fn training_configs() -> Vec<Config> {
        // Ernest-style sampling: small scales plus one larger anchor.
        vec![
            Config { instance: 0, nodes: 1, spark: 1 },
            Config { instance: 0, nodes: 2, spark: 1 },
            Config { instance: 0, nodes: 4, spark: 1 },
            Config { instance: 1, nodes: 4, spark: 1 },
            Config { instance: 0, nodes: 8, spark: 1 },
        ]
    }

    #[test]
    fn oracle_grid_matches_profiles() {
        let profiles: Vec<_> = ALL_JOBS.iter().map(|j| j.profile()).collect();
        let space = ConfigSpace::standard();
        let grid = OraclePredictor {
            profiles: profiles.clone(),
        }
        .predict(&space);
        assert_eq!(grid.tasks(), 4);
        for (t, p) in profiles.iter().enumerate() {
            for (c, cfg) in space.configs.iter().enumerate() {
                assert_eq!(grid.get(t, c), p.runtime(cfg));
            }
        }
    }

    #[test]
    fn learned_predictor_mape_under_25_percent() {
        // Paper: Ernest achieves < 20% error on most workloads; our
        // learned predictor must land in the same regime on the library.
        let mut rng = Rng::new(42);
        let profiles: Vec<_> = ALL_JOBS.iter().map(|j| j.profile()).collect();
        let logs: Vec<EventLog> = ALL_JOBS
            .iter()
            .map(|j| bootstrap_history(j.name(), &j.profile(), &training_configs(), &mut rng))
            .collect();
        let pred = LearnedPredictor::fit(&logs);
        let space = ConfigSpace::standard();
        let grid = pred.predict(&space);
        let err = mape(&grid, &profiles, &space);
        assert!(err < 0.25, "MAPE {err:.3} too high");
    }

    #[test]
    fn single_run_history_is_enough() {
        // Paper: "AGORA requires only one event log per task (one prior run)".
        let mut rng = Rng::new(7);
        let profile = JobKind::AirlineDelay.profile();
        let one = vec![Config {
            instance: 0,
            nodes: 4,
            spark: 1,
        }];
        let log = bootstrap_history("t", &profile, &one, &mut rng);
        let fit = LearnedPredictor::fit_task(&log);
        let space = ConfigSpace::standard();
        let grid = LearnedPredictor { fits: vec![fit] }.predict(&space);
        // Sanity: predictions are positive and finite everywhere.
        for c in 0..space.len() {
            let d = grid.get(0, c);
            assert!(d.is_finite() && d > 0.0);
        }
    }

    #[test]
    fn more_history_improves_accuracy() {
        let mut rng = Rng::new(9);
        let profile = JobKind::MovieRecommendation.profile();
        let space = ConfigSpace::standard();
        let profiles = vec![profile.clone()];

        let thin = bootstrap_history(
            "t",
            &profile,
            &[Config { instance: 0, nodes: 4, spark: 1 }],
            &mut rng,
        );
        let rich = bootstrap_history("t", &profile, &training_configs(), &mut rng);

        let err_thin = mape(
            &LearnedPredictor::fit(&[thin]).predict(&space),
            &profiles,
            &space,
        );
        let err_rich = mape(
            &LearnedPredictor::fit(&[rich]).predict(&space),
            &profiles,
            &space,
        );
        assert!(
            err_rich < err_thin,
            "rich {err_rich:.3} should beat thin {err_thin:.3}"
        );
    }

    #[test]
    fn fastest_respects_feasible_set() {
        let profiles: Vec<_> = vec![JobKind::IndexAnalysis.profile()];
        let space = ConfigSpace::standard();
        let grid = OraclePredictor { profiles }.predict(&space);
        let feasible: Vec<usize> = vec![0, 1, 2];
        let best = grid.fastest(0, &feasible);
        assert!(feasible.contains(&best));
    }

    #[test]
    fn model_runtime_floors_at_eps() {
        let fit = FittedTask {
            theta: [0.0; K],
            usl: [0.0, 0.0, 0.0, 1.0],
            preset_mult: [1.0; 3],
            family_mult: [1.0; Family::COUNT],
        };
        let cfg = Config {
            instance: 0,
            nodes: 1,
            spark: 1,
        };
        assert_eq!(model_runtime(&fit, &cfg), EPS);
    }

    #[test]
    fn m5_only_history_fits_neutral_family_multipliers() {
        // The family extension must be invisible on historical m5-only
        // logs: every family multiplier stays exactly 1.0.
        let mut rng = Rng::new(21);
        let log = bootstrap_history(
            "t",
            &JobKind::SentimentAnalysis.profile(),
            &training_configs(),
            &mut rng,
        );
        let fit = LearnedPredictor::fit_task(&log);
        assert_eq!(fit.family_mult, [1.0; Family::COUNT]);
    }

    #[test]
    fn speed_factor_scales_predictions_down_on_faster_families() {
        // Pure algebra (no fitting): with the speed-sensitive basis
        // features zeroed, a c5 prediction is exactly the m5 prediction
        // divided by the c5 speed factor.
        let mut theta = [0.0; K];
        theta[0] = 100.0;
        theta[1] = 50.0;
        let fit = FittedTask {
            theta,
            usl: [0.0, 0.0, 0.0, 1.0],
            preset_mult: [1.0; 3],
            family_mult: [1.0; Family::COUNT],
        };
        let m5 = Config { instance: 0, nodes: 2, spark: 1 };
        let c5_idx = crate::cluster::catalog::index_by_name("c5.4xlarge").unwrap();
        let c5 = Config { instance: c5_idx, nodes: 2, spark: 1 };
        // Neutralize the speed/memory basis features so the contraction
        // is family-invariant and only the output scaling differs.
        let pred_m5 = model_runtime(&fit, &m5);
        let pred_c5 = model_runtime(&fit, &c5);
        let speed = c5.instance_type().speed_factor;
        assert!(
            (pred_c5 - pred_m5 / speed).abs() < 1e-9,
            "c5 {pred_c5} should be m5 {pred_m5} / {speed}"
        );
    }

    #[test]
    fn family_samples_anchor_family_predictions_to_ground_truth() {
        // A noise-free history with one balanced run per alternate
        // family: the stage-3 ratio correction makes the prediction at
        // each sampled family config exactly the observed ground truth.
        let profile = TaskProfile {
            noise_sigma: 0.0,
            ..TaskProfile::example()
        };
        let mut configs = training_configs();
        let c5_idx = crate::cluster::catalog::index_by_name("c5.4xlarge").unwrap();
        let r5_idx = crate::cluster::catalog::index_by_name("r5.4xlarge").unwrap();
        let c5 = Config { instance: c5_idx, nodes: 4, spark: 1 };
        let r5 = Config { instance: r5_idx, nodes: 4, spark: 1 };
        configs.push(c5);
        configs.push(r5);
        let mut rng = Rng::new(5);
        let log = bootstrap_history("t", &profile, &configs, &mut rng);
        let fit = LearnedPredictor::fit_task(&log);
        for cfg in [c5, r5] {
            let truth = profile.runtime(&cfg);
            let pred = model_runtime(&fit, &cfg);
            assert!(
                (pred - truth).abs() / truth < 1e-6,
                "sampled family config should be ratio-anchored: pred {pred} truth {truth}"
            );
        }
        // The learned multipliers moved off the neutral anchor.
        assert!(fit.family_mult[Family::C5.index()] != 1.0);
        assert!(fit.family_mult[Family::R5.index()] != 1.0);
        assert_eq!(fit.family_mult[Family::M5.index()], 1.0);
    }
}
