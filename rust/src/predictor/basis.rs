//! Ernest basis features — MUST mirror `python/compile/kernels/ref.py`
//! (`ernest_basis`): the Rust coordinator builds these vectors and feeds
//! them to the AOT-compiled kernels, so any drift between the two
//! definitions silently corrupts predictions. `python/tests/test_kernel.py
//! ::test_ernest_basis_matches_rust_convention` pins the layout.

/// Number of basis features (padded to 8 so the kernel contraction is
/// MXU-aligned).
pub const K: usize = 8;

/// Feature vector for effective parallelism `n` on an instance with the
/// given speed factors. Layout:
///   0: 1                (serial term)
///   1: 1/n              (communication / all-to-one)
///   2: log2(n+1)        (tree aggregation)
///   3: n/64             (per-node overhead)
///   4: cpu_factor       (instance speed)
///   5: mem_factor       (instance memory headroom)
///   6,7: zero padding
pub fn ernest_basis(n: f64, cpu_factor: f64, mem_factor: f64) -> [f64; K] {
    let n = n.max(1.0);
    [
        1.0,
        1.0 / n,
        (n + 1.0).log2(),
        n / 64.0,
        cpu_factor,
        mem_factor,
        0.0,
        0.0,
    ]
}

/// Basis for a cluster configuration: n is the m5.4xlarge-equivalent node
/// count; the memory factor encodes usable memory relative to the m5
/// baseline of 4 GiB/vCPU (constant within the family, but carried so the
/// model generalizes to other catalogs).
///
/// Features 6 and 7 carry the Spark preset as a SIGNED pair
/// (thin-leaning bias, fat-leaning bias): NNLS coefficients are
/// non-negative, so a single monotone preset feature could only ever
/// model "thinner is slower" — the pair lets the fit express either
/// direction per task (shuffle-heavy jobs prefer fat executors,
/// embarrassingly parallel jobs prefer thin; see TaskProfile::spark_eff).
pub fn config_basis(cfg: &crate::cluster::Config) -> [f64; K] {
    let it = cfg.instance_type();
    let mem_factor =
        it.memory_per_vcpu() / 4.0 * cfg.spark_params().memory_fraction;
    let mut phi = ernest_basis(cfg.n_eff(), it.speed_factor, mem_factor);
    let bias = cfg.spark_params().parallel_bias;
    phi[6] = bias.max(0.0);
    phi[7] = (-bias).max(0.0);
    phi
}

/// Dot product against a coefficient vector.
pub fn dot(theta: &[f64; K], phi: &[f64; K]) -> f64 {
    theta.iter().zip(phi.iter()).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Config;

    #[test]
    fn basis_layout_matches_python_ref() {
        // Pinned against python/tests/test_kernel.py
        let b = ernest_basis(4.0, 1.5, 2.0);
        assert_eq!(b[0], 1.0);
        assert_eq!(b[1], 0.25);
        assert!((b[2] - 5.0f64.log2()).abs() < 1e-12);
        assert!((b[3] - 4.0 / 64.0).abs() < 1e-12);
        assert_eq!(b[4], 1.5);
        assert_eq!(b[5], 2.0);
        assert_eq!(b[6], 0.0);
        assert_eq!(b[7], 0.0);
    }

    #[test]
    fn n_below_one_clamps() {
        let b = ernest_basis(0.0, 1.0, 1.0);
        assert_eq!(b[1], 1.0);
    }

    #[test]
    fn config_basis_uses_n_eff() {
        let c = Config {
            instance: 3,
            nodes: 2,
            spark: 1,
        }; // 2 x m5.16xlarge = 8 n_eff
        let b = config_basis(&c);
        assert!((b[1] - 1.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn dot_product() {
        let mut theta = [0.0; K];
        theta[0] = 2.0;
        theta[1] = 4.0;
        let phi = ernest_basis(2.0, 1.0, 1.0);
        assert!((dot(&theta, &phi) - (2.0 + 4.0 * 0.5)).abs() < 1e-12);
    }
}
