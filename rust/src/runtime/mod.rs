//! PJRT runtime: load and execute the AOT-compiled L1/L2 artifacts.
//!
//! `make artifacts` (build time, Python) lowers the Predictor's fit and
//! grid-prediction graphs to HLO *text* under `artifacts/`; this module
//! loads them through the `xla` crate (PJRT CPU client), compiles once at
//! startup, and executes them on the request path. Python is never
//! invoked at runtime.
//!
//! Interchange is HLO text, not serialized protos: jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md).

pub mod engine;
pub mod predictor;
pub mod xla_stub;

pub use engine::{ArtifactManifest, Engine, Variant};
pub use predictor::PjrtPredictor;
