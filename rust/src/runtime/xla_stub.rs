//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The crate must build with only `anyhow` available (the offline vendor
//! set carries no `xla` / `xla_extension`), so this module mirrors the
//! minimal API surface `engine.rs` consumes and fails at *runtime* — at
//! the `PjRtClient::cpu()` entry point — with a clear message. Every
//! PJRT-dependent path in the repo already gates on `Engine::new`
//! succeeding (or on `artifacts/manifest.json` existing), so the stub
//! degrades the system to the numerically identical host predictor
//! instead of breaking the build.
//!
//! Swapping the real bindings back in is a two-line change in
//! `engine.rs` (`use xla;` instead of `use super::xla_stub as xla;`).

use std::fmt;

/// Error type mirroring the binding's debug-printable error.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "XLA/PJRT bindings are not available in this offline build; \
         the host predictor path (LearnedPredictor) is numerically \
         interchangeable"
            .to_string(),
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// Stub: always fails with the offline-build message.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    /// Stub platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Stub: always fails.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Stub: always fails.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Stub: returns the unit computation.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub: unreachable because compile() fails).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Stub: always fails.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Stub: always fails.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Host literal (stub).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    /// Stub: returns the unit literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Stub: always fails.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    /// Stub: always fails.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    /// Stub: always fails.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn literal_surface_is_inert() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
