//! Artifact loading + compiled-executable cache over the PJRT CPU client.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

// The real PJRT bindings are unavailable in the offline vendor set; the
// stub keeps this module compiling and fails cleanly at Engine::new.
use super::xla_stub as xla;
use crate::util::Json;

/// Shape variant of the compiled Predictor (see python VARIANTS).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Small static shapes (unit-test sized problems).
    Small,
    /// Large static shapes (macro-scale problems).
    Large,
}

impl Variant {
    /// Manifest-key suffix of this variant.
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Small => "small",
            Variant::Large => "large",
        }
    }

    /// Pick the smallest variant that fits (tasks, configs).
    pub fn for_problem(
        manifest: &ArtifactManifest,
        tasks: usize,
        configs: usize,
    ) -> Result<Variant> {
        for v in [Variant::Small, Variant::Large] {
            if let Some(e) = manifest.entries.get(&format!("predict_{}", v.name())) {
                if tasks <= e.tasks && configs <= e.configs {
                    return Ok(v);
                }
            }
        }
        bail!("no artifact variant fits {tasks} tasks x {configs} configs")
    }
}

/// One artifact's shape metadata from manifest.json.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// HLO text file name of the compiled entry point.
    pub entry: String,
    /// Static task-row capacity.
    pub tasks: usize,
    /// Static config-column capacity.
    pub configs: usize,
    /// Static sample-row capacity of the fit artifact.
    pub samples: usize,
}

/// Parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Ernest basis dimension K the artifacts were compiled for.
    pub k: usize,
    /// Artifact name -> shape metadata.
    pub entries: HashMap<String, ArtifactEntry>,
}

impl ArtifactManifest {
    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let manifest_path = dir.join("manifest.json");
        let v = Json::parse_file(&manifest_path)?;
        let k = v.get("k")?.as_usize()?;
        let mut entries = HashMap::new();
        for (name, e) in v.get("artifacts")?.as_obj()? {
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    entry: e.get("entry")?.as_str()?.to_string(),
                    tasks: e.get("tasks")?.as_usize()?,
                    configs: e.get("configs")?.as_usize()?,
                    samples: e.get("samples")?.as_usize()?,
                },
            );
        }
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            k,
            entries,
        })
    }

    /// Default artifact directory: $AGORA_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("AGORA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

/// The PJRT execution engine: one CPU client + a lazy cache of compiled
/// executables keyed by artifact name.
pub struct Engine {
    client: xla::PjRtClient,
    /// Shape metadata of the loaded artifact set.
    pub manifest: ArtifactManifest,
    cache: std::sync::Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create the engine and verify the artifact directory. Compilation
    /// happens lazily per artifact (first use) and is cached.
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        let manifest = ArtifactManifest::load(artifact_dir)
            .with_context(|| format!("loading artifacts from {}", artifact_dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            cache: std::sync::Mutex::new(HashMap::new()),
        })
    }

    /// PJRT platform name (e.g. `cpu`; `stub` offline).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by name, e.g. "predict_small".
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        if !self.manifest.entries.contains_key(name) {
            bail!(
                "unknown artifact {name:?}; manifest has {:?}",
                self.manifest.entries.keys().collect::<Vec<_>>()
            );
        }
        let path = self.manifest.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let arc = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Execute an artifact with f32 input tensors (shape: row-major dims)
    /// and return the tuple elements as flat f32 vectors.
    pub fn run_f32(
        &self,
        name: &str,
        inputs: &[(Vec<f32>, Vec<i64>)],
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let expected: i64 = dims.iter().product();
                assert_eq!(
                    expected as usize,
                    data.len(),
                    "input buffer size mismatch for {name}"
                );
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        // Artifacts are lowered with return_tuple=True.
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow!("reading f32 result: {e:?}"))
            })
            .collect()
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine integration tests live in rust/tests/integration.rs (they
    // need `make artifacts` to have run). Here: manifest parsing only.

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join("agora-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"k": 8, "artifacts": {"predict_small": {
                "entry": "predict", "variant": "small",
                "tasks": 32, "configs": 64, "samples": 0, "k": 8,
                "inputs": [[32,8]], "outputs": [[32,64]]}}}"#,
        )
        .unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.k, 8);
        let e = &m.entries["predict_small"];
        assert_eq!(e.tasks, 32);
        assert_eq!(e.configs, 64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn variant_selection_prefers_smallest_fit() {
        let mut entries = HashMap::new();
        for (name, t, c) in [("predict_small", 32, 64), ("predict_large", 128, 512)] {
            entries.insert(
                name.to_string(),
                ArtifactEntry {
                    entry: "predict".into(),
                    tasks: t,
                    configs: c,
                    samples: 0,
                },
            );
        }
        let m = ArtifactManifest {
            dir: PathBuf::from("."),
            k: 8,
            entries,
        };
        assert_eq!(Variant::for_problem(&m, 8, 64).unwrap(), Variant::Small);
        assert_eq!(Variant::for_problem(&m, 64, 64).unwrap(), Variant::Large);
        assert!(Variant::for_problem(&m, 500, 64).is_err());
    }

    #[test]
    fn missing_manifest_is_a_clean_error() {
        let r = ArtifactManifest::load(Path::new("/nonexistent-agora"));
        assert!(r.is_err());
    }
}
