//! PJRT-backed Predictor: the production path where the fitted model (or
//! raw event-log samples) run through the AOT-compiled L1 Pallas kernel.
//!
//! Numerically interchangeable with the host `LearnedPredictor` — both
//! implement the canonical model of python/compile/kernels/ref.py — and
//! asserted equal (1e-4 relative) by rust/tests/integration.rs.
//!
//! Preset multipliers: the kernel is linear in (theta, gamma) jointly, so
//! each task expands into one row per Spark preset with (theta, gamma)
//! scaled by that preset's multiplier; after execution, cell (t, c) is
//! read from the row matching config c's preset. The kernel contract is
//! untouched. Tasks are processed in chunks when the expansion exceeds
//! the artifact variant's static row count.
//!
//! Family/speed scaling: the host model predicts speed-normalized
//! work-time and scales by `family_mult / speed_factor` per config
//! (`predictor::model_runtime`). Both are per-config *output* scalings,
//! so the PJRT path applies them to the kernel result after execution —
//! again without touching the kernel contract — and speed-normalizes the
//! on-device fit targets exactly like the host fit does.

use anyhow::Result;

use super::engine::{Engine, Variant};
use crate::cluster::ConfigSpace;
use crate::predictor::{config_basis, EventLog, FittedTask, Grid, LearnedPredictor, K};

/// Number of Spark presets a task row expands into.
const PRESETS: usize = crate::cluster::config::SPARK_PRESETS.len();

/// Batched grid prediction through the compiled artifacts.
pub struct PjrtPredictor<'e> {
    /// The PJRT engine executing the compiled artifacts.
    pub engine: &'e Engine,
}

impl<'e> PjrtPredictor<'e> {
    /// Predictor over a loaded engine.
    pub fn new(engine: &'e Engine) -> Self {
        PjrtPredictor { engine }
    }

    /// Build the phi `[C, K]` and n `[C]` tensors for a config space, padded
    /// to `configs` rows.
    fn config_tensors(space: &ConfigSpace, configs: usize) -> (Vec<f32>, Vec<f32>) {
        let mut phi = vec![0f32; configs * K];
        let mut n = vec![1f32; configs];
        for (c, cfg) in space.configs.iter().enumerate() {
            let basis = config_basis(cfg);
            for (k, &b) in basis.iter().enumerate() {
                phi[c * K + k] = b as f32;
            }
            n[c] = cfg.n_eff() as f32;
        }
        (phi, n)
    }

    /// Predict the runtime grid from an already-fitted model, via the
    /// `predict_<variant>` artifact (pure L1 kernel).
    pub fn predict_fitted(&self, fits: &[FittedTask], space: &ConfigSpace) -> Result<Grid> {
        let c_real = space.len();
        // Any variant must fit the config axis; rows are chunked.
        let variant = Variant::for_problem(&self.engine.manifest, 1, c_real)?;
        let name = format!("predict_{}", variant.name());
        let entry = &self.engine.manifest.entries[&name];
        let (rows_pad, c_pad) = (entry.tasks, entry.configs);
        let tasks_per_chunk = (rows_pad / PRESETS).max(1);

        let (phi, n) = Self::config_tensors(space, c_pad);
        let mut durations: Vec<Vec<f64>> = Vec::with_capacity(fits.len());

        for chunk in fits.chunks(tasks_per_chunk) {
            // Expand: one row per (task, preset), theta/gamma scaled by
            // the preset multiplier.
            let mut theta = vec![0f32; rows_pad * K];
            let mut usl = vec![0f32; rows_pad * 4];
            for (t, fit) in chunk.iter().enumerate() {
                for (s, &mult) in fit.preset_mult.iter().enumerate() {
                    let row = t * PRESETS + s;
                    for (k, &v) in fit.theta.iter().enumerate() {
                        theta[row * K + k] = (v * mult) as f32;
                    }
                    usl[row * 4] = (fit.usl[0] * mult) as f32; // gamma
                    usl[row * 4 + 1] = fit.usl[1] as f32;
                    usl[row * 4 + 2] = fit.usl[2] as f32;
                    usl[row * 4 + 3] = fit.usl[3] as f32;
                }
            }
            // Padding rows: mix = 1 with zero theta -> EPS (inert).
            for row in chunk.len() * PRESETS..rows_pad {
                usl[row * 4 + 3] = 1.0;
            }

            let outputs = self.engine.run_f32(
                &name,
                &[
                    (theta, vec![rows_pad as i64, K as i64]),
                    (phi.clone(), vec![c_pad as i64, K as i64]),
                    (usl, vec![rows_pad as i64, 4]),
                    (n.clone(), vec![c_pad as i64]),
                ],
            )?;
            let flat = &outputs[0];
            for (t, fit) in chunk.iter().enumerate() {
                let row_of = |c: usize| t * PRESETS + space.configs[c].spark.min(PRESETS - 1);
                durations.push(
                    (0..c_real)
                        .map(|c| {
                            let it = space.configs[c].instance_type();
                            let scale = fit.family_mult[it.family.index()]
                                / it.speed_factor.max(1e-6);
                            (flat[row_of(c) * c_pad + c] as f64 * scale)
                                .max(crate::predictor::EPS)
                        })
                        .collect(),
                );
            }
        }
        Ok(Grid { durations })
    }

    /// Fit + predict: the batched NNLS runs in the fused
    /// `fit_predict_<variant>` artifact (fitted theta comes back from
    /// the device); preset multipliers are ratio estimates on the host
    /// (data-dependent control flow); the final grid goes through
    /// `predict_fitted` (kernel again).
    pub fn fit_predict(
        &self,
        logs: &[EventLog],
        space: &ConfigSpace,
    ) -> Result<(Grid, Vec<FittedTask>)> {
        let c_real = space.len();
        let variant = Variant::for_problem(&self.engine.manifest, 1, c_real)?;
        let name = format!("fit_predict_{}", variant.name());
        let entry = &self.engine.manifest.entries[&name];
        let (t_pad, c_pad, s_pad) = (entry.tasks, entry.configs, entry.samples);

        // Host fits provide the USL rows + preset multipliers; the Ernest
        // theta is recomputed on-device from the raw samples (balanced
        // preset only — matching the host's two-stage fit).
        let host_fits: Vec<FittedTask> = logs.iter().map(LearnedPredictor::fit_task).collect();
        let (phi, n) = Self::config_tensors(space, c_pad);

        let mut fits: Vec<FittedTask> = Vec::with_capacity(logs.len());
        for (chunk_logs, chunk_host) in logs.chunks(t_pad).zip(host_fits.chunks(t_pad)) {
            let mut x = vec![0f32; t_pad * s_pad * K];
            let mut y = vec![0f32; t_pad * s_pad];
            let mut usl = vec![0f32; t_pad * 4];
            for (t, log) in chunk_logs.iter().enumerate() {
                let mut s_i = 0usize;
                for run in log.runs.iter().filter(|r| r.config.spark == 1).take(s_pad) {
                    let basis = config_basis(&run.config);
                    for (k, &b) in basis.iter().enumerate() {
                        x[(t * s_pad + s_i) * K + k] = b as f32;
                    }
                    // Speed-normalized targets, matching the host fit.
                    y[t * s_pad + s_i] =
                        (run.runtime * run.config.instance_type().speed_factor) as f32;
                    s_i += 1;
                }
                if s_i == 0 {
                    // no balanced history: train on everything, like host
                    for run in log.runs.iter().take(s_pad) {
                        let basis = config_basis(&run.config);
                        for (k, &b) in basis.iter().enumerate() {
                            x[(t * s_pad + s_i) * K + k] = b as f32;
                        }
                        y[t * s_pad + s_i] =
                            (run.runtime * run.config.instance_type().speed_factor) as f32;
                        s_i += 1;
                    }
                }
                for (k, &v) in chunk_host[t].usl.iter().enumerate() {
                    usl[t * 4 + k] = v as f32;
                }
            }
            for t in chunk_logs.len()..t_pad {
                usl[t * 4 + 3] = 1.0;
            }

            let outputs = self.engine.run_f32(
                &name,
                &[
                    (x, vec![t_pad as i64, s_pad as i64, K as i64]),
                    (y, vec![t_pad as i64, s_pad as i64]),
                    (phi.clone(), vec![c_pad as i64, K as i64]),
                    (usl, vec![t_pad as i64, 4]),
                    (n.clone(), vec![c_pad as i64]),
                ],
            )?;
            let theta_flat = &outputs[1];
            for (t, host) in chunk_host.iter().enumerate() {
                let mut theta = [0f64; K];
                for (k, th) in theta.iter_mut().enumerate() {
                    *th = theta_flat[t * K + k] as f64;
                }
                fits.push(FittedTask {
                    theta,
                    usl: host.usl,
                    preset_mult: host.preset_mult,
                    family_mult: host.family_mult,
                });
            }
        }

        let grid = self.predict_fitted(&fits, space)?;
        Ok((grid, fits))
    }
}
