//! Event-driven cluster execution simulator — the stand-in for the
//! paper's AWS + Airflow testbed.
//!
//! Takes an optimized plan (config assignment + dispatch order) and plays
//! it against ground truth: actual task runtimes are the profiles'
//! `runtime(config)` with lognormal run noise, so predicted and realized
//! makespans diverge exactly as they would in production. Tasks dispatch
//! like Airflow executors do — a ready task starts as soon as its
//! predecessors finished AND its resources are free, in plan order — so a
//! task overrunning its prediction delays dependents naturally.
//!
//! The simulator also emits fresh event logs per executed task, closing
//! the §4.1 adaptive loop (coordinator feeds them back to the Predictor).
//!
//! `replan` closes that loop *inside* a batch as well: under a
//! [`ReplanPolicy`], injected divergence (stragglers, failures, capacity
//! outages) is detected at realized completions and the not-yet-started
//! cone of the DAG is re-optimized mid-flight (`execute_with_policy`).

pub mod executor;
pub mod replan;

pub use executor::{execute, execute_with_policy, ExecutionReport, TaskRecord};
pub use replan::{
    CapacityOutage, DivergenceSpec, ReplanEvent, ReplanPolicy, TaskDivergence,
};
