//! The execution engine: realize a schedule against ground truth.

use crate::cluster::CostModel;
use crate::dag::Dag;
use crate::predictor::eventlog::{simulate_run, EventLog};
use crate::solver::{Problem, Schedule};
use crate::util::Rng;

/// Execution record for one task.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub task: usize,
    pub config: usize,
    /// When the executor launched the task (actual, not planned).
    pub start: f64,
    /// Actual (noisy) runtime.
    pub runtime: f64,
    /// Predicted runtime from the plan's grid, for error accounting.
    pub predicted: f64,
}

impl TaskRecord {
    pub fn end(&self) -> f64 {
        self.start + self.runtime
    }
}

/// Result of executing one plan.
#[derive(Debug, Clone)]
pub struct ExecutionReport {
    pub records: Vec<TaskRecord>,
    pub makespan: f64,
    pub cost: f64,
    /// Realized per-DAG completion times.
    pub dag_completion: Vec<f64>,
    /// Mean absolute prediction error realized during this execution.
    pub prediction_mape: f64,
    /// Fresh event logs (one per task), for the adaptive feedback loop.
    pub new_logs: Vec<EventLog>,
}

/// Execute a schedule. `dags`/`releases` must be the ones the problem was
/// built from (the simulator needs ground-truth profiles the optimizer
/// never saw). Dispatch: plan order (by planned start, FIFO tie-break);
/// a task launches at the earliest instant when its predecessors have
/// *actually* finished and capacity is free.
pub fn execute(
    p: &Problem,
    dags: &[Dag],
    schedule: &Schedule,
    cost_model: &CostModel,
    rng: &mut Rng,
) -> ExecutionReport {
    let n = p.len();
    assert_eq!(schedule.start.len(), n);

    // Ground-truth profile per flat task.
    let profiles: Vec<_> = p
        .tasks
        .iter()
        .map(|ft| dags[ft.dag].tasks[ft.local].profile.clone())
        .collect();

    // Actual durations + event logs, drawn once up front (deterministic
    // in rng order: flat task order).
    let mut runtimes = Vec::with_capacity(n);
    let mut new_logs = Vec::with_capacity(n);
    for t in 0..n {
        let cfg = p.space.configs[schedule.assignment[t]];
        let (rt, stages) = simulate_run(&profiles[t], cfg, rng);
        runtimes.push(rt);
        let mut log = EventLog::new(&p.tasks[t].name);
        log.record(cfg, rt, stages);
        new_logs.push(log);
    }

    // Dispatch order: planned start, FIFO tie-break.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        schedule.start[a]
            .partial_cmp(&schedule.start[b])
            .unwrap()
            .then(a.cmp(&b))
    });

    // Event-driven placement with the same timeline machinery the
    // schedulers use — but over ACTUAL durations.
    let mut timeline =
        crate::solver::sgs::Timeline::new(p.capacity.vcpus, p.capacity.memory_gb);
    let mut start = vec![f64::NAN; n];
    let mut placed = vec![false; n];

    // Plan order is precedence-consistent for valid schedules, but actual
    // runtimes can reorder finishes; we still launch in plan order,
    // waiting on actual predecessor completion (Airflow semantics).
    let mut remaining: Vec<usize> = order;
    while !remaining.is_empty() {
        // find the first dispatchable task in plan order
        let pos = remaining
            .iter()
            .position(|&t| p.preds(t).iter().all(|&q| placed[q]))
            .expect("valid plans always have a dispatchable task");
        let t = remaining.remove(pos);
        let est = p
            .preds(t)
            .iter()
            .map(|&q| start[q] + runtimes[q])
            .fold(p.release[t], f64::max);
        let (cpu, mem) = p.demand(schedule.assignment[t]);
        let s = timeline.earliest_fit(est, runtimes[t], cpu, mem);
        timeline.place(s, runtimes[t], cpu, mem);
        start[t] = s;
        placed[t] = true;
    }

    let records: Vec<TaskRecord> = (0..n)
        .map(|t| TaskRecord {
            task: t,
            config: schedule.assignment[t],
            start: start[t],
            runtime: runtimes[t],
            predicted: p.duration(t, schedule.assignment[t]),
        })
        .collect();

    let makespan = records.iter().map(|r| r.end()).fold(0.0, f64::max);
    let cost = records
        .iter()
        .map(|r| cost_model.cost(&p.space.configs[r.config], r.runtime))
        .sum();
    let dag_completion = (0..dags.len())
        .map(|d| {
            records
                .iter()
                .filter(|r| p.tasks[r.task].dag == d)
                .map(|r| r.end())
                .fold(0.0, f64::max)
        })
        .collect();
    let prediction_mape = records
        .iter()
        .map(|r| (r.runtime - r.predicted).abs() / r.runtime.max(1e-9))
        .sum::<f64>()
        / n.max(1) as f64;

    ExecutionReport {
        records,
        makespan,
        cost,
        dag_completion,
        prediction_mape,
        new_logs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::solver::cp::{CpSolver, Limits};
    use crate::Predictor;

    fn setup() -> (Problem, Vec<Dag>) {
        let dags = vec![dag1(), dag2()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags
            .iter()
            .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
            .collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let p = Problem::new(
            &dags,
            &[0.0, 0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        );
        (p, dags)
    }

    fn plan(p: &Problem) -> Schedule {
        let c = crate::solver::cooptimizer::Agora::default_config(&p.space);
        let (s, _) = CpSolver::new(Limits::default()).solve(p, &vec![c; p.len()]);
        s
    }

    #[test]
    fn execution_respects_precedence_with_actual_times() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(1);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        for &(a, b) in &p.precedence {
            let ra = &rep.records[a];
            let rb = &rep.records[b];
            assert!(
                rb.start + 1e-6 >= ra.end(),
                "task {b} started before {a} finished"
            );
        }
    }

    #[test]
    fn execution_respects_capacity() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(2);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        for r in &rep.records {
            let at = r.start + 1e-9;
            let mut cpu = 0.0;
            for o in &rep.records {
                if o.start <= at && at < o.end() {
                    cpu += p.space.configs[o.config].vcpus();
                }
            }
            assert!(cpu <= p.capacity.vcpus + 1e-6);
        }
    }

    #[test]
    fn realized_makespan_close_to_predicted_with_oracle_grid() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(3);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        let predicted = s.makespan(&p);
        assert!(
            (rep.makespan - predicted).abs() / predicted < 0.25,
            "actual {} vs predicted {predicted}",
            rep.makespan
        );
        // oracle grid -> only run noise remains
        assert!(rep.prediction_mape < 0.15, "mape {}", rep.prediction_mape);
    }

    #[test]
    fn produces_one_event_log_per_task() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(4);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        assert_eq!(rep.new_logs.len(), p.len());
        assert!(rep.new_logs.iter().all(|l| l.len() == 1));
    }

    #[test]
    fn dag_completions_bounded_by_makespan() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(5);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        assert_eq!(rep.dag_completion.len(), 2);
        for &c in &rep.dag_completion {
            assert!(c <= rep.makespan + 1e-9);
            assert!(c > 0.0);
        }
    }

    #[test]
    fn cost_reflects_actual_runtimes() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(6);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        let manual: f64 = rep
            .records
            .iter()
            .map(|r| {
                p.space.configs[r.config].hourly_cost() * r.runtime / 3600.0
            })
            .sum();
        assert!((rep.cost - manual).abs() < 1e-9);
    }
}
