//! The execution engine: realize a schedule against ground truth.
//!
//! [`execute`] plays a plan open-loop, exactly as the historical
//! implementation did. [`execute_with_policy`] is the event-driven
//! closed-loop variant: it injects configurable divergence (stragglers,
//! failures with retry, capacity outages) from the policy's seeded
//! stream, scans realized completions in time order, and when one
//! diverges from its plan expectation past the policy threshold it
//! commits everything already started and re-optimizes the
//! not-yet-started cone (`sim::replan`), then continues under the new
//! suffix plan. With [`ReplanPolicy::off`] the two entry points are the
//! same code path and bit-identical output.

use crate::cluster::CostModel;
use crate::dag::Dag;
use crate::predictor::eventlog::{simulate_run, EventLog};
use crate::solver::{Problem, Schedule};
use crate::util::Rng;

use super::replan::{replan_suffix, ReplanEvent, ReplanPolicy};

/// Execution record for one task.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    /// Flat task index in the executed problem.
    pub task: usize,
    /// Configuration the task actually ran under (a replan may differ
    /// from the original plan's choice).
    pub config: usize,
    /// When the executor launched the task (actual, not planned).
    pub start: f64,
    /// Actual (noisy, possibly divergence-inflated) runtime.
    pub runtime: f64,
    /// Predicted runtime from the plan's grid, for error accounting.
    pub predicted: f64,
    /// Failed attempts absorbed before the successful run.
    pub retries: u32,
    /// Spot preemptions absorbed (lost in-flight work re-run); 0 on
    /// reliable capacity.
    pub preemptions: u32,
}

impl TaskRecord {
    /// Realized completion instant (start + runtime).
    pub fn end(&self) -> f64 {
        self.start + self.runtime
    }
}

/// Result of executing one plan.
#[derive(Debug, Clone)]
pub struct ExecutionReport {
    /// One record per executed task, in flat task order.
    pub records: Vec<TaskRecord>,
    /// Realized makespan (max record end).
    pub makespan: f64,
    /// Realized dollar cost.
    pub cost: f64,
    /// Realized per-DAG completion times.
    pub dag_completion: Vec<f64>,
    /// Mean absolute prediction error realized during this execution.
    pub prediction_mape: f64,
    /// Fresh event logs (one per task), for the adaptive feedback loop.
    pub new_logs: Vec<EventLog>,
    /// Mid-flight replan provenance (empty when the policy is off or
    /// never triggered).
    pub replans: Vec<ReplanEvent>,
}

/// Execute a schedule open-loop (no injected divergence, no replanning).
/// `dags`/`releases` must be the ones the problem was built from (the
/// simulator needs ground-truth profiles the optimizer never saw).
/// Dispatch: plan order (by planned start, FIFO tie-break); a task
/// launches at the earliest instant when its predecessors have *actually*
/// finished and capacity is free.
pub fn execute(
    p: &Problem,
    dags: &[Dag],
    schedule: &Schedule,
    cost_model: &CostModel,
    rng: &mut Rng,
) -> ExecutionReport {
    execute_with_policy(p, dags, schedule, cost_model, rng, &ReplanPolicy::off())
}

/// Event-driven execution under a [`ReplanPolicy`]: injected divergence
/// plus mid-flight suffix re-planning. See the module docs for the
/// trigger/commit semantics; [`ReplanPolicy::off`] reproduces [`execute`]
/// bit-identically (same RNG stream, same placements).
pub fn execute_with_policy(
    p: &Problem,
    dags: &[Dag],
    schedule: &Schedule,
    cost_model: &CostModel,
    rng: &mut Rng,
    policy: &ReplanPolicy,
) -> ExecutionReport {
    let n = p.len();
    assert_eq!(schedule.start.len(), n);

    // Ground-truth profile per flat task.
    let profiles: Vec<_> = p
        .tasks
        .iter()
        .map(|ft| dags[ft.dag].tasks[ft.local].profile.clone())
        .collect();

    // Actual durations + stage splits, drawn once up front at the planned
    // configurations (deterministic in rng order: flat task order — the
    // same stream as the historical executor).
    let mut assignment: Vec<usize> = schedule.assignment.clone();
    let mut runtimes = Vec::with_capacity(n);
    let mut stages_of = Vec::with_capacity(n);
    for t in 0..n {
        let cfg = p.space.configs[assignment[t]];
        let (rt, stages) = simulate_run(&profiles[t], cfg, rng);
        runtimes.push(rt);
        stages_of.push(stages);
    }

    // Injected divergence from the policy's own seeded stream; with the
    // spec off every modifier is exactly 1.0 and nothing below mutates.
    let divergence = policy.divergence.draw(n);
    for t in 0..n {
        if divergence[t].modifier != 1.0 {
            runtimes[t] *= divergence[t].modifier;
            for s in stages_of[t].iter_mut() {
                s.1 *= divergence[t].modifier;
            }
        }
    }

    // Spot preemptions: a seeded per-task arrival process on every task
    // occupying spot capacity (a spot catalog row, or any row under the
    // global CostModel::Spot ablation). Lost in-flight work is re-run,
    // inflating the realized runtime; the draws use per-task derived
    // streams, so the main rng and the straggler/failure stream are
    // untouched and an off spec leaves every runtime bit-identical.
    let global_spot = matches!(cost_model, CostModel::Spot { .. });
    let mut preemptions = vec![0u32; n];
    let mut spot_mult = vec![1.0f64; n];
    for t in 0..n {
        let cfg = &p.space.configs[assignment[t]];
        let on_spot = global_spot || cfg.is_spot();
        let (mult, hits) =
            policy
                .divergence
                .draw_spot(t, on_spot, cfg.nodes as f64, runtimes[t]);
        if mult != 1.0 {
            runtimes[t] *= mult;
            for s in stages_of[t].iter_mut() {
                s.1 *= mult;
            }
        }
        spot_mult[t] = mult;
        preemptions[t] = hits;
    }

    // Capacity-outage blocker rectangle, if any.
    let outage_rect: Option<(f64, f64, f64, f64)> = policy.divergence.outage.and_then(|o| {
        if o.duration > 0.0 && (o.cpu_fraction > 0.0 || o.mem_fraction > 0.0) {
            Some((
                o.at,
                o.duration,
                p.capacity.vcpus * o.cpu_fraction.clamp(0.0, 1.0),
                p.capacity.memory_gb * o.mem_fraction.clamp(0.0, 1.0),
            ))
        } else {
            None
        }
    });

    // Current plan state: dispatch priority + expected completions.
    let mut plan_start: Vec<f64> = schedule.start.clone();
    let mut expected_end: Vec<f64> = (0..n)
        .map(|t| schedule.start[t] + p.duration(t, assignment[t]))
        .collect();
    let plan_makespan = schedule.makespan(p).max(1e-9);

    let mut committed = vec![false; n];
    let mut checked = vec![false; n];
    let mut start = vec![f64::NAN; n];
    let mut replans: Vec<ReplanEvent> = Vec::new();
    // Replanned tasks can never be dispatched before the replan instant.
    let mut floor = f64::NEG_INFINITY;

    loop {
        // --- (Re)place every not-yet-committed task under the current
        // plan: plan order (planned start, FIFO tie-break), waiting on
        // actual predecessor completion (Airflow semantics), packed with
        // the same block-indexed timeline kernel the schedulers use — but
        // over ACTUAL durations. The occupancy reservations of previously
        // admitted rounds (continuous admission) seed the timeline, so
        // dispatch packs this round's tasks into the residual capacity;
        // the seed is empty for standalone executions.
        let mut timeline = crate::solver::Timeline::seeded(
            p.capacity.vcpus,
            p.capacity.memory_gb,
            &p.preplaced,
        );
        if let Some((at, dur, cpu, mem)) = outage_rect {
            timeline.place(at, dur, cpu, mem);
        }
        for t in 0..n {
            if committed[t] {
                let (cpu, mem) = p.demand(assignment[t]);
                timeline.place(start[t], runtimes[t], cpu, mem);
            }
        }
        let mut remaining: Vec<usize> = (0..n).filter(|&t| !committed[t]).collect();
        remaining.sort_by(|&a, &b| plan_start[a].total_cmp(&plan_start[b]).then(a.cmp(&b)));
        let mut placed = committed.clone();
        while !remaining.is_empty() {
            // find the first dispatchable task in plan order
            let pos = remaining
                .iter()
                .position(|&t| p.preds(t).iter().all(|&q| placed[q]))
                .expect("valid plans always have a dispatchable task");
            let t = remaining.remove(pos);
            let est = p
                .preds(t)
                .iter()
                .map(|&q| start[q] + runtimes[q])
                .fold(p.release[t].max(floor), f64::max);
            let (cpu, mem) = p.demand(assignment[t]);
            let s = timeline
                .earliest_fit(est, runtimes[t], cpu, mem)
                .expect("planned/replanned configurations draw from Problem::feasible");
            timeline.place(s, runtimes[t], cpu, mem);
            start[t] = s;
            placed[t] = true;
        }

        // --- Scan realized completions in time order for a divergence
        // trigger. Events before the firing instant have truly happened
        // (their tasks started earlier still), so marking them checked is
        // causally sound.
        let mut fired = false;
        if replans.len() < policy.max_replans {
            let mut events: Vec<usize> = (0..n).filter(|&t| !checked[t]).collect();
            events.sort_by(|&a, &b| {
                let ea = start[a] + runtimes[a];
                let eb = start[b] + runtimes[b];
                ea.total_cmp(&eb).then(a.cmp(&b))
            });
            for &t in &events {
                let end_t = start[t] + runtimes[t];
                let div = (end_t - expected_end[t]) / plan_makespan;
                checked[t] = true;
                // Deadline-at-risk trigger (armed by `sla_spot_penalty`):
                // even below the divergence threshold, a completion in a
                // DAG whose projected finish now misses its bounded SLA
                // deadline fires a replan, so the suffix search can
                // migrate the at-risk cone off spot capacity.
                let deadline_risk = policy.sla_spot_penalty > 0.0 && {
                    let d = p.tasks[t].dag;
                    let sla = &p.slas[d];
                    !sla.is_unbounded() && {
                        let projected = (0..n)
                            .filter(|&u| p.tasks[u].dag == d)
                            .map(|u| start[u] + runtimes[u])
                            .fold(0.0, f64::max);
                        projected > sla.deadline
                    }
                };
                if div <= policy.threshold && !deadline_risk {
                    continue;
                }

                // Trigger at this completion: freeze everything already
                // started, re-optimize the cone that has not.
                let tnow = end_t;
                for u in 0..n {
                    if !committed[u] && start[u] < tnow - 1e-9 {
                        committed[u] = true;
                    }
                }
                let active: Vec<usize> = (0..n).filter(|&u| !committed[u]).collect();
                if active.is_empty() {
                    // Everything is already running or done; nothing a
                    // replan could change, now or at any later event.
                    break;
                }
                // Committed work enters the replanning context below with
                // its realized rectangle, so its eventual completion
                // carries no new information — it must not burn another
                // replan out of the budget.
                for u in 0..n {
                    if committed[u] {
                        checked[u] = true;
                    }
                }

                let mut preplaced: Vec<(f64, f64, f64, f64)> = Vec::new();
                if let Some(r) = outage_rect {
                    preplaced.push(r);
                }
                for u in 0..n {
                    if committed[u] {
                        let (cpu, mem) = p.demand(assignment[u]);
                        preplaced.push((start[u], runtimes[u], cpu, mem));
                    }
                }
                let fixed_end: Vec<f64> = (0..n)
                    .map(|u| {
                        if committed[u] {
                            start[u] + runtimes[u]
                        } else {
                            f64::NAN
                        }
                    })
                    .collect();
                let stale_makespan = (0..n)
                    .map(|u| start[u] + runtimes[u])
                    .fold(0.0, f64::max);

                let suffix = replan_suffix(
                    p,
                    &assignment,
                    &active,
                    tnow,
                    &fixed_end,
                    &preplaced,
                    policy,
                    replans.len() + 1,
                );

                // Adopt the suffix plan: new configurations (fresh draws
                // for changed ones — same task, new machine shape), new
                // dispatch priorities and expectations for the cone.
                let mut reassigned = 0usize;
                for &u in &active {
                    if suffix.assignment[u] != assignment[u] {
                        reassigned += 1;
                        assignment[u] = suffix.assignment[u];
                        let cfg = p.space.configs[assignment[u]];
                        let (rt, mut stages) = simulate_run(&profiles[u], cfg, rng);
                        runtimes[u] = rt * divergence[u].modifier;
                        if divergence[u].modifier != 1.0 {
                            for s in stages.iter_mut() {
                                s.1 *= divergence[u].modifier;
                            }
                        }
                        stages_of[u] = stages;
                        // The new machine shape changes the task's spot
                        // exposure: re-draw its preemption realization
                        // (the per-task stream keeps this deterministic
                        // and leaves every other task untouched).
                        let on_spot = global_spot || cfg.is_spot();
                        let (mult, hits) = policy.divergence.draw_spot(
                            u,
                            on_spot,
                            cfg.nodes as f64,
                            runtimes[u],
                        );
                        if mult != 1.0 {
                            runtimes[u] *= mult;
                            for s in stages_of[u].iter_mut() {
                                s.1 *= mult;
                            }
                        }
                        spot_mult[u] = mult;
                        preemptions[u] = hits;
                    }
                    plan_start[u] = suffix.start[u];
                    expected_end[u] = suffix.start[u] + p.duration(u, assignment[u]);
                }
                replans.push(ReplanEvent {
                    round: replans.len() + 1,
                    trigger_task: t,
                    at: tnow,
                    divergence: div,
                    replanned: active.len(),
                    reassigned,
                    stale_makespan,
                    planned_makespan: suffix.makespan,
                });
                floor = tnow;
                fired = true;
                break;
            }
        }
        if !fired {
            break;
        }
    }

    let records: Vec<TaskRecord> = (0..n)
        .map(|t| TaskRecord {
            task: t,
            config: assignment[t],
            start: start[t],
            runtime: runtimes[t],
            predicted: p.duration(t, assignment[t]),
            retries: divergence[t].retries,
            preemptions: preemptions[t],
        })
        .collect();

    // Event logs carry the configuration each task actually ran under
    // and its PRODUCTIVE runtime: spot-preemption re-run inflation is
    // divided back out before feedback, because the planner prices that
    // risk separately (Problem::new re-inflates predicted spot rows by
    // the expected overhead) — feeding inflated observations to the
    // predictor would double-count it round over round. Straggler/retry
    // inflation stays in, as before: those are genuine observed runs.
    let new_logs: Vec<EventLog> = (0..n)
        .map(|t| {
            let mut log = EventLog::new(&p.tasks[t].name);
            let (rt, stages) = if spot_mult[t] != 1.0 {
                let m = spot_mult[t];
                (
                    runtimes[t] / m,
                    stages_of[t]
                        .iter()
                        .map(|(name, secs)| (name.clone(), secs / m))
                        .collect(),
                )
            } else {
                (runtimes[t], stages_of[t].clone())
            };
            log.record(p.space.configs[assignment[t]], rt, stages);
            log
        })
        .collect();

    let makespan = records.iter().map(|r| r.end()).fold(0.0, f64::max);
    // Realized accounting: pay for the capacity actually held (re-runs
    // are already inside the realized runtimes — the planner-side
    // expectation term of CostModel::Spot must not double-charge them).
    let cost = records
        .iter()
        .map(|r| cost_model.realized_cost(&p.space.configs[r.config], r.runtime))
        .sum();
    let dag_completion = (0..dags.len())
        .map(|d| {
            records
                .iter()
                .filter(|r| p.tasks[r.task].dag == d)
                .map(|r| r.end())
                .fold(0.0, f64::max)
        })
        .collect();
    let prediction_mape = mean_absolute_prediction_error(&records);

    ExecutionReport {
        records,
        makespan,
        cost,
        dag_completion,
        prediction_mape,
        new_logs,
        replans,
    }
}

/// Mean absolute prediction error over the executed records, guarded
/// against degenerate inputs: empty record sets, non-finite values, and
/// zero/near-zero runtimes or predictions cannot produce inf/NaN in
/// reports (each term is floored at a 1e-9 denominator and clamped).
fn mean_absolute_prediction_error(records: &[TaskRecord]) -> f64 {
    if records.is_empty() {
        return 0.0;
    }
    let sum: f64 = records
        .iter()
        .map(|r| {
            if !r.predicted.is_finite() || !r.runtime.is_finite() {
                return 0.0;
            }
            ((r.runtime - r.predicted).abs() / r.runtime.max(1e-9)).min(1e6)
        })
        .sum();
    sum / records.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::sim::replan::DivergenceSpec;
    use crate::solver::cp::{CpSolver, Limits};
    use crate::Predictor;

    fn setup() -> (Problem, Vec<Dag>) {
        let dags = vec![dag1(), dag2()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags
            .iter()
            .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
            .collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let p = Problem::new(
            &dags,
            &[0.0, 0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        );
        (p, dags)
    }

    fn plan(p: &Problem) -> Schedule {
        let c = crate::solver::cooptimizer::Agora::default_config(&p.space);
        let (s, _) = CpSolver::new(Limits::default())
            .solve(p, &vec![c; p.len()])
            .unwrap();
        s
    }

    #[test]
    fn execution_respects_precedence_with_actual_times() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(1);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        for &(a, b) in &p.precedence {
            let ra = &rep.records[a];
            let rb = &rep.records[b];
            assert!(
                rb.start + 1e-6 >= ra.end(),
                "task {b} started before {a} finished"
            );
        }
    }

    #[test]
    fn execution_respects_capacity() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(2);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        for r in &rep.records {
            let at = r.start + 1e-9;
            let mut cpu = 0.0;
            for o in &rep.records {
                if o.start <= at && at < o.end() {
                    cpu += p.space.configs[o.config].vcpus();
                }
            }
            assert!(cpu <= p.capacity.vcpus + 1e-6);
        }
    }

    #[test]
    fn realized_makespan_close_to_predicted_with_oracle_grid() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(3);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        let predicted = s.makespan(&p);
        assert!(
            (rep.makespan - predicted).abs() / predicted < 0.25,
            "actual {} vs predicted {predicted}",
            rep.makespan
        );
        // oracle grid -> only run noise remains
        assert!(rep.prediction_mape < 0.15, "mape {}", rep.prediction_mape);
    }

    #[test]
    fn produces_one_event_log_per_task() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(4);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        assert_eq!(rep.new_logs.len(), p.len());
        assert!(rep.new_logs.iter().all(|l| l.len() == 1));
    }

    #[test]
    fn dag_completions_bounded_by_makespan() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(5);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        assert_eq!(rep.dag_completion.len(), 2);
        for &c in &rep.dag_completion {
            assert!(c <= rep.makespan + 1e-9);
            assert!(c > 0.0);
        }
    }

    #[test]
    fn cost_reflects_actual_runtimes() {
        let (p, dags) = setup();
        let s = plan(&p);
        let mut rng = Rng::new(6);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        let manual: f64 = rep
            .records
            .iter()
            .map(|r| {
                p.space.configs[r.config].hourly_cost() * r.runtime / 3600.0
            })
            .sum();
        assert!((rep.cost - manual).abs() < 1e-9);
    }

    #[test]
    fn off_policy_is_bit_identical_to_execute() {
        let (p, dags) = setup();
        let s = plan(&p);
        let a = execute(&p, &dags, &s, &CostModel::OnDemand, &mut Rng::new(9));
        let b = execute_with_policy(
            &p,
            &dags,
            &s,
            &CostModel::OnDemand,
            &mut Rng::new(9),
            &ReplanPolicy::off(),
        );
        assert!(b.replans.is_empty());
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.prediction_mape, b.prediction_mape);
        for (x, y) in a.records.iter().zip(b.records.iter()) {
            assert_eq!(x.task, y.task);
            assert_eq!(x.config, y.config);
            assert_eq!(x.start, y.start);
            assert_eq!(x.runtime, y.runtime);
            assert_eq!(x.predicted, y.predicted);
            assert_eq!(x.retries, 0);
            assert_eq!(y.retries, 0);
        }
    }

    #[test]
    fn straggler_injection_inflates_the_straggling_task() {
        let (p, dags) = setup();
        let s = plan(&p);
        let base = execute(&p, &dags, &s, &CostModel::OnDemand, &mut Rng::new(11));
        let policy = ReplanPolicy {
            divergence: DivergenceSpec {
                straggler_tasks: vec![0],
                straggler_factor: 4.0,
                ..Default::default()
            },
            ..ReplanPolicy::off()
        };
        let hit = execute_with_policy(
            &p,
            &dags,
            &s,
            &CostModel::OnDemand,
            &mut Rng::new(11),
            &policy,
        );
        // Same base draws (same stream), inflated by exactly the factor.
        assert!(
            (hit.records[0].runtime - 4.0 * base.records[0].runtime).abs() < 1e-9,
            "straggler runtime {} vs base {}",
            hit.records[0].runtime,
            base.records[0].runtime
        );
        // (No makespan-monotonicity assertion: list-scheduling anomalies
        // can legitimately shrink the packed makespan when one task
        // grows.) The straggler's own completion is monotone:
        assert!(hit.records[0].end() > base.records[0].end());
        assert!(hit.makespan >= hit.records[0].end() - 1e-9);
    }

    #[test]
    fn failed_task_records_one_retry() {
        let (p, dags) = setup();
        let s = plan(&p);
        let base = execute(&p, &dags, &s, &CostModel::OnDemand, &mut Rng::new(12));
        let policy = ReplanPolicy {
            divergence: DivergenceSpec {
                fail_tasks: vec![3],
                ..Default::default()
            },
            ..ReplanPolicy::off()
        };
        let hit = execute_with_policy(
            &p,
            &dags,
            &s,
            &CostModel::OnDemand,
            &mut Rng::new(12),
            &policy,
        );
        assert_eq!(hit.records[3].retries, 1);
        assert!(hit.records.iter().enumerate().all(|(t, r)| t == 3 || r.retries == 0));
        // Wasted attempt inflates runtime by 20-80%.
        let ratio = hit.records[3].runtime / base.records[3].runtime;
        assert!((1.2..=1.8).contains(&ratio), "retry ratio {ratio}");
    }

    #[test]
    fn pinned_spot_preemption_inflates_by_exactly_half_a_run() {
        let (p, dags) = setup();
        let s = plan(&p);
        let base = execute(&p, &dags, &s, &CostModel::OnDemand, &mut Rng::new(21));
        let policy = ReplanPolicy {
            divergence: DivergenceSpec {
                spot_tasks: vec![2],
                ..Default::default()
            },
            ..ReplanPolicy::off()
        };
        let hit = execute_with_policy(
            &p,
            &dags,
            &s,
            &CostModel::OnDemand,
            &mut Rng::new(21),
            &policy,
        );
        assert_eq!(hit.records[2].preemptions, 1);
        assert!(
            (hit.records[2].runtime - 1.5 * base.records[2].runtime).abs() < 1e-9,
            "preempted runtime {} vs base {}",
            hit.records[2].runtime,
            base.records[2].runtime
        );
        assert!(hit
            .records
            .iter()
            .all(|r| r.task == 2 || r.preemptions == 0));
        // Predictor feedback carries the PRODUCTIVE runtime (re-run
        // inflation excluded — the cost model prices it separately), so
        // the adaptive loop cannot double-count spot risk.
        assert!(
            (hit.new_logs[2].runs[0].runtime - base.records[2].runtime).abs() < 1e-9,
            "fed-back runtime {} should be the productive {}",
            hit.new_logs[2].runs[0].runtime,
            base.records[2].runtime
        );
    }

    #[test]
    fn global_spot_model_realizes_preemptions_and_charges_occupancy() {
        // Under the global Spot ablation every node is spot: the seeded
        // interruption process fires, and the realized cost is exactly
        // discount x price x realized occupancy (re-runs included, no
        // double-charged expectation term).
        let (p, dags) = setup();
        let s = plan(&p);
        let model = CostModel::Spot {
            discount: 0.3,
            interrupt_rate: 2.0,
        };
        let policy = ReplanPolicy {
            divergence: DivergenceSpec {
                spot_rate: 2.0,
                seed: 23,
                ..Default::default()
            },
            ..ReplanPolicy::off()
        };
        let rep = execute_with_policy(&p, &dags, &s, &model, &mut Rng::new(22), &policy);
        let manual: f64 = rep
            .records
            .iter()
            .map(|r| p.space.configs[r.config].hourly_cost() * 0.3 * r.runtime / 3600.0)
            .sum();
        assert!((rep.cost - manual).abs() < 1e-9);
        // At rate 2/node-hour on 8-node configs, the batch sees
        // preemptions with overwhelming probability (seeded, so stable).
        let total: u32 = rep.records.iter().map(|r| r.preemptions).sum();
        assert!(total >= 1, "expected at least one preemption, got {total}");
        assert!(rep.records.iter().all(|r| r.preemptions <= 2));
    }

    #[test]
    fn execution_packs_around_admission_reservations() {
        // A full-capacity reservation over [0, 100) (another round's
        // in-flight work under continuous admission): no task of this
        // round may launch inside it, with or without divergence.
        let (p, dags) = setup();
        let cap = p.capacity;
        let p = p.with_occupancy(vec![(0.0, 100.0, cap.vcpus, cap.memory_gb)], 100.0);
        let s = plan(&p);
        let mut rng = Rng::new(7);
        let rep = execute(&p, &dags, &s, &CostModel::OnDemand, &mut rng);
        for r in &rep.records {
            assert!(
                r.start + 1e-9 >= 100.0,
                "task {} launched at {} inside the reservation",
                r.task,
                r.start
            );
        }
        assert!(rep.makespan >= 100.0);
    }

    #[test]
    fn mape_guard_handles_degenerate_records() {
        assert_eq!(mean_absolute_prediction_error(&[]), 0.0);
        let records = vec![
            TaskRecord {
                task: 0,
                config: 0,
                start: 0.0,
                runtime: 0.0,
                predicted: 0.0,
                retries: 0,
                preemptions: 0,
            },
            TaskRecord {
                task: 1,
                config: 0,
                start: 0.0,
                runtime: 10.0,
                predicted: f64::NAN,
                retries: 0,
                preemptions: 0,
            },
            TaskRecord {
                task: 2,
                config: 0,
                start: 0.0,
                runtime: 1e-12,
                predicted: f64::INFINITY,
                retries: 0,
                preemptions: 0,
            },
        ];
        let mape = mean_absolute_prediction_error(&records);
        assert!(mape.is_finite(), "mape must stay finite, got {mape}");
        assert!(mape >= 0.0);
    }
}
