//! Mid-flight re-planning: divergence injection, the replan policy, and
//! the suffix re-optimizer that closes the §4.1 loop *inside* a batch.
//!
//! The executor feeds realized completions back between batches (the
//! coordinator's adaptive loop), but a plan that is already dispatched
//! used to run open-loop: a straggling or failed task silently blew the
//! makespan. [`ReplanPolicy`] arms the executor with a trigger — a
//! completion diverging from its plan expectation by more than a
//! threshold fraction of the plan makespan — and a response: re-optimize
//! the *not-yet-started cone* of the DAG (configurations + packing) with
//! the [`SuffixSgs`](crate::solver::sgs::SuffixSgs) cone evaluator and a
//! small memoized annealing search, then continue executing the new
//! suffix plan. Committed work is never rewritten.
//!
//! Divergence itself is injected from a dedicated seeded [`Rng`] stream
//! ([`DivergenceSpec`]), so scenario replay is exact and the main
//! execution stream is untouched — with the spec off, the executor is
//! bit-identical to the historical (pre-replanning) implementation.

use std::collections::HashMap;

use crate::solver::cooptimizer::per_task_best;
use crate::solver::sgs::SuffixSgs;
use crate::solver::{Goal, Problem};
use crate::util::Rng;

/// A capacity-loss window: the cluster loses a slice of its resources
/// (instance failure, preemption wave) for `duration` seconds starting at
/// `at`. Modeled as a blocker rectangle on the execution timeline, so
/// both dispatch and replanning pack around it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CapacityOutage {
    /// Outage start (virtual seconds from batch start).
    pub at: f64,
    /// Outage length in seconds; <= 0 disables the outage.
    pub duration: f64,
    /// Fraction of cluster vCPUs lost during the window, in [0, 1].
    pub cpu_fraction: f64,
    /// Fraction of cluster memory lost during the window, in [0, 1].
    pub mem_fraction: f64,
}

/// Divergence injected into an execution, drawn from a seeded [`Rng`]
/// stream independent of the main execution stream.
#[derive(Debug, Clone, PartialEq)]
pub struct DivergenceSpec {
    /// Per-task probability of straggling.
    pub straggler_prob: f64,
    /// Runtime multiplier applied to straggling tasks (>= 1).
    pub straggler_factor: f64,
    /// Flat task indices that straggle unconditionally (pinned scenarios).
    pub straggler_tasks: Vec<usize>,
    /// Per-task probability of one failed attempt (followed by a retry
    /// that succeeds; the wasted partial attempt inflates the runtime).
    pub fail_prob: f64,
    /// Flat task indices that fail once unconditionally.
    pub fail_tasks: Vec<usize>,
    /// Optional capacity-loss window.
    pub outage: Option<CapacityOutage>,
    /// Spot-market interruption intensity: expected preemptions per
    /// **spot node-hour** (0 = spot capacity never reclaimed). Realized
    /// as a seeded Poisson arrival process per spot task — each
    /// preemption loses the in-flight work (a uniform fraction of the
    /// run) which is re-run, matching the closed-form expectation of
    /// [`CostModel::Spot`](crate::cluster::CostModel) /
    /// [`expected_spot_overhead`](crate::cluster::expected_spot_overhead).
    pub spot_rate: f64,
    /// Cap on charged preemptions per task (the coordinator falls back
    /// to stable capacity afterwards). Defaults to the canonical
    /// [`SPOT_PREEMPTION_CAP`](crate::cluster::cost::SPOT_PREEMPTION_CAP)
    /// the cost model's closed form always prices; the differential test
    /// in tests/market.rs pins the two against each other. A different
    /// value here is an executor-side stress knob: realized costs then
    /// deliberately diverge from the priced expectation.
    pub spot_max: u32,
    /// Flat task indices preempted exactly once unconditionally, losing
    /// exactly half the run (the expected loss) — pinned scenarios.
    pub spot_tasks: Vec<usize>,
    /// Seed of the divergence stream.
    pub seed: u64,
}

impl Default for DivergenceSpec {
    fn default() -> Self {
        DivergenceSpec {
            straggler_prob: 0.0,
            straggler_factor: 4.0,
            straggler_tasks: Vec::new(),
            fail_prob: 0.0,
            fail_tasks: Vec::new(),
            outage: None,
            spot_rate: 0.0,
            spot_max: crate::cluster::cost::SPOT_PREEMPTION_CAP,
            spot_tasks: Vec::new(),
            seed: 0xD117,
        }
    }
}

/// Divergence drawn for one task.
#[derive(Debug, Clone, Copy)]
pub struct TaskDivergence {
    /// Multiplier on the task's ground-truth runtime (>= 1).
    pub modifier: f64,
    /// Whether the straggler draw fired for this task.
    pub straggled: bool,
    /// Failed attempts before the successful run.
    pub retries: u32,
}

impl DivergenceSpec {
    /// Whether the spec injects nothing at all.
    pub fn is_off(&self) -> bool {
        self.straggler_prob <= 0.0
            && self.straggler_tasks.is_empty()
            && self.fail_prob <= 0.0
            && self.fail_tasks.is_empty()
            && self.outage.is_none()
            && self.spot_rate <= 0.0
            && self.spot_tasks.is_empty()
    }

    /// Realize the spot-preemption process for one task: returns the
    /// runtime multiplier (1 + re-run work, one uniform fraction of the
    /// run per preemption) and the number of charged preemptions
    /// (capped at [`spot_max`](DivergenceSpec::spot_max)).
    ///
    /// `on_spot` says whether the task actually occupies spot capacity
    /// (a spot catalog row, or any row under the global
    /// `CostModel::Spot` ablation); `nodes` scales the arrival
    /// intensity (any reclaimed node of the gang preempts the task);
    /// `runtime` is the productive runtime exposed to the market.
    ///
    /// Draws come from a per-`(seed, task)` derived stream — independent
    /// of the main execution stream and of draw *order*, so a mid-flight
    /// replan that re-draws a reassigned task perturbs nothing else and
    /// seeded executions stay bit-reproducible.
    pub fn draw_spot(
        &self,
        task: usize,
        on_spot: bool,
        nodes: f64,
        runtime: f64,
    ) -> (f64, u32) {
        // `spot_max == 0` disables realized preemptions entirely (pins
        // included): `preemptions <= spot_max` holds unconditionally.
        let cap = self.spot_max;
        if cap == 0 {
            return (1.0, 0);
        }
        let mut multiplier = 1.0f64;
        let mut preemptions = 0u32;
        if self.spot_tasks.contains(&task) {
            // Pinned preemption: lose exactly the expected half-run.
            multiplier += 0.5;
            preemptions = 1;
        }
        if on_spot && self.spot_rate > 0.0 && runtime > 0.0 && preemptions < cap {
            let lambda = self.spot_rate * nodes * runtime / 3600.0;
            let mut rng = Rng::new(spot_stream_seed(self.seed, task));
            // Poisson arrivals via unit-exponential inter-arrival sums;
            // stop at the cap (only min(N, cap) is ever charged).
            let mut acc = 0.0f64;
            while preemptions < cap {
                acc += rng.exponential(1.0);
                if acc > lambda {
                    break;
                }
                // Work since the last checkpoint is lost and re-run: a
                // uniform fraction of the run, half in expectation.
                multiplier += rng.f64();
                preemptions += 1;
            }
        }
        (multiplier, preemptions)
    }

    /// Per-task runtime modifiers, drawn in flat task order from the
    /// spec's own seeded stream.
    pub fn draw(&self, n: usize) -> Vec<TaskDivergence> {
        let mut rng = Rng::new(self.seed);
        (0..n)
            .map(|t| {
                let straggled = self.straggler_tasks.contains(&t)
                    || (self.straggler_prob > 0.0 && rng.chance(self.straggler_prob));
                let failed = self.fail_tasks.contains(&t)
                    || (self.fail_prob > 0.0 && rng.chance(self.fail_prob));
                let mut modifier = 1.0;
                let mut retries = 0;
                if straggled {
                    modifier *= self.straggler_factor.max(1.0);
                }
                if failed {
                    // The first attempt dies partway through; the retry
                    // runs to completion, so the wasted fraction stacks
                    // on top of the full runtime.
                    modifier *= 1.0 + rng.uniform(0.2, 0.8);
                    retries = 1;
                }
                TaskDivergence {
                    modifier,
                    straggled,
                    retries,
                }
            })
            .collect()
    }
}

/// When and how the executor re-plans mid-flight.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplanPolicy {
    /// Trigger: a completion whose (realized - expected) end exceeds this
    /// fraction of the plan makespan fires a replan.
    pub threshold: f64,
    /// Maximum suffix re-optimizations per execution; 0 disables
    /// replanning entirely.
    pub max_replans: usize,
    /// Annealing iterations per suffix re-optimization.
    pub iters: usize,
    /// Objective of the suffix re-optimization (default: recover
    /// runtime — the divergence already blew the makespan).
    pub goal: Goal,
    /// Seed of the replan search stream.
    pub seed: u64,
    /// Divergence injected into the execution.
    pub divergence: DivergenceSpec,
    /// Deadline-at-risk spot migration: energy surcharge per cone task
    /// left on a **spot** row when its DAG's projected completion under
    /// the incumbent continuation already misses a bounded SLA deadline
    /// ([`crate::solver::Problem::slas`]). Any positive value dominates
    /// the O(1) normalized cost/makespan terms, so the search flips
    /// at-risk tasks to on-demand capacity whenever an on-demand row is
    /// feasible. 0.0 (the default) disables the rule — replanning is
    /// then bit-identical to the SLA-blind search.
    pub sla_spot_penalty: f64,
    /// Order the replan cone troublesome-first: the suffix evaluator
    /// packs the cone with [`Rule::Troublesome`] (DAGPS subgraph boosts
    /// over criticality) instead of plain [`Rule::CriticalPath`], so
    /// at-risk heavy subgraphs grab residual capacity before filler
    /// tasks. `false` (the default) keeps the historical criticality
    /// order, bit-identical.
    ///
    /// [`Rule::Troublesome`]: crate::solver::sgs::Rule::Troublesome
    /// [`Rule::CriticalPath`]: crate::solver::sgs::Rule::CriticalPath
    pub troublesome_cone: bool,
}

impl Default for ReplanPolicy {
    fn default() -> Self {
        ReplanPolicy {
            threshold: 0.2,
            max_replans: 0,
            iters: 200,
            goal: Goal::Runtime,
            seed: 0x2EF1A,
            divergence: DivergenceSpec::default(),
            sla_spot_penalty: 0.0,
            troublesome_cone: false,
        }
    }
}

impl ReplanPolicy {
    /// Fully inert policy: no injected divergence, no replanning. The
    /// executor reproduces the historical behaviour bit-identically.
    pub fn off() -> ReplanPolicy {
        ReplanPolicy::default()
    }

    /// Whether the policy neither injects divergence nor replans.
    pub fn is_off(&self) -> bool {
        self.max_replans == 0 && self.divergence.is_off()
    }

    /// Per-round policy for multi-round coordinators: same knobs,
    /// decorrelated seed streams (round 0 keeps the base seeds). Without
    /// this, probabilistic divergence would replay the identical pattern
    /// every batch round, biasing macro comparisons.
    pub fn for_round(&self, round: u64) -> ReplanPolicy {
        let mut p = self.clone();
        p.seed = round_seed(self.seed, round as usize);
        p.divergence.seed = round_seed(self.divergence.seed, round as usize);
        p
    }
}

/// Provenance of one mid-flight replan, carried on the execution report
/// so benches and the service can quantify adaptation gains.
#[derive(Debug, Clone)]
pub struct ReplanEvent {
    /// 1-based replan round within this execution.
    pub round: usize,
    /// Flat task whose divergent completion fired the trigger.
    pub trigger_task: usize,
    /// Virtual time of the trigger (the task's realized completion).
    pub at: f64,
    /// Relative divergence that fired:
    /// (realized end - expected end) / plan makespan.
    pub divergence: f64,
    /// Tasks in the re-optimized cone.
    pub replanned: usize,
    /// Cone tasks whose configuration the replan changed.
    pub reassigned: usize,
    /// Projected makespan had execution continued on the stale plan.
    pub stale_makespan: f64,
    /// Predicted makespan of the adopted suffix plan (committed work
    /// included).
    pub planned_makespan: f64,
}

/// The suffix plan a replan adopts.
#[derive(Debug, Clone)]
pub struct SuffixPlan {
    /// Full-length assignment vector; entries outside the cone are the
    /// incumbent's.
    pub assignment: Vec<usize>,
    /// Full-length planned-start vector; only cone entries meaningful.
    pub start: Vec<f64>,
    /// Predicted makespan over committed work plus the cone.
    pub makespan: f64,
}

/// Deterministic per-round replan seed (SplitMix64 increment, mirroring
/// `solver::anneal::chain_seed`).
fn round_seed(seed: u64, round: usize) -> u64 {
    seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(round as u64))
}

/// Per-(seed, task) stream for the spot-preemption process: salted so it
/// never collides with the straggler/failure stream seeded directly from
/// `DivergenceSpec::seed`.
fn spot_stream_seed(seed: u64, task: usize) -> u64 {
    round_seed(seed ^ 0x5B07_AB1E_0000_0001, task.wrapping_add(1))
}

/// Evaluate one cone assignment: (projected makespan, cone cost), memoized
/// so the annealing walk never pays twice for a revisited assignment.
fn eval_candidate(
    p: &Problem,
    active: &[usize],
    committed_peak: f64,
    sgs: &mut SuffixSgs,
    memo: &mut HashMap<Vec<usize>, (f64, f64)>,
    assignment: &[usize],
) -> (f64, f64) {
    if let Some(&hit) = memo.get(assignment) {
        return hit;
    }
    let makespan = sgs.evaluate(p, assignment).max(committed_peak);
    let cost: f64 = active.iter().map(|&t| p.cost(t, assignment[t])).sum();
    memo.insert(assignment.to_vec(), (makespan, cost));
    (makespan, cost)
}

/// Re-optimize the not-yet-started cone at a replan trigger.
///
/// The search seeds from the incumbent assignment *and* the per-task-best
/// assignment for the policy goal, keeps the best plan ever evaluated,
/// and refines with a short mostly-greedy annealing walk over cone
/// configurations (memoized, suffix-incremental evaluation). The result
/// is therefore never predicted-worse than continuing the incumbent
/// suffix as-scheduled by the same evaluator.
#[allow(clippy::too_many_arguments)]
pub fn replan_suffix(
    p: &Problem,
    incumbent: &[usize],
    active: &[usize],
    floor: f64,
    fixed_end: &[f64],
    preplaced: &[(f64, f64, f64, f64)],
    policy: &ReplanPolicy,
    round: usize,
) -> SuffixPlan {
    let cone_rule = if policy.troublesome_cone {
        crate::solver::sgs::Rule::Troublesome
    } else {
        crate::solver::sgs::Rule::CriticalPath
    };
    let mut sgs = SuffixSgs::with_rule(
        p,
        incumbent,
        active,
        floor,
        fixed_end,
        preplaced,
        cone_rule,
    );
    let committed_peak = preplaced
        .iter()
        .map(|&(s, d, _, _)| s + d)
        .fold(floor, f64::max);
    let mut memo: HashMap<Vec<usize>, (f64, f64)> = HashMap::new();

    // Incumbent continuation: the scale-free reference for the blend.
    let mut best = incumbent.to_vec();
    let (m0, c0) = eval_candidate(p, active, committed_peak, &mut sgs, &mut memo, &best);

    // Deadline-at-risk detection (armed by `sla_spot_penalty`): per-DAG
    // projected completion under the incumbent continuation — committed
    // ends plus the cone evaluator's placement, which `sgs` still holds
    // from the incumbent evaluation above. A DAG already projected past
    // its bounded deadline marks its cone tasks at-risk.
    let mut in_cone = vec![false; p.len()];
    for &t in active {
        in_cone[t] = true;
    }
    let mut at_risk = vec![false; p.slas.len()];
    if policy.sla_spot_penalty > 0.0 {
        let mut completion = vec![0.0f64; p.slas.len()];
        for t in 0..p.len() {
            let end = if in_cone[t] {
                sgs.start_of(t) + p.duration(t, incumbent[t])
            } else {
                fixed_end[t]
            };
            let d = p.tasks[t].dag;
            completion[d] = completion[d].max(end);
        }
        for (d, sla) in p.slas.iter().enumerate() {
            at_risk[d] = !sla.is_unbounded() && completion[d] > sla.deadline;
        }
    }
    // Energy surcharge: each at-risk cone task still on a spot row pays
    // `sla_spot_penalty`. Returns exactly 0.0 when the rule is off, so
    // `energy + surcharge` is bit-identical to the SLA-blind search
    // (the blend terms are non-negative).
    let surcharge = |assignment: &[usize]| -> f64 {
        if policy.sla_spot_penalty <= 0.0 {
            return 0.0;
        }
        active
            .iter()
            .filter(|&&t| at_risk[p.tasks[t].dag] && p.config(assignment[t]).is_spot())
            .count() as f64
            * policy.sla_spot_penalty
    };

    let base_m = m0.max(1e-9);
    let base_c = c0.max(1e-9);
    let w = policy.goal.weight();
    let energy = |m: f64, c: f64| w * m / base_m + (1.0 - w) * c / base_c;
    let mut best_e = energy(m0, c0) + surcharge(&best);

    // Per-task-best candidate (what a task-local optimizer would pick for
    // the goal) — a strong, deterministic lower anchor for the search.
    let ptb = per_task_best(p, policy.goal);
    let mut cand = incumbent.to_vec();
    for &t in active {
        cand[t] = ptb[t];
    }
    let (m1, c1) = eval_candidate(p, active, committed_peak, &mut sgs, &mut memo, &cand);
    let e1 = energy(m1, c1) + surcharge(&cand);
    let (mut cur, mut cur_e) = if e1 < best_e {
        best = cand.clone();
        best_e = e1;
        (cand, e1)
    } else {
        (best.clone(), best_e)
    };

    // Deadline-repair candidate: with the spot surcharge armed and some
    // DAG at risk, seed the search with at-risk cone tasks flipped to
    // their cheapest **on-demand** row. Deterministic — under a
    // cost-weighted goal this is the surcharge-free optimum, so the
    // spot→on-demand migration never hinges on the SA walk proposing it.
    if at_risk.iter().any(|&r| r) {
        let mut repair = best.clone();
        for &t in active {
            if !at_risk[p.tasks[t].dag] {
                continue;
            }
            if let Some(&c) = p
                .feasible
                .iter()
                .filter(|&&c| !p.config(c).is_spot())
                .min_by(|&&a, &&b| p.cost(t, a).total_cmp(&p.cost(t, b)))
            {
                repair[t] = c;
            }
        }
        let (m2, c2) =
            eval_candidate(p, active, committed_peak, &mut sgs, &mut memo, &repair);
        let e2 = energy(m2, c2) + surcharge(&repair);
        if e2 < best_e {
            best = repair.clone();
            best_e = e2;
            cur = repair;
            cur_e = e2;
        }
    }

    // Short, mostly-greedy SA over cone configurations.
    let mut rng = Rng::new(round_seed(policy.seed, round));
    let mut temperature = 0.05;
    if !active.is_empty() {
        for _ in 0..policy.iters {
            let mut proposal = cur.clone();
            let t = active[rng.below(active.len())];
            proposal[t] = p.feasible[rng.below(p.feasible.len())];
            let (m, c) =
                eval_candidate(p, active, committed_peak, &mut sgs, &mut memo, &proposal);
            let e = energy(m, c) + surcharge(&proposal);
            let de = e - cur_e;
            let accept = de < 0.0
                || (e.is_finite() && rng.f64() < (-de / temperature.max(1e-12)).exp());
            if accept {
                cur = proposal;
                cur_e = e;
                if e < best_e - 1e-12 {
                    best = cur.clone();
                    best_e = e;
                }
            }
            temperature *= 0.97;
        }
    }

    // Materialize the winning cone plan (re-evaluate so the evaluator's
    // start vector reflects `best`, not the last SA proposal).
    let makespan = sgs.evaluate(p, &best).max(committed_peak);
    let start: Vec<f64> = (0..p.len()).map(|t| sgs.start_of(t)).collect();
    SuffixPlan {
        assignment: best,
        start,
        makespan,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_policy_is_off() {
        let policy = ReplanPolicy::off();
        assert!(policy.is_off());
        assert!(policy.divergence.is_off());
        assert_eq!(policy.max_replans, 0);
    }

    #[test]
    fn for_round_decorrelates_but_keeps_round_zero_identity() {
        let base = ReplanPolicy {
            max_replans: 1,
            divergence: DivergenceSpec {
                straggler_prob: 0.5,
                seed: 9,
                ..Default::default()
            },
            ..Default::default()
        };
        assert_eq!(base.for_round(0), base);
        let r1 = base.for_round(1);
        let r2 = base.for_round(2);
        assert_ne!(r1.divergence.seed, base.divergence.seed);
        assert_ne!(r1.divergence.seed, r2.divergence.seed);
        assert_ne!(r1.seed, r2.seed);
        // Knobs are untouched; only seed streams move.
        assert_eq!(r1.max_replans, base.max_replans);
        assert_eq!(r1.divergence.straggler_prob, base.divergence.straggler_prob);
        // Derivation is itself deterministic.
        assert_eq!(base.for_round(1), base.for_round(1));
    }

    #[test]
    fn sla_spot_penalty_defaults_off_and_survives_round_derivation() {
        let base = ReplanPolicy::default();
        assert_eq!(base.sla_spot_penalty, 0.0);
        let armed = ReplanPolicy {
            sla_spot_penalty: 10.0,
            ..Default::default()
        };
        assert_eq!(armed.for_round(0), armed);
        assert_eq!(armed.for_round(3).sla_spot_penalty, 10.0);
    }

    #[test]
    fn troublesome_cone_defaults_off_and_survives_round_derivation() {
        let base = ReplanPolicy::default();
        assert!(!base.troublesome_cone);
        let armed = ReplanPolicy {
            troublesome_cone: true,
            ..Default::default()
        };
        assert_eq!(armed.for_round(0), armed);
        assert!(armed.for_round(3).troublesome_cone);
    }

    #[test]
    fn troublesome_cone_replan_is_valid_under_both_orders() {
        // A full-cone replan (trigger at t = 0, nothing committed) must
        // produce a feasible suffix plan under both the historical
        // critical-path cone order and the DAGPS troublesome-first order.
        use crate::cluster::{Capacity, ConfigSpace, CostModel};
        use crate::dag::workloads::dag2;
        use crate::predictor::OraclePredictor;
        use crate::Predictor;

        let dags = vec![dag2()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags[0].tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let p = Problem::new(
            &dags,
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        );

        let incumbent = vec![p.feasible[0]; p.len()];
        let active: Vec<usize> = (0..p.len()).collect();
        let fixed_end = vec![0.0; p.len()];
        for troublesome in [false, true] {
            let policy = ReplanPolicy {
                iters: 40,
                troublesome_cone: troublesome,
                ..ReplanPolicy::off()
            };
            let plan = replan_suffix(&p, &incumbent, &active, 0.0, &fixed_end, &[], &policy, 0);
            assert_eq!(plan.assignment.len(), p.len());
            for &c in &plan.assignment {
                assert!(p.feasible.contains(&c), "cone escaped the feasible set");
            }
            assert!(
                plan.makespan.is_finite() && plan.makespan > 0.0,
                "degenerate cone makespan {} (troublesome={troublesome})",
                plan.makespan
            );
        }
    }

    #[test]
    fn divergence_draw_is_deterministic_and_respects_pins() {
        let spec = DivergenceSpec {
            straggler_prob: 0.3,
            straggler_factor: 5.0,
            straggler_tasks: vec![2],
            fail_prob: 0.2,
            fail_tasks: vec![4],
            seed: 77,
            ..Default::default()
        };
        let a = spec.draw(8);
        let b = spec.draw(8);
        assert_eq!(a.len(), 8);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.modifier, y.modifier);
            assert_eq!(x.straggled, y.straggled);
            assert_eq!(x.retries, y.retries);
        }
        assert!(a[2].straggled, "pinned straggler must straggle");
        assert!(a[2].modifier >= 5.0);
        assert_eq!(a[4].retries, 1, "pinned failure must retry once");
        assert!(a[4].modifier > 1.0);
    }

    #[test]
    fn off_divergence_draws_identity_modifiers() {
        let spec = DivergenceSpec::default();
        assert!(spec.is_off());
        for d in spec.draw(16) {
            assert_eq!(d.modifier, 1.0);
            assert_eq!(d.retries, 0);
            assert!(!d.straggled);
        }
    }

    #[test]
    fn spot_rate_arms_the_spec() {
        let spec = DivergenceSpec {
            spot_rate: 1.0,
            ..Default::default()
        };
        assert!(!spec.is_off());
        let pinned = DivergenceSpec {
            spot_tasks: vec![3],
            ..Default::default()
        };
        assert!(!pinned.is_off());
    }

    #[test]
    fn spot_draw_is_deterministic_and_order_independent() {
        let spec = DivergenceSpec {
            spot_rate: 3.0,
            seed: 99,
            ..Default::default()
        };
        // Same (seed, task) -> same draw, regardless of any other draws
        // in between (per-task derived streams).
        let a = spec.draw_spot(5, true, 2.0, 1800.0);
        let _ = spec.draw_spot(7, true, 1.0, 3600.0);
        let b = spec.draw_spot(5, true, 2.0, 1800.0);
        assert_eq!(a.0.to_bits(), b.0.to_bits());
        assert_eq!(a.1, b.1);
    }

    #[test]
    fn spot_draw_respects_cap_and_bounds() {
        let spec = DivergenceSpec {
            spot_rate: 1e6, // essentially certain preemption pressure
            seed: 7,
            ..Default::default()
        };
        for task in 0..64 {
            let (mult, n) = spec.draw_spot(task, true, 4.0, 3600.0);
            assert!(n <= spec.spot_max, "task {task}: {n} preemptions");
            assert!(mult >= 1.0);
            // At most spot_max whole re-runs of lost work.
            assert!(mult <= 1.0 + spec.spot_max as f64);
        }
        // Saturating pressure: the cap itself is essentially always hit.
        let hits = (0..64)
            .filter(|&t| spec.draw_spot(t, true, 4.0, 3600.0).1 == spec.spot_max)
            .count();
        assert!(hits >= 60, "only {hits}/64 tasks hit the cap at rate 1e6");
    }

    #[test]
    fn spot_draw_is_inert_off_spot_or_at_zero_rate() {
        let spec = DivergenceSpec {
            spot_rate: 5.0,
            ..Default::default()
        };
        // Not on spot capacity: nothing happens even at a high rate.
        assert_eq!(spec.draw_spot(0, false, 4.0, 3600.0), (1.0, 0));
        let off = DivergenceSpec::default();
        assert_eq!(off.draw_spot(0, true, 4.0, 3600.0), (1.0, 0));
    }

    #[test]
    fn spot_max_zero_disables_realized_preemptions_entirely() {
        // The preemptions <= spot_max invariant must hold at 0 too —
        // for the rate process AND for pinned tasks.
        let spec = DivergenceSpec {
            spot_rate: 100.0,
            spot_max: 0,
            spot_tasks: vec![0],
            ..Default::default()
        };
        for task in 0..4 {
            assert_eq!(spec.draw_spot(task, true, 8.0, 3600.0), (1.0, 0));
        }
    }

    #[test]
    fn pinned_spot_task_loses_exactly_half_a_run() {
        let spec = DivergenceSpec {
            spot_tasks: vec![2],
            ..Default::default()
        };
        assert_eq!(spec.draw_spot(2, false, 1.0, 100.0), (1.5, 1));
        assert_eq!(spec.draw_spot(1, false, 1.0, 100.0), (1.0, 0));
    }
}
