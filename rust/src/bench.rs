//! Bench-harness utilities (criterion is unavailable offline): wall-clock
//! measurement with warmup + repetitions, and paper-style table/series
//! printers shared by every `rust/benches/*.rs` target.

use std::time::{Duration, Instant};

/// Timing summary of a measured closure.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Label of the measured operation.
    pub name: String,
    /// Measured repetitions (warmup runs excluded).
    pub reps: usize,
    /// Mean wall-clock time per repetition.
    pub mean: Duration,
    /// Fastest repetition.
    pub min: Duration,
    /// Slowest repetition.
    pub max: Duration,
}

impl Measurement {
    /// Mean wall-clock time in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    /// Fastest repetition in milliseconds — the estimator scaling fits
    /// use (min is far less noise-sensitive than mean under CI load).
    pub fn min_ms(&self) -> f64 {
        self.min.as_secs_f64() * 1e3
    }
}

/// Measure a closure: `warmup` unmeasured runs, then `reps` measured.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    let total: Duration = samples.iter().sum();
    Measurement {
        name: name.to_string(),
        reps: samples.len(),
        mean: total / samples.len() as u32,
        min: samples.iter().min().copied().unwrap_or_default(),
        max: samples.iter().max().copied().unwrap_or_default(),
    }
}

/// Paper-style experiment header with reproduction context.
pub fn header(experiment: &str, description: &str) {
    println!("\n{}", "=".repeat(78));
    println!("{experiment}: {description}");
    println!("{}", "=".repeat(78));
}

/// Fixed-width table printer. `rows` are already formatted cells.
pub fn table(columns: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = columns.iter().map(|c| c.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, cell) in cells.iter().enumerate() {
            s.push_str(&format!("{:<width$}  ", cell, width = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(&columns.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
}

/// Print an x/y series (one figure panel) as aligned columns.
pub fn series(title: &str, x_label: &str, y_labels: &[&str], points: &[(f64, Vec<f64>)]) {
    println!("\n-- {title} --");
    let mut cols = vec![x_label];
    cols.extend_from_slice(y_labels);
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|(x, ys)| {
            let mut row = vec![format!("{x:.1}")];
            row.extend(ys.iter().map(|y| format!("{y:.3}")));
            row
        })
        .collect();
    table(&cols, &rows);
}

/// Least-squares slope of `ln y` against `ln x` — the fitted scaling
/// exponent of a measured size sweep (y ~ x^slope). The scaling bench
/// asserts this in `--smoke` mode so an accidental O(n²) regression in a
/// kernel hot path fails CI rather than silently shipping. Points with a
/// non-positive coordinate are dropped; returns `None` with fewer than
/// two usable points or a degenerate (constant-x) sweep.
pub fn fit_log_log_slope(points: &[(f64, f64)]) -> Option<f64> {
    let pts: Vec<(f64, f64)> = points
        .iter()
        .filter(|(x, y)| *x > 0.0 && *y > 0.0)
        .map(|&(x, y)| (x.ln(), y.ln()))
        .collect();
    if pts.len() < 2 {
        return None;
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = sxx - sx * sx / n;
    if denom.abs() < 1e-12 {
        return None;
    }
    Some((sxy - sx * sy / n) / denom)
}

/// Wall-clock speedup of `new` relative to `base`, formatted "3.2x".
pub fn speedup(base: Duration, new: Duration) -> String {
    let b = base.as_secs_f64();
    let n = new.as_secs_f64();
    if n <= 0.0 || b <= 0.0 {
        return "n/a".into();
    }
    format!("{:.1}x", b / n)
}

/// Relative change formatted as the paper quotes it ("45% faster").
pub fn pct(base: f64, new: f64) -> String {
    if base <= 0.0 {
        return "n/a".into();
    }
    let imp = (base - new) / base * 100.0;
    if imp >= 0.0 {
        format!("-{imp:.1}%")
    } else {
        format!("+{:.1}%", -imp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_reps() {
        let mut calls = 0;
        let m = measure("noop", 2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(m.reps, 5);
        assert!(m.min <= m.mean && m.mean <= m.max);
    }

    #[test]
    fn log_log_fit_recovers_the_exponent() {
        // Exact power law y = 3 x^2 -> slope exactly 2 (up to fp error).
        let pts: Vec<(f64, f64)> = [50.0, 200.0, 1000.0, 10_000.0]
            .iter()
            .map(|&x| (x, 3.0 * x * x))
            .collect();
        let slope = fit_log_log_slope(&pts).unwrap();
        assert!((slope - 2.0).abs() < 1e-9, "slope {slope}");
        // Linear sweep fits slope 1.
        let lin: Vec<(f64, f64)> = pts.iter().map(|&(x, _)| (x, 0.5 * x)).collect();
        assert!((fit_log_log_slope(&lin).unwrap() - 1.0).abs() < 1e-9);
        // Degenerate inputs refuse to fit instead of returning garbage.
        assert!(fit_log_log_slope(&[(100.0, 1.0)]).is_none());
        assert!(fit_log_log_slope(&[(100.0, 1.0), (100.0, 2.0)]).is_none());
        assert!(fit_log_log_slope(&[(-1.0, 1.0), (0.0, 2.0)]).is_none());
    }

    #[test]
    fn speedup_formats() {
        assert_eq!(
            speedup(Duration::from_secs(4), Duration::from_secs(1)),
            "4.0x"
        );
        assert_eq!(speedup(Duration::from_secs(1), Duration::ZERO), "n/a");
    }

    #[test]
    fn pct_formats_direction() {
        assert_eq!(pct(100.0, 55.0), "-45.0%");
        assert_eq!(pct(100.0, 130.0), "+30.0%");
        assert_eq!(pct(0.0, 1.0), "n/a");
    }

    #[test]
    fn table_does_not_panic_on_ragged_rows() {
        table(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }
}
