//! Deterministic PRNG for simulation, workload generation and annealing.
//!
//! The offline vendor set has no `rand` crate, so we carry a small,
//! well-known generator: SplitMix64 for seeding and xoshiro256++ for the
//! stream. Everything in the repo that needs randomness takes an explicit
//! `Rng` so every experiment is reproducible from a seed printed in its
//! header.

/// xoshiro256++ seeded via SplitMix64. Not cryptographic; excellent for
/// simulation workloads.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Deterministic generator from a seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Split off an independent stream (for per-DAG / per-task generators).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Modulo bias is negligible for n << 2^64 (all our uses).
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Pareto (heavy-tailed) with scale x_m and shape a > 0.
    pub fn pareto(&mut self, xm: f64, a: f64) -> f64 {
        xm / (1.0 - self.f64()).powf(1.0 / a)
    }

    /// Pick a uniform element of a non-empty slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Weighted index pick; weights must be non-negative, not all zero.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted: all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_roughly_centered() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform(0.0, 10.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn below_covers_all_values() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(8);
        for _ in 0..500 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn pareto_is_heavy_tailed() {
        let mut r = Rng::new(10);
        let xs: Vec<f64> = (0..10_000).map(|_| r.pareto(1.0, 1.5)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0));
        let max = xs.iter().cloned().fold(0.0, f64::max);
        assert!(max > 20.0, "tail too light: max={max}");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Rng::new(11);
        let mut b = a.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
