//! Small statistics helpers used by the simulator, benches and reports.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation; 0.0 if empty.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Empirical CDF evaluated at the given points: fraction of xs <= point.
pub fn cdf_at(xs: &[f64], points: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    points
        .iter()
        .map(|&p| {
            let idx = v.partition_point(|&x| x <= p);
            idx as f64 / v.len().max(1) as f64
        })
        .collect()
}

/// Geometric mean of positive values; 0.0 if empty or any value <= 0.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Relative improvement of `new` over `base`: (base - new) / base.
/// Positive = improvement. 0.0 when base is 0.
pub fn improvement(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        (base - new) / base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [1.0, 2.0, 2.0, 5.0];
        let c = cdf_at(&xs, &[0.0, 1.0, 2.0, 5.0, 9.0]);
        assert_eq!(c, vec![0.0, 0.25, 0.75, 1.0, 1.0]);
    }

    #[test]
    fn improvement_signs() {
        assert!((improvement(100.0, 60.0) - 0.4).abs() < 1e-12);
        assert!(improvement(100.0, 140.0) < 0.0);
        assert_eq!(improvement(0.0, 5.0), 0.0);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[3.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
        assert_eq!(geomean(&[1.0, -1.0]), 0.0);
    }
}
