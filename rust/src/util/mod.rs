//! Infrastructure utilities carried in-repo because the build is fully
//! offline: JSON codec (no serde), PRNG (no rand), CLI parser (no clap),
//! statistics helpers, and a property-testing harness (no proptest).

pub mod cli;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;

/// Format seconds as `1h02m03s` / `4m05s` / `6.3s` for report tables.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 3600.0 {
        let h = (secs / 3600.0).floor();
        let m = ((secs - h * 3600.0) / 60.0).floor();
        let s = secs - h * 3600.0 - m * 60.0;
        format!("{h:.0}h{m:02.0}m{s:02.0}s")
    } else if secs >= 60.0 {
        let m = (secs / 60.0).floor();
        let s = secs - m * 60.0;
        format!("{m:.0}m{s:02.0}s")
    } else {
        format!("{secs:.1}s")
    }
}

/// Format a dollar amount for report tables.
pub fn fmt_cost(dollars: f64) -> String {
    format!("${dollars:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations_format() {
        assert_eq!(fmt_duration(6.33), "6.3s");
        assert_eq!(fmt_duration(65.0), "1m05s");
        assert_eq!(fmt_duration(3723.0), "1h02m03s");
    }

    #[test]
    fn costs_format() {
        assert_eq!(fmt_cost(1.5), "$1.50");
        assert_eq!(fmt_cost(0.0), "$0.00");
    }
}
