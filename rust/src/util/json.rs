//! Minimal JSON codec (the offline vendor set has no `serde`).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest, config files, DAG specs, and experiment output. The
//! API is a dynamic `Json` value with typed accessors that return
//! `anyhow::Result` so config errors carry a path-like context message.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so emitted
/// JSON is deterministic — experiment outputs diff cleanly between runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (always carried as `f64`).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (keys kept sorted for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Parse a JSON file, with the path in any error context.
    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // -- constructors ------------------------------------------------------

    /// Build an object from key/value pairs.
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            entries
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Build an array from an iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Build a number value.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Build a string value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- typed accessors ---------------------------------------------------

    /// This value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(anyhow!("expected number, got {}", other.kind())),
        }
    }

    /// This value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    /// This value as a boolean.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected bool, got {}", other.kind())),
        }
    }

    /// This value as a string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {}", other.kind())),
        }
    }

    /// This value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(anyhow!("expected array, got {}", other.kind())),
        }
    }

    /// This value as an object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(anyhow!("expected object, got {}", other.kind())),
        }
    }

    /// Required object field.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing field {key:?}"))
    }

    /// Optional object field.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // -- writer ------------------------------------------------------------

    /// Serialize to compact JSON.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize to indented JSON with a trailing newline.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    item.write_pretty(out, indent + 2);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.i),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow!("bad \\u escape {hex:?}"))?;
                            self.i += 4;
                            // Surrogate pairs: accept but replace lone
                            // surrogates with U+FFFD (we never emit them).
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c if c < 0x20 => bail!("raw control character in string"),
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: re-decode from the original slice.
                    let start = self.i - 1;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| anyhow!("invalid UTF-8 in string"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let x: f64 = text
            .parse()
            .map_err(|_| anyhow!("invalid number {text:?} at byte {start}"))?;
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse(r#""café ☃ ü""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☃ ü");
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn object_keys_sorted_deterministically() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse("[1]").unwrap();
        assert!(v.as_obj().is_err());
        assert!(v.as_arr().unwrap()[0].as_str().is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Num(3.0).as_usize().is_ok());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
          "artifacts": {
            "predict_small": {"tasks": 32, "configs": 64, "inputs": [[32,8],[64,8]]}
          },
          "k": 8
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("k").unwrap().as_usize().unwrap(), 8);
        let p = v.get("artifacts").unwrap().get("predict_small").unwrap();
        assert_eq!(p.get("configs").unwrap().as_usize().unwrap(), 64);
    }
}
