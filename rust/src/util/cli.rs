//! Tiny CLI argument parser (no `clap` in the offline vendor set).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [positional...]`.
//! `--key=value` is also accepted. Unknown flags are an error so typos
//! fail loudly instead of silently running a default experiment.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional arguments, and flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token, e.g. `optimize`.
    pub subcommand: Option<String>,
    /// Remaining non-flag tokens (DAG names, file paths).
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags the program declares; used to reject unknown ones.
    known: Vec<(&'static str, &'static str)>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known: &[(&'static str, &'static str)],
    ) -> Result<Args> {
        let mut args = Args {
            known: known.to_vec(),
            ..Default::default()
        };
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if !known.iter().any(|(k, _)| *k == key) {
                    bail!("unknown flag --{key}\n{}", Self::usage_for(known));
                }
                let value = match inline_val {
                    Some(v) => v,
                    None => {
                        // Boolean flags: next token missing or another flag.
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                args.flags.insert(key, value);
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments (argv[0] excluded).
    pub fn from_env(known: &[(&'static str, &'static str)]) -> Result<Args> {
        Self::parse(std::env::args().skip(1), known)
    }

    /// Render the flag reference for a declared flag set.
    pub fn usage_for(known: &[(&'static str, &'static str)]) -> String {
        let mut s = String::from("flags:\n");
        for (k, help) in known {
            s.push_str(&format!("  --{k:<18} {help}\n"));
        }
        s
    }

    /// Whether a flag was passed at all.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Raw value of a flag, if passed.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag with a default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Float flag with a default; parse errors name the flag.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    /// Unsigned-integer flag with a default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// u64 flag with a default (seeds).
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Boolean flag with a default (`--flag`, `--flag true|false`).
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("--{key} expects true/false, got {v:?}"),
        }
    }

    /// Render the flag reference of this parse's declared flags.
    pub fn usage(&self) -> String {
        Self::usage_for(&self.known)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KNOWN: &[(&str, &str)] = &[
        ("goal", "optimization goal"),
        ("seed", "rng seed"),
        ("verbose", "chatty output"),
    ];

    fn parse(args: &[&str]) -> Result<Args> {
        Args::parse(args.iter().map(|s| s.to_string()), KNOWN)
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["optimize", "--goal", "cost", "--seed=7", "input.json"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("optimize"));
        assert_eq!(a.get("goal"), Some("cost"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
        assert_eq!(a.positional, vec!["input.json"]);
    }

    #[test]
    fn boolean_flag_without_value() {
        let a = parse(&["run", "--verbose", "--goal", "runtime"]).unwrap();
        assert!(a.bool_or("verbose", false).unwrap());
        assert_eq!(a.get("goal"), Some("runtime"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse(&["run", "--bogus", "1"]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["run"]).unwrap();
        assert_eq!(a.f64_or("goal", 0.5).unwrap(), 0.5);
        assert_eq!(a.str_or("goal", "balanced"), "balanced");
        assert!(!a.bool_or("verbose", false).unwrap());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["run", "--seed", "abc"]).unwrap();
        assert!(a.u64_or("seed", 0).is_err());
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse(&["run", "--verbose"]).unwrap();
        assert!(a.bool_or("verbose", false).unwrap());
    }
}
