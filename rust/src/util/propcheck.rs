//! Minimal property-based testing helper (no `proptest` in the offline
//! vendor set).
//!
//! A property is a closure from an `Rng`-driven generated case to
//! `Result<(), String>`. The runner executes N cases from a deterministic
//! seed sequence; on failure it retries the case with progressively
//! "smaller" seeds derived from the failing one (a cheap stand-in for
//! shrinking) and reports the smallest failing seed so the case can be
//! replayed in a unit test.
//!
//! Usage:
//! ```ignore
//! propcheck::check(100, |rng| {
//!     let dag = generator::random_dag(rng, 10);
//!     let schedule = solver.solve(&dag);
//!     invariants::check_schedule(&dag, &schedule).map_err(|e| e.to_string())
//! });
//! ```

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of generated cases to run.
    pub cases: usize,
    /// Base seed of the deterministic case-seed sequence.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Base seed is fixed: property tests are deterministic in CI.
        Config {
            cases: 100,
            seed: 0xA60_2A,
        }
    }
}

/// Run `prop` for `cases` generated inputs. Panics with a replayable
/// message on the first failure.
pub fn check<F>(cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    check_with(Config {
        cases,
        ..Config::default()
    }, prop)
}

/// Like [`check`] but with an explicit config (e.g. to replay one seed).
pub fn check_with<F>(config: Config, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut meta = Rng::new(config.seed);
    for case in 0..config.cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case}/{} (replay with seed {case_seed:#x}):\n  {msg}",
                config.cases
            );
        }
    }
}

/// Replay a single failing case seed reported by [`check`].
pub fn replay<F>(case_seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replayed property failure (seed {case_seed:#x}):\n  {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        // interior mutability via Cell to count invocations
        let counter = std::cell::Cell::new(0usize);
        check(25, |_rng| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(10, |rng| {
            if rng.f64() >= 0.0 {
                Err("always fails".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn deterministic_between_runs() {
        let collect = |n: usize| {
            let seeds = std::cell::RefCell::new(Vec::new());
            check(n, |rng| {
                seeds.borrow_mut().push(rng.next_u64());
                Ok(())
            });
            seeds.into_inner()
        };
        assert_eq!(collect(10), collect(10));
    }
}
