//! Bounded worker pool running portfolio co-optimization off the
//! coordinator thread.
//!
//! Workers pull [`Job`]s from a shared queue and run **only** the pure
//! planning step (`Agora::optimize` with a pre-drawn seed); everything
//! stateful — history bootstraps, the occupancy ledger, execution, log
//! feedback, replies — stays serialized on the control thread, which
//! commits results strictly in round order. That split is what lets the
//! pool scale without perturbing the service's deterministic RNG stream
//! (see [`super::control`] for the determinism argument).
//!
//! A worker wraps the optimizer in `catch_unwind`: a panicking attempt
//! becomes an `Err` [`Done`] carrying the panic message, feeding the
//! retry ladder instead of deadlocking the round.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::retry::FaultSpec;
use super::service::Shared;
use crate::solver::{Agora, AgoraOptions, Problem, Schedule};

/// One optimization attempt handed to the pool.
pub(crate) struct Job {
    /// Round number (1-based, commit order).
    pub(crate) round: usize,
    /// Attempt number (1-based; grows with retries).
    pub(crate) attempt: usize,
    /// The round's problem, built by the control thread.
    pub(crate) problem: Problem,
    /// Fully-resolved optimizer options (seed pre-drawn by control).
    pub(crate) options: AgoraOptions,
    /// Fault injection for retry tests (off in production configs).
    pub(crate) fault: FaultSpec,
}

/// One finished attempt, posted back through the ingress mailbox.
pub(crate) struct Done {
    /// Round number of the attempt.
    pub(crate) round: usize,
    /// The problem handed back (so retries and commit need no rebuild).
    pub(crate) problem: Problem,
    /// Planned schedule + optimizer wall-clock, or the failure message.
    pub(crate) outcome: Result<(Schedule, Duration), String>,
}

/// Best-effort text of a panic payload (shared with
/// [`Service::shutdown`](super::service::Service::shutdown)'s
/// panic-propagation path).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "optimizer panicked".to_string()
    }
}

/// Fixed-size worker pool; dropped (or disconnected) senders terminate
/// the workers.
pub(crate) struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` (>= 1) threads pulling from one shared job queue
    /// and posting [`Done`]s to `shared`'s ingress mailbox.
    pub(crate) fn spawn(workers: usize, shared: Arc<Shared>) -> WorkerPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers.max(1))
            .map(|i| {
                let rx = rx.clone();
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("agora-worker-{i}"))
                    .spawn(move || worker_loop(&rx, &shared))
                    .expect("spawn worker thread")
            })
            .collect();
        WorkerPool {
            tx: Some(tx),
            handles,
        }
    }

    /// Hand one attempt to the pool; `Err` if every worker is gone.
    pub(crate) fn dispatch(&self, job: Job) -> Result<(), String> {
        match &self.tx {
            Some(tx) => tx
                .send(job)
                .map_err(|_| "worker pool has shut down".to_string()),
            None => Err("worker pool has shut down".to_string()),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Disconnect the queue so workers drain and exit, then reap them.
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: &Arc<Mutex<Receiver<Job>>>, shared: &Arc<Shared>) {
    loop {
        // Hold the queue lock only for the receive itself, so idle
        // workers queue up fairly behind it while one optimizes.
        let job = {
            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
            guard.recv()
        };
        let Job {
            round,
            attempt,
            problem,
            options,
            fault,
        } = match job {
            Ok(j) => j,
            Err(_) => return,
        };
        let outcome = if attempt <= fault.optimize_failures {
            Err(format!("injected optimizer fault (attempt {attempt})"))
        } else {
            catch_unwind(AssertUnwindSafe(|| {
                let plan = Agora::new(options).optimize(&problem);
                (plan.schedule, plan.overhead)
            }))
            .map_err(panic_message)
        };
        shared.ingress.push_done(Done {
            round,
            problem,
            outcome,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::coordinator::round::RoundEngine;
    use crate::coordinator::service::ServiceConfig;
    use crate::sim::ReplanPolicy;
    use crate::solver::{Goal, Mode};
    use crate::util::Rng;
    use std::collections::HashMap;

    fn fixture() -> (Arc<Shared>, Problem) {
        let shared = Arc::new(Shared::new(ServiceConfig::default()));
        let space = ConfigSpace::standard();
        let cost_model = CostModel::OnDemand;
        let replan = ReplanPolicy::off();
        let engine = RoundEngine {
            capacity: Capacity::micro(),
            space: &space,
            cost_model: &cost_model,
            replan: &replan,
        };
        let dags = vec![crate::dag::workloads::dag1()];
        let mut db = HashMap::new();
        let mut rng = Rng::new(9);
        let p = engine.build_problem(&dags, &mut db, &mut rng);
        (shared, p)
    }

    fn wait_done(shared: &Arc<Shared>) -> Done {
        for _ in 0..600 {
            let mut view = shared.ingress.wait(Duration::from_millis(100));
            if let Some(d) = view.done.pop() {
                return d;
            }
        }
        panic!("worker never reported");
    }

    #[test]
    fn pool_plans_a_round_and_reports_back() {
        let (shared, p) = fixture();
        let pool = WorkerPool::spawn(2, shared.clone());
        pool.dispatch(Job {
            round: 1,
            attempt: 1,
            problem: p,
            options: RoundEngine::agora_options(Goal::Balanced, Mode::CoOptimize, 42, 1),
            fault: FaultSpec::default(),
        })
        .expect("dispatch");
        let done = wait_done(&shared);
        assert_eq!(done.round, 1);
        let (schedule, overhead) = done.outcome.expect("planned");
        assert!(!schedule.assignment.is_empty());
        assert!(overhead > Duration::ZERO);
    }

    #[test]
    fn injected_faults_surface_as_errors_not_hangs() {
        let (shared, p) = fixture();
        let pool = WorkerPool::spawn(1, shared.clone());
        pool.dispatch(Job {
            round: 3,
            attempt: 1,
            problem: p.clone(),
            options: RoundEngine::agora_options(Goal::Balanced, Mode::CoOptimize, 42, 1),
            fault: FaultSpec {
                optimize_failures: 1,
            },
        })
        .expect("dispatch");
        let done = wait_done(&shared);
        assert_eq!(done.round, 3);
        let msg = done.outcome.expect_err("fault injected");
        assert!(msg.contains("injected optimizer fault"));
        // The returned problem survives for the retry redispatch.
        assert_eq!(done.problem.tasks.len(), p.tasks.len());
        // The same round past its fault budget succeeds.
        pool.dispatch(Job {
            round: 3,
            attempt: 2,
            problem: done.problem,
            options: RoundEngine::agora_options(Goal::Balanced, Mode::CoOptimize, 42, 1),
            fault: FaultSpec {
                optimize_failures: 1,
            },
        })
        .expect("dispatch");
        let done = wait_done(&shared);
        assert!(done.outcome.is_ok());
    }

    #[test]
    fn dropping_the_pool_reaps_workers() {
        let (shared, _) = fixture();
        let pool = WorkerPool::spawn(3, shared);
        drop(pool); // must not hang
    }
}
