//! The control actor: the single coordinator thread that owns every
//! piece of mutable service state (RNG stream, event-log database,
//! occupancy ledger, round sequencing) and drives the worker pool.
//!
//! # Protocol
//!
//! Each loop iteration, in order:
//!
//! 1. **Commit** every contiguous finished round starting at
//!    `next_commit`: execute the planned schedule on the simulated
//!    cluster, absorb occupancy (continuous admission), feed logs back,
//!    answer every submission of the round. Rounds always commit in
//!    round order, even when a later round's optimization finishes
//!    first — out-of-order results park in `planned` until their turn.
//! 2. **Redispatch** retries whose backoff expired (same round number,
//!    same optimizer seed).
//! 3. **Dispatch** new rounds while a worker slot is free and the
//!    trigger (window elapsed / demand / shutdown drain) fires: take a
//!    batch from ingress, build the round's [`Problem`], draw its
//!    optimizer seed, hand the pure planning step to the pool.
//! 4. **Exit** once shutdown was requested and no work remains.
//! 5. **Sleep** on the mailbox for submissions/completions/shutdown.
//!
//! # Determinism
//!
//! The coordinator RNG is consumed only on this thread and only at two
//! points, in round order: the bootstrap-history draws inside
//! `build_problem` + one `next_u64` seed at dispatch, and the
//! simulator's draws at commit. With one worker, dispatch of round
//! *N + 1* cannot start before round *N* commits (the single slot frees
//! only when the result arrives, and commits are processed before
//! dispatches in the iteration), so the draw order is exactly the
//! legacy serial `bootstrap(N) → seed(N) → execute(N) → bootstrap(N+1)
//! → …` — which is why the single-worker, unbounded-queue service is
//! bit-identical to the pre-refactor loop. With more workers the
//! commit order (and thus the reply order) is still deterministic, but
//! execute draws interleave differently with later rounds' bootstraps,
//! so realized numbers may differ from the serial stream — the
//! documented price of parallel planning.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::ingress::Pending;
use super::pool::{Job, WorkerPool};
use super::reload::ConfigSnapshot;
use super::retry::RoundError;
use super::round::{busy_core_seconds, RoundEngine};
use super::service::{Shared, SubmitResult};
use super::{Admission, OccupancyLedger, SlaPolicy, TriggerPolicy};
use crate::dag::Dag;
use crate::predictor::EventLog;
use crate::solver::{Mode, Problem, Schedule, Sla};
use crate::util::Rng;

/// A dispatched, uncommitted round.
struct Inflight {
    /// The submissions of the round (replies outstanding).
    batch: Vec<Pending>,
    /// The round's DAGs (batch order).
    dags: Vec<Dag>,
    /// Configuration generation pinned at dispatch.
    snapshot: Arc<ConfigSnapshot>,
    /// Virtual admission instant on the shared timeline.
    vnow: f64,
    /// Optimizer seed drawn at dispatch; reused verbatim by retries.
    seed: u64,
    /// Failed attempts so far.
    failures: usize,
    /// Wall-clock dispatch instant (queue-delay accounting).
    dispatched_at: Instant,
    /// The problem handed back by a failed attempt, kept for redispatch.
    retry_problem: Option<Problem>,
}

/// A finished optimization waiting for its in-order commit slot.
struct Planned {
    problem: Problem,
    schedule: Schedule,
}

/// Run the control actor until shutdown; returns rounds served.
pub(crate) fn run(shared: Arc<Shared>) -> usize {
    let boot = shared.config.load();
    let mut rng = Rng::new(boot.config.seed);
    drop(boot);
    let pool = WorkerPool::spawn(shared.workers, shared.clone());

    let mut log_db: HashMap<String, EventLog> = HashMap::new();
    let mut ledger = OccupancyLedger::default();
    let mut inflight: BTreeMap<usize, Inflight> = BTreeMap::new();
    let mut planned: BTreeMap<usize, Planned> = BTreeMap::new();
    let mut failed: BTreeMap<usize, RoundError> = BTreeMap::new();
    let mut delayed: Vec<(Instant, usize)> = Vec::new();
    let mut pool_busy = 0usize;
    let mut dispatched = 0usize;
    let mut next_commit = 1usize;
    let mut served = 0usize;
    // Absolute virtual-time horizon for utilization accounting: rounds
    // stack back-to-back under the barrier, overlap under continuous
    // admission.
    let mut horizon = 0.0f64;
    let mut window_start = Instant::now();
    let mut shutting_down = false;

    loop {
        let snap = shared.config.load();
        let cfg = &snap.config;

        // 1. Commit finished rounds, strictly in round order.
        loop {
            let round = next_commit;
            if let Some(pl) = planned.remove(&round) {
                let inf = match inflight.remove(&round) {
                    Some(inf) => inf,
                    None => {
                        next_commit += 1;
                        continue;
                    }
                };
                let pinned = &inf.snapshot.config;
                let engine = RoundEngine {
                    capacity: pinned.capacity,
                    space: &pinned.space,
                    cost_model: &pinned.cost_model,
                    replan: &pinned.replan,
                };
                let report = engine.execute(&pl.problem, &inf.dags, &pl.schedule, round, &mut rng);
                if pinned.admission == Admission::Continuous {
                    ledger.absorb(&pl.problem, &report, inf.vnow);
                }
                RoundEngine::feed_back(&mut log_db, &pl.problem, &report);
                horizon = match pinned.admission {
                    Admission::Rounds => horizon + report.makespan,
                    Admission::Continuous => horizon.max(inf.vnow + report.makespan),
                };
                let busy = busy_core_seconds(&pl.problem, &report);

                let n = inf.batch.len();
                let mut tenants = Vec::with_capacity(n);
                let mut completions = Vec::with_capacity(n);
                let mut delays = Vec::with_capacity(n);
                let mut round_cost = 0.0f64;
                for (d, pending) in inf.batch.iter().enumerate() {
                    let cost = RoundEngine::dag_cost(&pinned.cost_model, &pl.problem, &report, d);
                    round_cost += cost;
                    tenants.push(pending.tenant.clone());
                    completions.push(report.dag_completion[d]);
                    delays.push(
                        inf.dispatched_at
                            .saturating_duration_since(pending.enqueued)
                            .as_secs_f64(),
                    );
                    let _ = pending.reply.send(Ok(SubmitResult {
                        tenant: pending.tenant.clone(),
                        dag_name: pending.dag.name.clone(),
                        completion: report.dag_completion[d],
                        cost,
                        round,
                    }));
                }
                shared.status.round_committed(
                    &tenants,
                    &completions,
                    &delays,
                    round_cost,
                    busy,
                    horizon,
                );
                served += 1;
                next_commit += 1;
            } else if let Some(err) = failed.remove(&round) {
                if let Some(inf) = inflight.remove(&round) {
                    for pending in &inf.batch {
                        let _ = pending.reply.send(Err(err.clone()));
                    }
                }
                next_commit += 1;
            } else {
                break;
            }
        }
        shared.status.set_in_flight(inflight.len());

        // 2. Redispatch retries whose backoff expired.
        let now = Instant::now();
        let mut i = 0;
        while i < delayed.len() {
            if pool_busy >= shared.workers {
                break;
            }
            if delayed[i].0 > now {
                i += 1;
                continue;
            }
            let (_, round) = delayed.swap_remove(i);
            let job = inflight.get_mut(&round).and_then(|inf| {
                inf.retry_problem.take().map(|problem| {
                    let c = &inf.snapshot.config;
                    Job {
                        round,
                        attempt: inf.failures + 1,
                        problem,
                        options: RoundEngine::agora_options(
                            c.goal,
                            Mode::CoOptimize,
                            inf.seed,
                            c.parallelism.max(1),
                        ),
                        fault: c.fault.clone(),
                    }
                })
            });
            if let Some(job) = job {
                let attempts = job.attempt - 1;
                match pool.dispatch(job) {
                    Ok(()) => pool_busy += 1,
                    Err(message) => {
                        failed.insert(
                            round,
                            RoundError {
                                round,
                                attempts,
                                message,
                            },
                        );
                    }
                }
            }
        }

        // 3. Dispatch new rounds while the trigger fires and a worker
        // slot is free.
        while pool_busy < shared.workers {
            let queued = shared.ingress.queued();
            if queued == 0 {
                break;
            }
            let window_elapsed = window_start.elapsed() >= cfg.batch_window;
            if !(shutting_down || window_elapsed || queued >= cfg.max_queue) {
                break;
            }
            let cap = if cfg.max_batch == 0 {
                usize::MAX
            } else {
                cfg.max_batch
            };
            let mut batch = shared.ingress.take_batch(cap);
            if batch.is_empty() {
                break;
            }
            let round = dispatched + 1;
            // Virtual admission instant: consecutive rounds sit one
            // trigger interval (the paper's 15 minutes, which a
            // batch_window stands for) apart — round-indexed, so slow
            // optimizes cannot silently drain the ledger.
            let vnow = match cfg.admission {
                Admission::Rounds => 0.0,
                Admission::Continuous => (round as f64 - 1.0) * TriggerPolicy::default().interval,
            };
            let mut dags: Vec<Dag> = batch.iter().map(|p| p.dag.clone()).collect();
            let engine = RoundEngine {
                capacity: cfg.capacity,
                space: &cfg.space,
                cost_model: &cfg.cost_model,
                replan: &cfg.replan,
            };
            let mut problem = engine.build_problem(&dags, &mut log_db, &mut rng);
            if cfg.admission == Admission::Continuous {
                problem = problem.with_occupancy(ledger.snapshot(vnow), 0.0);
            }
            // SLA admission: attach round-local deadlines
            // (`deadline_frac` x the DAG's completion lower bound) and
            // reject provably-infeasible hard-deadline DAGs with an
            // explicit error ticket before any optimization is spent.
            if !cfg.sla.is_off() {
                let attach = |p: Problem, s: &SlaPolicy| -> Problem {
                    let slas: Vec<Sla> = p
                        .dag_lower_bounds()
                        .iter()
                        .map(|&lb| s.sla_for(s.deadline_frac * lb))
                        .collect();
                    p.with_slas(slas)
                };
                problem = attach(problem, &cfg.sla);
                if cfg.sla.enforce {
                    let infeasible = problem.sla_infeasible();
                    if infeasible.iter().any(|&x| x) {
                        let mut kept = Vec::new();
                        for (pending, bad) in batch.into_iter().zip(infeasible) {
                            if bad {
                                shared.status.record_rejected(&pending.tenant);
                                let _ = pending.reply.send(Err(RoundError {
                                    round,
                                    attempts: 0,
                                    message: format!(
                                        "DAG '{}' rejected: completion lower bound \
                                         exceeds its hard deadline",
                                        pending.dag.name
                                    ),
                                }));
                            } else {
                                kept.push(pending);
                            }
                        }
                        batch = kept;
                        if batch.is_empty() {
                            // Whole batch rejected: no round is consumed.
                            window_start = Instant::now();
                            continue;
                        }
                        dags = batch.iter().map(|p| p.dag.clone()).collect();
                        // Rebuild for the survivors — their logs are
                        // cached now, so this draws nothing.
                        problem = engine.build_problem(&dags, &mut log_db, &mut rng);
                        if cfg.admission == Admission::Continuous {
                            problem = problem.with_occupancy(ledger.snapshot(vnow), 0.0);
                        }
                        problem = attach(problem, &cfg.sla);
                    }
                }
            }
            dispatched += 1;
            let seed = rng.next_u64();
            let job = Job {
                round,
                attempt: 1,
                problem,
                options: RoundEngine::agora_options(
                    cfg.goal,
                    Mode::CoOptimize,
                    seed,
                    cfg.parallelism.max(1),
                ),
                fault: cfg.fault.clone(),
            };
            inflight.insert(
                round,
                Inflight {
                    batch,
                    dags,
                    snapshot: snap.clone(),
                    vnow,
                    seed,
                    failures: 0,
                    dispatched_at: Instant::now(),
                    retry_problem: None,
                },
            );
            match pool.dispatch(job) {
                Ok(()) => pool_busy += 1,
                Err(message) => {
                    failed.insert(
                        round,
                        RoundError {
                            round,
                            attempts: 0,
                            message,
                        },
                    );
                }
            }
            window_start = Instant::now();
        }
        shared.status.set_in_flight(inflight.len());
        // An elapsed window with nothing queued just re-arms (the legacy
        // idle reset): the window measures batching delay, not idleness.
        if shared.ingress.queued() == 0 && window_start.elapsed() >= cfg.batch_window {
            window_start = Instant::now();
        }

        // 4. Exit once draining is complete. Failed dispatches parked in
        // `failed` still count as work until their in-order reply.
        if shutting_down
            && inflight.is_empty()
            && failed.is_empty()
            && shared.ingress.queued() == 0
        {
            break;
        }

        // 5. Sleep until the next event, but never past the batching
        // window (work queued + free slot) or a retry deadline.
        let mut timeout = Duration::from_millis(100);
        if pool_busy < shared.workers && shared.ingress.queued() > 0 {
            let remaining = cfg
                .batch_window
                .saturating_sub(window_start.elapsed())
                .max(Duration::from_millis(1));
            timeout = timeout.min(remaining);
        }
        let now = Instant::now();
        for (due, _) in &delayed {
            let wait = due
                .saturating_duration_since(now)
                .max(Duration::from_millis(1));
            timeout = timeout.min(wait);
        }
        let view = shared.ingress.wait(timeout);
        shutting_down = shutting_down || view.shutting_down;
        for done in view.done {
            pool_busy = pool_busy.saturating_sub(1);
            match done.outcome {
                Ok((schedule, overhead)) => {
                    shared.status.add_overhead(overhead);
                    planned.insert(
                        done.round,
                        Planned {
                            problem: done.problem,
                            schedule,
                        },
                    );
                }
                Err(message) => {
                    if let Some(inf) = inflight.get_mut(&done.round) {
                        inf.failures += 1;
                        inf.retry_problem = Some(done.problem);
                        let retry = &inf.snapshot.config.retry;
                        if retry.exhausted(inf.failures) {
                            shared.status.round_failed();
                            failed.insert(
                                done.round,
                                RoundError {
                                    round: done.round,
                                    attempts: inf.failures,
                                    message,
                                },
                            );
                        } else {
                            shared.status.round_retried();
                            delayed.push((Instant::now() + retry.backoff(inf.failures), done.round));
                        }
                    }
                }
            }
        }
    }

    shared.status.set_in_flight(0);
    served
}
