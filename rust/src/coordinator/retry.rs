//! Bounded-retry policy for optimization rounds.
//!
//! A round whose co-optimization attempt errors (or panics inside the
//! worker pool) is not dropped: the control plane re-queues it with
//! bounded exponential backoff, keeping its round number and optimizer
//! seed, until [`RetryPolicy::max_attempts`] is exhausted — at which
//! point every submission of the round is answered with a
//! [`RoundError`] instead of silently losing its reply.

use std::fmt;
use std::time::Duration;

/// Bounded exponential backoff for failed optimization rounds.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts per round, including the first (>= 1; a value of 1
    /// disables retries).
    pub max_attempts: usize,
    /// Backoff before the first retry.
    pub base: Duration,
    /// Multiplicative backoff growth per additional failure (>= 1).
    pub factor: f64,
    /// Upper bound on a single backoff wait.
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            base: Duration::from_millis(50),
            factor: 2.0,
            cap: Duration::from_secs(2),
        }
    }
}

impl RetryPolicy {
    /// Backoff to wait after the `failures`-th consecutive failure
    /// (1-based): `base * factor^(failures-1)`, capped at [`cap`].
    ///
    /// [`cap`]: RetryPolicy::cap
    pub fn backoff(&self, failures: usize) -> Duration {
        if failures == 0 {
            return Duration::ZERO;
        }
        let exp = (failures - 1).min(30) as i32;
        let secs = self.base.as_secs_f64() * self.factor.max(1.0).powi(exp);
        Duration::from_secs_f64(secs.min(self.cap.as_secs_f64()).max(0.0))
    }

    /// Has the round burned through its attempt budget?
    pub fn exhausted(&self, failures: usize) -> bool {
        failures >= self.max_attempts.max(1)
    }
}

/// Deterministic fault injection for control-plane tests: the first
/// `optimize_failures` attempts of *every* round fail inside the worker
/// pool before the optimizer runs, exercising the retry ladder without
/// touching optimizer internals. Off (0) by default.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// Number of leading attempts per round that fail artificially.
    pub optimize_failures: usize,
}

/// Terminal failure of an optimization round after retries were
/// exhausted; delivered to every submission the round contained.
#[derive(Debug, Clone)]
pub struct RoundError {
    /// The round that failed.
    pub round: usize,
    /// Attempts consumed before giving up.
    pub attempts: usize,
    /// The last attempt's error (or panic) message.
    pub message: String,
}

impl fmt::Display for RoundError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "round {} failed after {} attempt(s): {}",
            self.round, self.attempts, self.message
        )
    }
}

impl std::error::Error for RoundError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_geometrically_and_caps() {
        let p = RetryPolicy {
            max_attempts: 10,
            base: Duration::from_millis(100),
            factor: 2.0,
            cap: Duration::from_millis(500),
        };
        assert_eq!(p.backoff(1), Duration::from_millis(100));
        assert_eq!(p.backoff(2), Duration::from_millis(200));
        assert_eq!(p.backoff(3), Duration::from_millis(400));
        // capped from here on
        assert_eq!(p.backoff(4), Duration::from_millis(500));
        assert_eq!(p.backoff(20), Duration::from_millis(500));
    }

    #[test]
    fn zero_failures_waits_nothing() {
        assert_eq!(RetryPolicy::default().backoff(0), Duration::ZERO);
    }

    #[test]
    fn huge_failure_counts_do_not_overflow() {
        let p = RetryPolicy::default();
        assert!(p.backoff(usize::MAX) <= p.cap);
    }

    #[test]
    fn exhaustion_respects_max_attempts() {
        let p = RetryPolicy {
            max_attempts: 3,
            ..Default::default()
        };
        assert!(!p.exhausted(1));
        assert!(!p.exhausted(2));
        assert!(p.exhausted(3));
        assert!(p.exhausted(4));
        // max_attempts 0 degrades to "one attempt, no retries"
        let p = RetryPolicy {
            max_attempts: 0,
            ..Default::default()
        };
        assert!(p.exhausted(1));
    }

    #[test]
    fn round_error_renders_context() {
        let e = RoundError {
            round: 7,
            attempts: 3,
            message: "optimizer panicked".into(),
        };
        let s = e.to_string();
        assert!(s.contains("round 7"));
        assert!(s.contains("3 attempt(s)"));
        assert!(s.contains("optimizer panicked"));
    }

    #[test]
    fn fault_spec_defaults_off() {
        assert_eq!(FaultSpec::default().optimize_failures, 0);
    }
}
