//! Ingress actor state: per-tenant submission queues with bounded depth,
//! priority ordering and explicit backpressure.
//!
//! Tenants talk to the control plane exclusively through
//! [`ServiceHandle::submit`], which enqueues into this module's
//! [`Mailbox`] and returns a [`Ticket`] — or an explicit
//! [`SubmitError`] when the tenant's queue is full
//! ([`SubmitError::QueueFull`]) or the service is draining
//! ([`SubmitError::ShuttingDown`]). Nothing in the submission path can
//! panic the caller.
//!
//! The mailbox doubles as the control actor's single event source: the
//! coordinator thread sleeps on one condvar that submissions, worker
//! completions ([`Done`]) and shutdown all notify.
//!
//! Batch selection ([`Mailbox::take_batch`]) orders by priority tier
//! (high → normal → low), round-robins one submission per tenant within
//! a tier so a flooding tenant cannot crowd others out of a capped
//! batch, and finally sorts the selected batch by admission sequence —
//! so the default unbounded-batch, uniform-priority configuration
//! reproduces the pre-refactor arrival-order batches exactly.
//!
//! [`ServiceHandle::submit`]: super::service::ServiceHandle::submit

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::pool::Done;
use super::retry::RoundError;
use super::service::SubmitResult;
use crate::dag::Dag;

/// Scheduling priority of one submission. Priority orders *across*
/// tenants when a round's batch is capped; within a tenant, submissions
/// always stay FIFO.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Batched after every queued normal/high submission.
    Low,
    /// The default tier.
    Normal,
    /// Batched before every queued normal/low submission.
    High,
}

/// Why a submission was not admitted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The tenant's bounded ingress queue is at capacity — explicit
    /// backpressure; resubmit after a round drains the queue.
    QueueFull {
        /// The tenant whose queue is full.
        tenant: String,
        /// The configured per-tenant bound that was hit.
        bound: usize,
    },
    /// The service is shutting down (or its coordinator is gone); no new
    /// work is admitted.
    ShuttingDown,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull { tenant, bound } => {
                write!(f, "tenant {tenant:?} ingress queue full (bound {bound})")
            }
            SubmitError::ShuttingDown => write!(f, "service is shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Reply payload delivered for one submission: the served outcome, or
/// the terminal error of its round.
pub(crate) type Reply = Result<SubmitResult, RoundError>;

/// An admitted submission: proof of admission plus the reply channel.
///
/// The ticket is the only way to receive the round outcome; dropping it
/// abandons the reply (the round still runs).
#[derive(Debug)]
pub struct Ticket {
    seq: u64,
    tenant: String,
    rx: Receiver<Reply>,
}

impl Ticket {
    /// Global admission sequence number (FIFO order across all tenants).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// The tenant this ticket belongs to.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Block until the submission's round commits (or fails terminally).
    pub fn recv(&self) -> anyhow::Result<SubmitResult> {
        match self.rx.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(anyhow::Error::new(e)),
            Err(_) => Err(anyhow!("service coordinator dropped the reply channel")),
        }
    }

    /// Like [`Ticket::recv`] with a timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> anyhow::Result<SubmitResult> {
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(anyhow::Error::new(e)),
            Err(e) => Err(anyhow!("waiting for service reply: {e}")),
        }
    }
}

/// One queued submission, owned by the mailbox until a round takes it.
pub(crate) struct Pending {
    /// Global admission sequence (ticket order).
    pub(crate) seq: u64,
    /// Submitting tenant.
    pub(crate) tenant: String,
    /// Batch-selection priority.
    pub(crate) priority: Priority,
    /// The submitted DAG.
    pub(crate) dag: Dag,
    /// Where the round outcome is delivered.
    pub(crate) reply: Sender<Reply>,
    /// Wall-clock admission instant (queue-delay accounting).
    pub(crate) enqueued: Instant,
}

/// What the control thread learns from one mailbox poll.
pub(crate) struct ControlView {
    /// Worker completions harvested since the last poll.
    pub(crate) done: Vec<Done>,
    /// Has shutdown been requested?
    pub(crate) shutting_down: bool,
}

struct MailboxState {
    tenants: BTreeMap<String, VecDeque<Pending>>,
    queued: usize,
    next_seq: u64,
    shutting_down: bool,
    done: Vec<Done>,
}

/// The control actor's mailbox: per-tenant bounded submission queues
/// plus the worker-completion inbox, guarded by one mutex + condvar.
pub(crate) struct Mailbox {
    bound: usize,
    state: Mutex<MailboxState>,
    cv: Condvar,
}

impl Mailbox {
    /// A mailbox with the given per-tenant queue bound (0 = unbounded).
    pub(crate) fn new(bound: usize) -> Mailbox {
        Mailbox {
            bound,
            state: Mutex::new(MailboxState {
                tenants: BTreeMap::new(),
                queued: 0,
                next_seq: 0,
                shutting_down: false,
                done: Vec::new(),
            }),
            cv: Condvar::new(),
        }
    }

    fn lock_state(&self) -> MutexGuard<'_, MailboxState> {
        // Poison-tolerant: a panicking peer must not cascade into every
        // other thread that touches the mailbox.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Enqueue a submission; `Err` communicates backpressure/shutdown
    /// instead of panicking or blocking.
    pub(crate) fn submit(
        &self,
        tenant: &str,
        dag: Dag,
        priority: Priority,
    ) -> Result<Ticket, SubmitError> {
        let mut st = self.lock_state();
        if st.shutting_down {
            return Err(SubmitError::ShuttingDown);
        }
        if self.bound > 0 {
            if let Some(q) = st.tenants.get(tenant) {
                if q.len() >= self.bound {
                    return Err(SubmitError::QueueFull {
                        tenant: tenant.to_string(),
                        bound: self.bound,
                    });
                }
            }
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        let (tx, rx) = channel();
        st.tenants
            .entry(tenant.to_string())
            .or_default()
            .push_back(Pending {
                seq,
                tenant: tenant.to_string(),
                priority,
                dag,
                reply: tx,
                enqueued: Instant::now(),
            });
        st.queued += 1;
        drop(st);
        self.cv.notify_all();
        Ok(Ticket {
            seq,
            tenant: tenant.to_string(),
            rx,
        })
    }

    /// Flag shutdown (new submissions are rejected) and wake the control
    /// thread so it starts draining.
    pub(crate) fn begin_shutdown(&self) {
        self.lock_state().shutting_down = true;
        self.cv.notify_all();
    }

    /// Deliver one worker completion and wake the control thread.
    pub(crate) fn push_done(&self, done: Done) {
        self.lock_state().done.push(done);
        self.cv.notify_all();
    }

    /// Sleep until an event arrives (or `timeout`), then drain the
    /// completion inbox and snapshot the queue state. Spurious wakeups
    /// are fine — the control loop re-evaluates its triggers each poll.
    pub(crate) fn wait(&self, timeout: Duration) -> ControlView {
        let mut st = self.lock_state();
        // Sleep only while there is nothing to hand over; the control
        // loop re-checks shutdown/queue state every poll, and the
        // timeout is capped, so a notify raced past us costs at most
        // one poll interval.
        if st.done.is_empty() {
            let (guard, _) = self
                .cv
                .wait_timeout(st, timeout)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
        }
        ControlView {
            done: std::mem::take(&mut st.done),
            shutting_down: st.shutting_down,
        }
    }

    /// Total queued submissions across tenants.
    pub(crate) fn queued(&self) -> usize {
        self.lock_state().queued
    }

    /// Live per-tenant queue depths (tenants in name order).
    pub(crate) fn depths(&self) -> Vec<(String, usize)> {
        self.lock_state()
            .tenants
            .iter()
            .map(|(t, q)| (t.clone(), q.len()))
            .collect()
    }

    /// Select the next round's batch: up to `cap` submissions, by
    /// priority tier then round-robin across tenants (one per tenant per
    /// sweep, tenants in name order), returned in admission-sequence
    /// order (see module docs for why).
    pub(crate) fn take_batch(&self, cap: usize) -> Vec<Pending> {
        let mut st = self.lock_state();
        let mut picked: Vec<Pending> = Vec::new();
        for priority in [Priority::High, Priority::Normal, Priority::Low] {
            'tier: loop {
                let mut took = false;
                for q in st.tenants.values_mut() {
                    if picked.len() >= cap {
                        break 'tier;
                    }
                    if q.front().map(|p| p.priority == priority).unwrap_or(false) {
                        if let Some(p) = q.pop_front() {
                            picked.push(p);
                            took = true;
                        }
                    }
                }
                if !took {
                    break;
                }
            }
            if picked.len() >= cap {
                break;
            }
        }
        st.queued -= picked.len();
        st.tenants.retain(|_, q| !q.is_empty());
        picked.sort_by_key(|p| p.seq);
        picked
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::workloads::dag1;

    fn names(batch: &[Pending]) -> Vec<String> {
        batch.iter().map(|p| p.tenant.clone()).collect()
    }

    #[test]
    fn bounded_queue_rejects_at_exactly_the_bound() {
        let mb = Mailbox::new(2);
        assert!(mb.submit("a", dag1(), Priority::Normal).is_ok());
        assert!(mb.submit("a", dag1(), Priority::Normal).is_ok());
        match mb.submit("a", dag1(), Priority::Normal) {
            Err(SubmitError::QueueFull { tenant, bound }) => {
                assert_eq!(tenant, "a");
                assert_eq!(bound, 2);
            }
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // The bound is per tenant: another tenant is still admitted.
        assert!(mb.submit("b", dag1(), Priority::Normal).is_ok());
        // Draining frees capacity again.
        let batch = mb.take_batch(usize::MAX);
        assert_eq!(batch.len(), 3);
        assert!(mb.submit("a", dag1(), Priority::Normal).is_ok());
    }

    #[test]
    fn shutdown_rejects_new_submissions() {
        let mb = Mailbox::new(0);
        assert!(mb.submit("a", dag1(), Priority::Normal).is_ok());
        mb.begin_shutdown();
        assert_eq!(
            mb.submit("a", dag1(), Priority::Normal).unwrap_err(),
            SubmitError::ShuttingDown
        );
        // Work queued before shutdown is still drainable.
        assert_eq!(mb.take_batch(usize::MAX).len(), 1);
    }

    #[test]
    fn capped_batch_round_robins_across_tenants() {
        let mb = Mailbox::new(0);
        // A flooding tenant enqueues four, a quiet one enqueues one, late.
        for _ in 0..4 {
            mb.submit("flood", dag1(), Priority::Normal).unwrap();
        }
        mb.submit("quiet", dag1(), Priority::Normal).unwrap();
        // A batch of two must contain one from each tenant.
        let batch = mb.take_batch(2);
        let mut t = names(&batch);
        t.sort();
        assert_eq!(t, ["flood", "quiet"]);
        assert_eq!(mb.queued(), 3);
    }

    #[test]
    fn priority_tiers_jump_the_line() {
        let mb = Mailbox::new(0);
        mb.submit("a", dag1(), Priority::Low).unwrap();
        mb.submit("b", dag1(), Priority::Normal).unwrap();
        mb.submit("c", dag1(), Priority::High).unwrap();
        let batch = mb.take_batch(1);
        assert_eq!(names(&batch), ["c"]);
        let batch = mb.take_batch(1);
        assert_eq!(names(&batch), ["b"]);
        let batch = mb.take_batch(1);
        assert_eq!(names(&batch), ["a"]);
    }

    #[test]
    fn unbounded_batch_is_admission_order() {
        let mb = Mailbox::new(0);
        // Interleaved tenants; the full batch must come back in global
        // admission order regardless of the per-tenant queues.
        mb.submit("b", dag1(), Priority::Normal).unwrap();
        mb.submit("a", dag1(), Priority::Normal).unwrap();
        mb.submit("b", dag1(), Priority::Normal).unwrap();
        mb.submit("c", dag1(), Priority::Normal).unwrap();
        let batch = mb.take_batch(usize::MAX);
        assert_eq!(names(&batch), ["b", "a", "b", "c"]);
        let seqs: Vec<u64> = batch.iter().map(|p| p.seq).collect();
        assert_eq!(seqs, [0, 1, 2, 3]);
        assert_eq!(mb.queued(), 0);
        assert!(mb.depths().is_empty());
    }

    #[test]
    fn within_a_tenant_submissions_stay_fifo() {
        let mb = Mailbox::new(0);
        mb.submit("a", dag1(), Priority::Normal).unwrap();
        mb.submit("a", dag1(), Priority::High).unwrap();
        // The high-priority submission is behind its tenant's earlier
        // normal one: per-tenant FIFO wins (documented contract).
        let batch = mb.take_batch(1);
        assert_eq!(batch[0].priority, Priority::Normal);
    }

    #[test]
    fn depths_track_queues() {
        let mb = Mailbox::new(0);
        mb.submit("x", dag1(), Priority::Normal).unwrap();
        mb.submit("x", dag1(), Priority::Normal).unwrap();
        mb.submit("y", dag1(), Priority::Normal).unwrap();
        assert_eq!(
            mb.depths(),
            vec![("x".to_string(), 2), ("y".to_string(), 1)]
        );
    }

    #[test]
    fn wait_returns_promptly_on_notify() {
        use std::sync::Arc;
        let mb = Arc::new(Mailbox::new(0));
        let mb2 = mb.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            mb2.begin_shutdown();
        });
        let t0 = Instant::now();
        // Far shorter than the 5s timeout: the notify must wake us.
        let view = loop {
            let v = mb.wait(Duration::from_secs(5));
            if v.shutting_down {
                break v;
            }
        };
        assert!(view.shutting_down);
        assert!(t0.elapsed() < Duration::from_secs(4));
        t.join().unwrap();
    }
}
