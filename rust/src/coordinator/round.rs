//! The shared per-round optimization pipeline: bootstrap histories →
//! fit predictor → build [`Problem`] → plan → execute → feed logs back.
//!
//! Both coordinator front-ends run rounds through [`RoundEngine`] — the
//! virtual-time [`BatchRunner`](super::BatchRunner) calls
//! [`RoundEngine::run_round`] synchronously, while the threaded service
//! control plane ([`super::control`]) runs the same stages split across
//! its dispatch/worker/commit protocol — so the two cannot drift
//! semantically (the service counterpart of the PR 3
//! `build_round_problem`/`record_outcomes` unification).
//!
//! RNG discipline: [`RoundEngine::build_problem`] consumes bootstrap
//! draws in DAG/task order, then the Agora plan path consumes exactly
//! one `next_u64` for the optimizer seed, then execution consumes the
//! simulator's draws. Keeping the draw order identical to the legacy
//! inline pipelines is what pins seeded results bit-for-bit.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{Context, Result};

use super::batch::Strategy;
use crate::cluster::{Capacity, ConfigSpace, CostModel};
use crate::dag::Dag;
use crate::predictor::{
    bootstrap_history, profiling_configs_for, scoped_task_name, EventLog, LearnedPredictor,
    Predictor,
};
use crate::sim::{self, ReplanPolicy};
use crate::solver::{Agora, AgoraOptions, Goal, Mode, Problem, Reservation, Schedule};
use crate::util::Rng;

/// One executed round: the problem it was planned against and the
/// realized execution report.
pub(crate) struct RoundOutcome {
    /// The round's problem (task table, config space, occupancy).
    pub(crate) problem: Problem,
    /// The simulator's realized report.
    pub(crate) report: sim::ExecutionReport,
}

/// The per-round pipeline, borrowing the coordinator's round-invariant
/// configuration.
pub(crate) struct RoundEngine<'a> {
    /// Simulated cluster capacity.
    pub(crate) capacity: Capacity,
    /// Candidate configuration space.
    pub(crate) space: &'a ConfigSpace,
    /// Pricing model for planning and realized accounting.
    pub(crate) cost_model: &'a CostModel,
    /// Mid-flight re-planning policy applied to execution.
    pub(crate) replan: &'a ReplanPolicy,
}

impl RoundEngine<'_> {
    /// Assemble one round's problem in round-local time (releases 0):
    /// fetch/bootstrap each DAG's task history from `log_db` (keyed by
    /// the canonical scoped task name — the same key realized runs are
    /// written back under), fit the predictor, predict the grid.
    pub(crate) fn build_problem(
        &self,
        dags: &[Dag],
        log_db: &mut HashMap<String, EventLog>,
        rng: &mut Rng,
    ) -> Problem {
        let releases = vec![0.0f64; dags.len()];
        let profiling = profiling_configs_for(self.space);
        let mut logs: Vec<EventLog> = Vec::new();
        for d in dags {
            for t in &d.tasks {
                let key = scoped_task_name(&d.name, &t.name);
                let entry = log_db
                    .entry(key.clone())
                    .or_insert_with(|| bootstrap_history(&key, &t.profile, &profiling, rng));
                logs.push(entry.clone());
            }
        }
        let predictor = LearnedPredictor::fit(&logs);
        let grid = predictor.predict(self.space);
        Problem::new(
            dags,
            &releases,
            self.capacity,
            self.space.clone(),
            grid,
            self.cost_model.clone(),
        )
    }

    /// The service's co-optimizer options for one round attempt. Pulled
    /// out so dispatch (control thread) and retry redispatch construct
    /// byte-identical options from a stored seed.
    pub(crate) fn agora_options(
        goal: Goal,
        mode: Mode,
        seed: u64,
        parallelism: usize,
    ) -> AgoraOptions {
        AgoraOptions {
            goal,
            mode,
            params: crate::solver::AnnealParams::fast(),
            seed,
            parallelism,
            ..Default::default()
        }
    }

    /// Run the co-optimizer with a pre-drawn seed, accumulating its
    /// wall-clock overhead.
    pub(crate) fn optimize(
        p: &Problem,
        goal: Goal,
        mode: Mode,
        seed: u64,
        parallelism: usize,
        overhead: &mut Duration,
    ) -> Schedule {
        let agora = Agora::new(Self::agora_options(goal, mode, seed, parallelism));
        let plan = agora.optimize(p);
        *overhead += plan.overhead;
        plan.schedule
    }

    /// Plan one round's batch with a [`Strategy`]. The Airflow baseline
    /// draws no RNG; the Agora arms draw exactly one seed — identical
    /// across admission modes so runs stay comparable per seed.
    pub(crate) fn plan(
        &self,
        strategy: &Strategy,
        parallelism: usize,
        p: &Problem,
        round: usize,
        rng: &mut Rng,
        overhead: &mut Duration,
    ) -> Result<Schedule> {
        Ok(match strategy {
            Strategy::Airflow => {
                use crate::baselines::{AirflowScheduler, Scheduler};
                AirflowScheduler::default()
                    .schedule(p)
                    .with_context(|| format!("scheduling round {round}"))?
            }
            Strategy::Agora(goal) => {
                let seed = rng.next_u64();
                Self::optimize(p, *goal, Mode::CoOptimize, seed, parallelism, overhead)
            }
            Strategy::AgoraMode(goal, mode) => {
                let seed = rng.next_u64();
                Self::optimize(p, *goal, *mode, seed, parallelism, overhead)
            }
        })
    }

    /// Execute one planned round on the simulated cluster (closed-loop
    /// when the replan policy is armed; per-round seed derivation keeps
    /// injected divergence decorrelated across rounds).
    pub(crate) fn execute(
        &self,
        p: &Problem,
        dags: &[Dag],
        schedule: &Schedule,
        round: usize,
        rng: &mut Rng,
    ) -> sim::ExecutionReport {
        sim::execute_with_policy(
            p,
            dags,
            schedule,
            self.cost_model,
            rng,
            &self.replan.for_round(round as u64 - 1),
        )
    }

    /// Feed realized runs back into the event-log database under the
    /// canonical scoped key (the §4.1 adaptive loop).
    pub(crate) fn feed_back(
        log_db: &mut HashMap<String, EventLog>,
        p: &Problem,
        report: &sim::ExecutionReport,
    ) {
        for (t, log) in report.new_logs.iter().enumerate() {
            let key = p.tasks[t].name.clone();
            let entry = log_db
                .entry(key)
                .or_insert_with(|| EventLog::new(&p.tasks[t].name));
            entry.runs.extend(log.runs.iter().cloned());
        }
    }

    /// Realized dollar cost of one DAG (by batch index) in a report.
    pub(crate) fn dag_cost(
        cost_model: &CostModel,
        p: &Problem,
        report: &sim::ExecutionReport,
        d: usize,
    ) -> f64 {
        report
            .records
            .iter()
            .filter(|r| p.tasks[r.task].dag == d)
            .map(|r| cost_model.realized_cost(&p.space.configs[r.config], r.runtime))
            .sum()
    }

    /// The whole synchronous pipeline for one round: build the problem
    /// (seeding `occupancy` under continuous admission), plan with the
    /// strategy, execute, feed logs back.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_round(
        &self,
        strategy: &Strategy,
        parallelism: usize,
        dags: &[Dag],
        round: usize,
        occupancy: Option<Vec<Reservation>>,
        log_db: &mut HashMap<String, EventLog>,
        rng: &mut Rng,
        overhead: &mut Duration,
    ) -> Result<RoundOutcome> {
        let mut p = self.build_problem(dags, log_db, rng);
        if let Some(reservations) = occupancy {
            p = p.with_occupancy(reservations, 0.0);
        }
        let schedule = self.plan(strategy, parallelism, &p, round, rng, overhead)?;
        let report = self.execute(&p, dags, &schedule, round, rng);
        Self::feed_back(log_db, &p, &report);
        Ok(RoundOutcome { problem: p, report })
    }
}

/// Spot preemptions realized by one execution report — shared by every
/// coordinator loop so their accounting cannot drift.
pub(crate) fn preemption_count(report: &sim::ExecutionReport) -> usize {
    report.records.iter().map(|r| r.preemptions as usize).sum()
}

/// Busy core-seconds realized by one execution report.
pub(crate) fn busy_core_seconds(p: &Problem, report: &sim::ExecutionReport) -> f64 {
    report
        .records
        .iter()
        .map(|r| p.space.configs[r.config].vcpus() * r.runtime)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::workloads::dag1;

    fn engine_fixture() -> (Capacity, ConfigSpace, CostModel, ReplanPolicy) {
        (
            Capacity::micro(),
            ConfigSpace::standard(),
            CostModel::OnDemand,
            ReplanPolicy::off(),
        )
    }

    #[test]
    fn run_round_matches_the_inline_pipeline_bit_for_bit() {
        // The engine against a hand-inlined legacy pipeline, same seed:
        // identical realized completions and costs.
        let (capacity, space, cost_model, replan) = engine_fixture();
        let dags = vec![dag1()];

        // Engine path.
        let engine = RoundEngine {
            capacity,
            space: &space,
            cost_model: &cost_model,
            replan: &replan,
        };
        let mut db_a = HashMap::new();
        let mut rng_a = Rng::new(77);
        let mut overhead = Duration::ZERO;
        let out = engine
            .run_round(
                &Strategy::Agora(Goal::Balanced),
                1,
                &dags,
                1,
                None,
                &mut db_a,
                &mut rng_a,
                &mut overhead,
            )
            .expect("round");

        // Inline legacy path (the pre-refactor serve_round stages).
        let mut db_b: HashMap<String, EventLog> = HashMap::new();
        let mut rng_b = Rng::new(77);
        let profiling = profiling_configs_for(&space);
        let mut logs = Vec::new();
        for d in &dags {
            for t in &d.tasks {
                let key = scoped_task_name(&d.name, &t.name);
                let entry = db_b
                    .entry(key.clone())
                    .or_insert_with(|| bootstrap_history(&key, &t.profile, &profiling, &mut rng_b));
                logs.push(entry.clone());
            }
        }
        let grid = LearnedPredictor::fit(&logs).predict(&space);
        let p = Problem::new(
            &dags,
            &[0.0],
            capacity,
            space.clone(),
            grid,
            cost_model.clone(),
        );
        let plan = Agora::new(AgoraOptions {
            goal: Goal::Balanced,
            mode: Mode::CoOptimize,
            params: crate::solver::AnnealParams::fast(),
            seed: rng_b.next_u64(),
            parallelism: 1,
            ..Default::default()
        })
        .optimize(&p);
        let report = sim::execute_with_policy(
            &p,
            &dags,
            &plan.schedule,
            &cost_model,
            &mut rng_b,
            &replan.for_round(0),
        );

        assert_eq!(
            out.report.dag_completion[0].to_bits(),
            report.dag_completion[0].to_bits()
        );
        assert_eq!(
            RoundEngine::dag_cost(&cost_model, &out.problem, &out.report, 0).to_bits(),
            RoundEngine::dag_cost(&cost_model, &p, &report, 0).to_bits()
        );
        assert!(overhead > Duration::ZERO);
    }

    #[test]
    fn feed_back_appends_under_the_scoped_key() {
        let (capacity, space, cost_model, replan) = engine_fixture();
        let engine = RoundEngine {
            capacity,
            space: &space,
            cost_model: &cost_model,
            replan: &replan,
        };
        let dags = vec![dag1()];
        let mut db = HashMap::new();
        let mut rng = Rng::new(3);
        let mut overhead = Duration::ZERO;
        engine
            .run_round(
                &Strategy::Airflow,
                1,
                &dags,
                1,
                None,
                &mut db,
                &mut rng,
                &mut overhead,
            )
            .expect("round");
        // every task has bootstrap + one realized run under its scoped key
        assert_eq!(db.len(), dags[0].tasks.len());
        assert!(db.keys().all(|k| k.starts_with("DAG1/")));
        assert!(db.values().all(|l| l.len() >= 2));
    }
}
