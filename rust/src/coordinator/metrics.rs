//! Macro-run metric aggregation (Fig. 11's panels).

use super::batch::MacroReport;
use crate::util::stats;

/// Headline macro comparison: normalized cost + completion vs a baseline.
#[derive(Debug, Clone)]
pub struct MacroSummary {
    /// Strategy name of the compared run.
    pub strategy: String,
    /// Run cost / baseline cost.
    pub normalized_cost: f64,
    /// Run total completion / baseline total completion.
    pub normalized_completion: f64,
    /// Fraction of DAGs whose completion improved vs the baseline.
    pub improved_fraction: f64,
    /// Fraction of DAGs with >= 95% completion improvement.
    pub near_total_fraction: f64,
}

impl MacroSummary {
    /// Compare a run against a baseline run over the same trace. DAGs are
    /// matched by name.
    pub fn against(base: &MacroReport, run: &MacroReport) -> MacroSummary {
        let improvements = improvement_cdf(base, run);
        let improved = improvements.iter().filter(|&&i| i > 0.0).count();
        let near_total = improvements.iter().filter(|&&i| i >= 0.95).count();
        MacroSummary {
            strategy: run.strategy.clone(),
            normalized_cost: run.total_cost / base.total_cost.max(1e-9),
            normalized_completion: run.total_completion / base.total_completion.max(1e-9),
            improved_fraction: improved as f64 / improvements.len().max(1) as f64,
            near_total_fraction: near_total as f64 / improvements.len().max(1) as f64,
        }
    }
}

/// One row of the continuous-vs-round-barrier admission comparison the
/// macro benchmarks print: DAG-completion distribution, queueing delay
/// and cluster utilization at the run's realized cost.
#[derive(Debug, Clone)]
pub struct AdmissionStats {
    /// Admission-mode name (`"rounds"` or `"continuous"`).
    pub admission: String,
    /// Mean per-DAG completion time (seconds).
    pub mean_completion: f64,
    /// 95th-percentile per-DAG completion time (seconds).
    pub p95_completion: f64,
    /// Mean queueing delay: first task launch minus submission (seconds).
    pub mean_queue_delay: f64,
    /// Busy core-seconds over cluster cores times the run horizon
    /// (virtual t = 0 to the last finish).
    pub utilization: f64,
    /// Realized total dollar cost (the equal-budget axis of the
    /// comparison).
    pub total_cost: f64,
}

impl AdmissionStats {
    /// Extract the comparison row from a macro report.
    pub fn of(report: &MacroReport) -> AdmissionStats {
        AdmissionStats {
            admission: report.admission.clone(),
            mean_completion: report.mean_completion,
            p95_completion: report.p95_completion,
            mean_queue_delay: report.mean_queue_delay,
            utilization: report.utilization,
            total_cost: report.total_cost,
        }
    }

    /// Render as a bench-table row: mode, mean, p95, queue delay,
    /// utilization %, cost.
    pub fn row(&self) -> Vec<String> {
        vec![
            self.admission.clone(),
            format!("{:.0}s", self.mean_completion),
            format!("{:.0}s", self.p95_completion),
            format!("{:.0}s", self.mean_queue_delay),
            format!("{:.0}%", self.utilization * 100.0),
            format!("${:.2}", self.total_cost),
        ]
    }
}

/// One row of the deadline/SLA comparison (Fig. 13): admission verdicts
/// and the realized penalty bill of a run under an SLA policy.
#[derive(Debug, Clone)]
pub struct SlaStats {
    /// Strategy name of the run.
    pub strategy: String,
    /// DAGs that finished within their deadline.
    pub met: usize,
    /// DAGs that finished past their deadline.
    pub missed: usize,
    /// DAGs rejected by admission control.
    pub rejected: usize,
    /// Total soft-SLA penalty dollars across missed DAGs.
    pub penalty_cost: f64,
    /// Realized total dollar cost of the admitted work.
    pub total_cost: f64,
}

impl SlaStats {
    /// Extract the comparison row from a macro report.
    pub fn of(report: &MacroReport) -> SlaStats {
        SlaStats {
            strategy: report.strategy.clone(),
            met: report.sla_met,
            missed: report.sla_missed,
            rejected: report.rejected,
            penalty_cost: report.penalty_cost,
            total_cost: report.total_cost,
        }
    }

    /// Render as a bench-table row: strategy, met, missed, rejected,
    /// penalty, cost.
    pub fn row(&self) -> Vec<String> {
        vec![
            self.strategy.clone(),
            format!("{}", self.met),
            format!("{}", self.missed),
            format!("{}", self.rejected),
            format!("${:.2}", self.penalty_cost),
            format!("${:.2}", self.total_cost),
        ]
    }
}

/// Per-DAG completion-time improvement of `run` vs `base`
/// ((base - run)/base per DAG, matched by name), sorted ascending —
/// the CDF panel of Fig. 11.
pub fn improvement_cdf(base: &MacroReport, run: &MacroReport) -> Vec<f64> {
    let base_by_name: std::collections::HashMap<&str, f64> = base
        .outcomes
        .iter()
        .map(|o| (o.name.as_str(), o.completion))
        .collect();
    let mut improvements: Vec<f64> = run
        .outcomes
        .iter()
        .filter_map(|o| {
            base_by_name
                .get(o.name.as_str())
                .map(|&b| stats::improvement(b, o.completion))
        })
        .collect();
    improvements.sort_by(|a, b| a.total_cmp(b));
    improvements
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batch::DagOutcome;
    use std::time::Duration;

    fn report(strategy: &str, completions: &[(&str, f64, f64)]) -> MacroReport {
        let values: Vec<f64> = completions.iter().map(|c| c.1).collect();
        MacroReport {
            strategy: strategy.into(),
            admission: "rounds".into(),
            outcomes: completions
                .iter()
                .map(|&(name, completion, cost)| DagOutcome {
                    name: name.into(),
                    submit_time: 0.0,
                    first_start: 0.0,
                    finish_time: completion,
                    completion,
                    cost,
                })
                .collect(),
            total_cost: completions.iter().map(|c| c.2).sum(),
            total_completion: values.iter().sum(),
            mean_completion: crate::util::stats::mean(&values),
            p95_completion: crate::util::stats::percentile(&values, 95.0),
            mean_queue_delay: 0.0,
            utilization: 0.5,
            rounds: 1,
            optimizer_overhead: Duration::ZERO,
            replans: 0,
            preemptions: 0,
            sla_met: 0,
            sla_missed: 0,
            rejected: 0,
            penalty_cost: 0.0,
        }
    }

    #[test]
    fn improvement_cdf_matches_by_name() {
        let base = report("base", &[("a", 100.0, 1.0), ("b", 200.0, 2.0)]);
        let run = report("run", &[("b", 100.0, 1.0), ("a", 50.0, 0.5)]);
        let cdf = improvement_cdf(&base, &run);
        assert_eq!(cdf, vec![0.5, 0.5]);
    }

    #[test]
    fn admission_stats_extract_report_fields() {
        let r = report("airflow", &[("a", 100.0, 1.0), ("b", 300.0, 3.0)]);
        let s = AdmissionStats::of(&r);
        assert_eq!(s.admission, "rounds");
        assert!((s.mean_completion - 200.0).abs() < 1e-9);
        assert!((s.total_cost - 4.0).abs() < 1e-9);
        assert_eq!(s.row().len(), 6);
    }

    #[test]
    fn sla_stats_extract_report_fields() {
        let mut r = report("agora", &[("a", 100.0, 1.0)]);
        r.sla_met = 3;
        r.sla_missed = 1;
        r.rejected = 2;
        r.penalty_cost = 4.5;
        let s = SlaStats::of(&r);
        assert_eq!((s.met, s.missed, s.rejected), (3, 1, 2));
        assert!((s.penalty_cost - 4.5).abs() < 1e-12);
        assert_eq!(s.row().len(), 6);
    }

    #[test]
    fn summary_normalizes() {
        let base = report("base", &[("a", 100.0, 2.0), ("b", 100.0, 2.0)]);
        let run = report("run", &[("a", 50.0, 1.0), ("b", 120.0, 1.0)]);
        let s = MacroSummary::against(&base, &run);
        assert!((s.normalized_cost - 0.5).abs() < 1e-9);
        assert!((s.normalized_completion - 0.85).abs() < 1e-9);
        assert!((s.improved_fraction - 0.5).abs() < 1e-9);
        assert_eq!(s.near_total_fraction, 0.0);
    }
}
