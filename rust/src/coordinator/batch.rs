//! Batch optimization rounds over a submission trace (virtual time).
//!
//! This is the macro-benchmark engine (Fig. 11): jobs arrive over a
//! window; the trigger policy groups them into rounds; each round is
//! co-optimized (or scheduled by a baseline) and executed on the
//! simulated cluster; completed runs feed event logs back into the
//! Predictor database (the §4.1 adaptive loop).
//!
//! Two admission modes are supported ([`Admission`]):
//!
//! * **rounds** — the historical bulk-synchronous barrier: a round's
//!   batch is planned against an empty cluster and the next round cannot
//!   start until the previous one has fully drained.
//! * **continuous** — at each trigger the coordinator prunes its
//!   occupancy ledger to the still-in-flight reservations, seeds the new
//!   round's [`Problem`] with them ([`Problem::with_occupancy`] — every
//!   scheduling primitive packs around them through the shared
//!   block-indexed [`crate::solver::Timeline`] kernel), and plans +
//!   executes the batch
//!   *into the gaps* of the occupied-cluster timeline. Outcomes are
//!   accounted at true finish times in absolute virtual time, so rounds
//!   overlap instead of queueing.

use std::collections::{HashMap, HashSet};
use std::time::Duration;

use anyhow::Result;

use super::round::{busy_core_seconds, preemption_count, RoundEngine, RoundOutcome};
use super::{Admission, OccupancyLedger, TriggerPolicy};
use crate::cluster::{Capacity, ConfigSpace, CostModel};
use crate::dag::Dag;
#[cfg(test)]
use crate::predictor::default_profiling_configs;
use crate::predictor::EventLog;
use crate::sim::{self, ReplanPolicy};
use crate::solver::{Agora, Goal, Mode, Problem, Reservation, Schedule, Sla};
use crate::trace::TracedJob;
use crate::util::{stats, Rng};

/// How each round is scheduled.
#[derive(Debug, Clone)]
pub enum Strategy {
    /// Default Airflow: default configs, priority-weight dispatch.
    Airflow,
    /// Full AGORA co-optimization with a goal.
    Agora(Goal),
    /// AGORA ablations (§5.2).
    AgoraMode(Goal, Mode),
}

impl Strategy {
    /// Stable name used in report tables.
    pub fn name(&self) -> String {
        match self {
            Strategy::Airflow => "airflow".into(),
            Strategy::Agora(g) => format!("agora[{}]", g.name()),
            Strategy::AgoraMode(g, m) => format!("{}[{}]", m.name(), g.name()),
        }
    }
}

/// Per-DAG SLA attachment + admission policy for macro runs.
///
/// Each DAG's deadline is fixed at its **first admission evaluation**:
/// `origin + deadline_frac * cp_lb(dag)`, where `cp_lb` is the DAG's
/// critical-path completion lower bound under best-case durations
/// ([`Problem::dag_lower_bounds`]) and `origin` the round's admission
/// instant — the SLA clock starts when the coordinator first considers
/// the DAG, so trigger-batching delay does not eat the budget. The
/// deadline is remembered across deferrals.
#[derive(Debug, Clone, PartialEq)]
pub struct SlaPolicy {
    /// Deadline slack as a multiple of the DAG's critical-path lower
    /// bound (>= 1 is meetable in principle). `<= 0` disables SLAs
    /// entirely — the runner is then bit-identical to the SLA-free one.
    pub deadline_frac: f64,
    /// Dollars accrued per second past a missed deadline (soft
    /// accounting; reported as [`MacroReport::penalty_cost`]).
    pub penalty_per_sec: f64,
    /// Hard SLAs: admission rejects provably-infeasible DAGs (completion
    /// lower bound past the deadline), defers DAGs whose *planned*
    /// completion misses (once — a second miss rejects), and the
    /// attached [`Sla::hard`] arms deadline budgets in the solver.
    pub hard: bool,
    /// Enforce admission control. When false the runner only *accounts*
    /// SLA outcomes (`sla_met`/`sla_missed`/`penalty_cost`) — the
    /// SLA-blind baseline the fig13 bench compares against.
    pub enforce: bool,
}

impl Default for SlaPolicy {
    fn default() -> Self {
        SlaPolicy::off()
    }
}

impl SlaPolicy {
    /// SLAs disabled: no deadlines attached, no admission control, all
    /// SLA report fields zero.
    pub fn off() -> SlaPolicy {
        SlaPolicy {
            deadline_frac: 0.0,
            penalty_per_sec: 0.0,
            hard: false,
            enforce: true,
        }
    }

    /// Whether this policy attaches no SLAs at all.
    pub fn is_off(&self) -> bool {
        self.deadline_frac <= 0.0
    }

    /// The [`Sla`] attached to one DAG given its deadline in round-local
    /// time.
    pub(crate) fn sla_for(&self, local_deadline: f64) -> Sla {
        if self.hard {
            Sla::hard(local_deadline)
        } else {
            Sla::soft(local_deadline, self.penalty_per_sec)
        }
    }
}

/// Per-DAG outcome in a macro run.
#[derive(Debug, Clone)]
pub struct DagOutcome {
    /// DAG name (job id in the trace).
    pub name: String,
    /// When the DAG was submitted (virtual time).
    pub submit_time: f64,
    /// When the DAG's first task actually launched (virtual time);
    /// `first_start - submit_time` is the queueing delay.
    pub first_start: f64,
    /// Wall-clock completion instant (virtual time).
    pub finish_time: f64,
    /// finish - submit.
    pub completion: f64,
    /// Realized dollar cost of the DAG's tasks.
    pub cost: f64,
}

/// Full macro-run report.
#[derive(Debug, Clone)]
pub struct MacroReport {
    /// Name of the scheduling strategy that produced this run.
    pub strategy: String,
    /// Admission-mode name (`"rounds"` or `"continuous"`).
    pub admission: String,
    /// Per-DAG outcomes, in admission order.
    pub outcomes: Vec<DagOutcome>,
    /// Realized total dollar cost across all DAGs.
    pub total_cost: f64,
    /// Sum of per-DAG completion times (the paper's "total DAG completion
    /// time" metric).
    pub total_completion: f64,
    /// Mean per-DAG completion time.
    pub mean_completion: f64,
    /// 95th-percentile per-DAG completion time.
    pub p95_completion: f64,
    /// Mean queueing delay: first task launch minus submission.
    pub mean_queue_delay: f64,
    /// Cluster utilization: busy core-seconds over cluster cores times
    /// the run horizon (virtual t = 0 to the last finish).
    pub utilization: f64,
    /// Optimization rounds fired by the trigger policy.
    pub rounds: usize,
    /// Total optimizer wall-clock overhead across rounds.
    pub optimizer_overhead: Duration,
    /// Mid-flight replans fired across all rounds (0 when the policy is
    /// off).
    pub replans: usize,
    /// Spot preemptions realized across all rounds (0 without spot
    /// capacity or with the interruption process off).
    pub preemptions: usize,
    /// Admitted DAGs that finished at or before their SLA deadline
    /// (0 with SLAs off).
    pub sla_met: usize,
    /// Admitted DAGs that finished past their SLA deadline (0 with SLAs
    /// off).
    pub sla_missed: usize,
    /// DAGs rejected by SLA admission control — provably unable (or,
    /// after a deferral, still planned unable) to meet a hard deadline.
    /// They never execute and have no [`DagOutcome`].
    pub rejected: usize,
    /// Dollars of soft-SLA penalty accrued across all missed deadlines
    /// (`penalty_per_sec * overshoot`, summed; 0 whenever
    /// `sla_missed == 0`).
    pub penalty_cost: f64,
}

/// Virtual-time batch runner.
pub struct BatchRunner {
    /// Cluster capacity shared by every round.
    pub capacity: Capacity,
    /// Candidate configuration space handed to the optimizer.
    pub space: ConfigSpace,
    /// Pricing model for realized costs.
    pub cost_model: CostModel,
    /// When to fire optimization rounds.
    pub trigger: TriggerPolicy,
    /// How each round is scheduled.
    pub strategy: Strategy,
    /// Seed of the runner's RNG stream (bootstraps, noise, optimizer).
    pub seed: u64,
    /// Portfolio chains handed to the co-optimizer per round
    /// (1 = deterministic single chain).
    pub parallelism: usize,
    /// Mid-flight re-planning + divergence injection applied to every
    /// round's execution (off by default).
    pub replan: ReplanPolicy,
    /// Round-barrier or continuous admission (default: rounds, the
    /// historical bulk-synchronous behaviour).
    pub admission: Admission,
    /// Per-DAG SLA attachment + admission control (off by default).
    pub sla: SlaPolicy,
    /// Event-log database (scoped task name -> history), persisted
    /// across rounds.
    pub log_db: HashMap<String, EventLog>,
}

impl BatchRunner {
    /// A runner with default trigger policy, on-demand pricing, a single
    /// optimizer chain, replanning off and round-barrier admission.
    pub fn new(capacity: Capacity, space: ConfigSpace, strategy: Strategy, seed: u64) -> Self {
        BatchRunner {
            capacity,
            space,
            cost_model: CostModel::OnDemand,
            trigger: TriggerPolicy::default(),
            strategy,
            seed,
            parallelism: 1,
            replan: ReplanPolicy::off(),
            admission: Admission::Rounds,
            sla: SlaPolicy::off(),
            log_db: HashMap::new(),
        }
    }

    /// Builder-style portfolio knob.
    pub fn with_parallelism(mut self, parallelism: usize) -> Self {
        self.parallelism = parallelism.max(1);
        self
    }

    /// Builder-style replan/divergence knob.
    pub fn with_replan(mut self, replan: ReplanPolicy) -> Self {
        self.replan = replan;
        self
    }

    /// Builder-style admission knob.
    pub fn with_admission(mut self, admission: Admission) -> Self {
        self.admission = admission;
        self
    }

    /// Builder-style pricing knob (e.g. [`CostModel::Market`] for
    /// heterogeneous-market runs; on-demand by default).
    pub fn with_cost_model(mut self, cost_model: CostModel) -> Self {
        self.cost_model = cost_model;
        self
    }

    /// Builder-style SLA knob (deadline attachment + admission control).
    pub fn with_sla(mut self, sla: SlaPolicy) -> Self {
        self.sla = sla;
        self
    }

    /// Core demand of one queued task at the default configuration (the
    /// unit the trigger policy measures queue pressure in).
    fn default_cores(&self) -> f64 {
        let c = Agora::default_config(&self.space);
        self.space.configs[c].vcpus()
    }

    /// Record per-DAG outcomes of one executed round. `origin` is the
    /// round's virtual-time origin (the round start under the barrier,
    /// the admission instant under continuous admission); realized
    /// record times are round-local and shift by it.
    fn record_outcomes(
        &self,
        outcomes: &mut Vec<DagOutcome>,
        p: &Problem,
        batch: &[TracedJob],
        report: &sim::ExecutionReport,
        origin: f64,
    ) {
        for (d, job) in batch.iter().enumerate() {
            let finish = origin + report.dag_completion[d];
            let first = report
                .records
                .iter()
                .filter(|r| p.tasks[r.task].dag == d)
                .map(|r| r.start)
                .fold(f64::INFINITY, f64::min);
            outcomes.push(DagOutcome {
                name: job.dag.name.clone(),
                submit_time: job.submit_time,
                first_start: if first.is_finite() {
                    origin + first
                } else {
                    origin
                },
                finish_time: finish,
                completion: finish - job.submit_time,
                cost: RoundEngine::dag_cost(&self.cost_model, p, report, d),
            });
        }
    }

    /// Aggregate per-DAG outcomes into the macro report. `deadlines`
    /// maps DAG names to the absolute deadline fixed at first admission
    /// (empty with SLAs off); `rejected` counts DAGs SLA admission
    /// turned away.
    #[allow(clippy::too_many_arguments)]
    fn summarize(
        &self,
        outcomes: Vec<DagOutcome>,
        rounds: usize,
        overhead: Duration,
        replans: usize,
        preemptions: usize,
        busy_core_seconds: f64,
        deadlines: &HashMap<String, f64>,
        rejected: usize,
    ) -> MacroReport {
        let mut sla_met = 0usize;
        let mut sla_missed = 0usize;
        let mut penalty_cost = 0.0f64;
        for o in &outcomes {
            if let Some(&deadline) = deadlines.get(&o.name) {
                if o.finish_time <= deadline {
                    sla_met += 1;
                } else {
                    sla_missed += 1;
                    penalty_cost += (o.finish_time - deadline) * self.sla.penalty_per_sec;
                }
            }
        }
        let total_cost = outcomes.iter().map(|o| o.cost).sum();
        let total_completion = outcomes.iter().map(|o| o.completion).sum();
        let completions: Vec<f64> = outcomes.iter().map(|o| o.completion).collect();
        let delays: Vec<f64> = outcomes
            .iter()
            .map(|o| (o.first_start - o.submit_time).max(0.0))
            .collect();
        let horizon = outcomes.iter().map(|o| o.finish_time).fold(0.0, f64::max);
        let utilization = if horizon > 0.0 {
            busy_core_seconds / (self.capacity.vcpus * horizon)
        } else {
            0.0
        };
        MacroReport {
            strategy: self.strategy.name(),
            admission: self.admission.name().to_string(),
            mean_completion: stats::mean(&completions),
            p95_completion: stats::percentile(&completions, 95.0),
            mean_queue_delay: stats::mean(&delays),
            utilization,
            outcomes,
            total_cost,
            total_completion,
            rounds,
            optimizer_overhead: overhead,
            replans,
            preemptions,
            sla_met,
            sla_missed,
            rejected,
            penalty_cost,
        }
    }

    /// Run the whole trace; returns the per-DAG outcomes. A failing
    /// per-round scheduler is propagated as an error (with round context)
    /// instead of panicking the coordinator.
    ///
    /// ```
    /// use agora::cluster::ConfigSpace;
    /// use agora::coordinator::{BatchRunner, Strategy};
    /// use agora::trace::{generate, TraceParams};
    /// use agora::util::Rng;
    ///
    /// let params = TraceParams::tiny();
    /// let jobs = generate(&params, &mut Rng::new(7));
    /// let mut runner = BatchRunner::new(
    ///     params.batch_capacity(),
    ///     ConfigSpace::standard(),
    ///     Strategy::Airflow,
    ///     1,
    /// );
    /// let report = runner.run(&jobs)?;
    /// assert_eq!(report.outcomes.len(), jobs.len());
    /// assert!(report.total_cost > 0.0);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn run(&mut self, jobs: &[TracedJob]) -> Result<MacroReport> {
        match self.admission {
            Admission::Rounds => self.run_rounds(jobs),
            Admission::Continuous => self.run_continuous(jobs),
        }
    }

    /// The historical bulk-synchronous runner: each round is planned
    /// against an empty cluster and `cluster_free` serializes rounds.
    fn run_rounds(&mut self, jobs: &[TracedJob]) -> Result<MacroReport> {
        let mut rng = Rng::new(self.seed);
        let mut outcomes = Vec::new();
        let mut rounds = 0usize;
        let mut overhead = Duration::ZERO;
        let mut replans = 0usize;
        let mut preempts = 0usize;
        let mut busy = 0.0f64;

        // Virtual clock: advance to each trigger firing.
        let mut queue: Vec<&TracedJob> = Vec::new();
        let mut next_job = 0usize;
        let mut clock = 0.0f64;
        let mut last_round = 0.0f64;
        // when the cluster frees up from the previous round
        let mut cluster_free = 0.0f64;
        // queue demand measured at the default config
        let default_cores = self.default_cores();
        // SLA admission state (all inert with the policy off).
        let mut deadlines: HashMap<String, f64> = HashMap::new();
        let mut deferred: Vec<TracedJob> = Vec::new();
        let mut deferred_once: HashSet<String> = HashSet::new();
        let mut rejected = 0usize;

        loop {
            // Admit arrivals up to the clock.
            while next_job < jobs.len() && jobs[next_job].submit_time <= clock {
                queue.push(&jobs[next_job]);
                next_job += 1;
            }

            let queued_demand: f64 = queue
                .iter()
                .map(|j| j.dag.len() as f64 * default_cores)
                .sum::<f64>()
                + deferred
                    .iter()
                    .map(|j| j.dag.len() as f64 * default_cores)
                    .sum::<f64>();
            let fire = self.trigger.should_fire(
                queued_demand,
                self.capacity.vcpus,
                clock - last_round,
                queue.len() + deferred.len(),
            );

            if fire {
                rounds += 1;
                last_round = clock;
                // SLA-deferred DAGs (older) rejoin ahead of fresh queue.
                let mut batch: Vec<TracedJob> = deferred.drain(..).collect();
                batch.extend(queue.drain(..).cloned());
                let round_start = clock.max(cluster_free);

                // The shared per-round pipeline (build → plan → execute
                // → feed back), same stages as the threaded service.
                let engine = RoundEngine {
                    capacity: self.capacity,
                    space: &self.space,
                    cost_model: &self.cost_model,
                    replan: &self.replan,
                };
                let out = if self.sla.is_off() {
                    let dags: Vec<Dag> = batch.iter().map(|j| j.dag.clone()).collect();
                    let out = engine.run_round(
                        &self.strategy,
                        self.parallelism,
                        &dags,
                        rounds,
                        None,
                        &mut self.log_db,
                        &mut rng,
                        &mut overhead,
                    )?;
                    Some((batch, out))
                } else {
                    run_sla_round(
                        &engine,
                        &self.strategy,
                        self.parallelism,
                        &self.sla,
                        batch,
                        None,
                        round_start,
                        rounds,
                        &mut self.log_db,
                        &mut rng,
                        &mut overhead,
                        &mut deadlines,
                        &mut deferred_once,
                        &mut deferred,
                        &mut rejected,
                    )?
                };
                if let Some((batch, out)) = out {
                    replans += out.report.replans.len();
                    preempts += preemption_count(&out.report);
                    cluster_free = round_start + out.report.makespan;
                    busy += busy_core_seconds(&out.problem, &out.report);

                    self.record_outcomes(
                        &mut outcomes,
                        &out.problem,
                        &batch,
                        &out.report,
                        round_start,
                    );
                }
            }

            match next_clock(
                jobs,
                next_job,
                queue.is_empty() && deferred.is_empty(),
                last_round,
                self.trigger.interval,
                clock,
            ) {
                Some(c) => clock = c,
                None => break,
            }
        }

        Ok(self.summarize(
            outcomes, rounds, overhead, replans, preempts, busy, &deadlines, rejected,
        ))
    }

    /// Continuous multi-tenant admission: each round is planned and
    /// executed against the residual capacity left by the still-in-flight
    /// reservations of prior rounds (round-local time, occupancy shifted
    /// to the admission instant), and outcomes are accounted at true
    /// finish times in absolute virtual time — a new batch starts filling
    /// the cluster's gaps at the trigger instant instead of queueing
    /// behind the previous round's tail.
    fn run_continuous(&mut self, jobs: &[TracedJob]) -> Result<MacroReport> {
        let mut rng = Rng::new(self.seed);
        let mut outcomes = Vec::new();
        let mut rounds = 0usize;
        let mut overhead = Duration::ZERO;
        let mut replans = 0usize;
        let mut preempts = 0usize;
        let mut busy = 0.0f64;

        let mut queue: Vec<&TracedJob> = Vec::new();
        let mut next_job = 0usize;
        let mut clock = 0.0f64;
        let mut last_round = 0.0f64;
        // Occupancy ledger: realized reservations of every admitted task,
        // in absolute virtual time. Pruned to the in-flight suffix at
        // each admission instant.
        let mut ledger = OccupancyLedger::default();
        let default_cores = self.default_cores();
        // SLA admission state (all inert with the policy off).
        let mut deadlines: HashMap<String, f64> = HashMap::new();
        let mut deferred: Vec<TracedJob> = Vec::new();
        let mut deferred_once: HashSet<String> = HashSet::new();
        let mut rejected = 0usize;

        loop {
            while next_job < jobs.len() && jobs[next_job].submit_time <= clock {
                queue.push(&jobs[next_job]);
                next_job += 1;
            }

            let queued_demand: f64 = queue
                .iter()
                .map(|j| j.dag.len() as f64 * default_cores)
                .sum::<f64>()
                + deferred
                    .iter()
                    .map(|j| j.dag.len() as f64 * default_cores)
                    .sum::<f64>();
            let fire = self.trigger.should_fire(
                queued_demand,
                self.capacity.vcpus,
                clock - last_round,
                queue.len() + deferred.len(),
            );

            if fire {
                rounds += 1;
                last_round = clock;
                // SLA-deferred DAGs (older) rejoin ahead of fresh queue.
                let mut batch: Vec<TracedJob> = deferred.drain(..).collect();
                batch.extend(queue.drain(..).cloned());

                // Snapshot the occupied-cluster timeline and run the
                // shared pipeline in round-local time (origin = the
                // admission instant): the ledger prunes to the in-flight
                // suffix and shifts by -clock; releases/floor are 0, so
                // no task of this batch can start in the past and every
                // scheduler packs into the gaps. Timeline packing is
                // translation-invariant; the local origin keeps the
                // optimizer's percentage energies scale-free regardless
                // of how deep into the trace the round fires.
                let shifted = ledger.snapshot(clock);
                let engine = RoundEngine {
                    capacity: self.capacity,
                    space: &self.space,
                    cost_model: &self.cost_model,
                    replan: &self.replan,
                };
                let out = if self.sla.is_off() {
                    let dags: Vec<Dag> = batch.iter().map(|j| j.dag.clone()).collect();
                    let out = engine.run_round(
                        &self.strategy,
                        self.parallelism,
                        &dags,
                        rounds,
                        Some(shifted),
                        &mut self.log_db,
                        &mut rng,
                        &mut overhead,
                    )?;
                    Some((batch, out))
                } else {
                    run_sla_round(
                        &engine,
                        &self.strategy,
                        self.parallelism,
                        &self.sla,
                        batch,
                        Some(shifted),
                        clock,
                        rounds,
                        &mut self.log_db,
                        &mut rng,
                        &mut overhead,
                        &mut deadlines,
                        &mut deferred_once,
                        &mut deferred,
                        &mut rejected,
                    )?
                };
                if let Some((batch, out)) = out {
                    replans += out.report.replans.len();
                    preempts += preemption_count(&out.report);
                    busy += busy_core_seconds(&out.problem, &out.report);

                    // Every realized record becomes a reservation later
                    // rounds must pack around (ledger is absolute time).
                    ledger.absorb(&out.problem, &out.report, clock);

                    // Outcomes at true finish times (absolute virtual time).
                    self.record_outcomes(&mut outcomes, &out.problem, &batch, &out.report, clock);
                }
            }

            match next_clock(
                jobs,
                next_job,
                queue.is_empty() && deferred.is_empty(),
                last_round,
                self.trigger.interval,
                clock,
            ) {
                Some(c) => clock = c,
                None => break,
            }
        }

        Ok(self.summarize(
            outcomes, rounds, overhead, replans, preempts, busy, &deadlines, rejected,
        ))
    }
}

/// Planned per-DAG completion instants of one schedule (round-local
/// time): max planned end over each DAG's tasks.
fn planned_dag_completions(p: &Problem, schedule: &Schedule) -> Vec<f64> {
    let mut out = vec![0.0f64; p.slas.len()];
    for t in 0..p.len() {
        let end = schedule.start[t] + p.duration(t, schedule.assignment[t]);
        let d = p.tasks[t].dag;
        out[d] = out[d].max(end);
    }
    out
}

/// One SLA-gated round, shared by both admission modes.
///
/// Stages: build the full batch's problem (bootstrap draws happen once,
/// in submission order — rebuilds below hit the event-log cache and draw
/// nothing), fix each DAG's deadline at first sight, **reject** DAGs
/// whose completion lower bound provably exceeds a hard deadline, plan,
/// **defer** DAGs whose planned completion misses a hard deadline (once;
/// a second planned miss rejects), and execute the surviving batch.
/// Returns the admitted jobs with the executed round outcome, or `None`
/// when admission emptied the batch.
#[allow(clippy::too_many_arguments)]
fn run_sla_round(
    engine: &RoundEngine,
    strategy: &Strategy,
    parallelism: usize,
    sla: &SlaPolicy,
    mut jobs: Vec<TracedJob>,
    occupancy: Option<Vec<Reservation>>,
    origin: f64,
    round: usize,
    log_db: &mut HashMap<String, EventLog>,
    rng: &mut Rng,
    overhead: &mut Duration,
    deadlines: &mut HashMap<String, f64>,
    deferred_once: &mut HashSet<String>,
    deferred: &mut Vec<TracedJob>,
    rejected: &mut usize,
) -> Result<Option<(Vec<TracedJob>, RoundOutcome)>> {
    let build =
        |jobs: &[TracedJob], log_db: &mut HashMap<String, EventLog>, rng: &mut Rng| -> Problem {
            let dags: Vec<Dag> = jobs.iter().map(|j| j.dag.clone()).collect();
            let mut p = engine.build_problem(&dags, log_db, rng);
            if let Some(res) = &occupancy {
                p = p.with_occupancy(res.clone(), 0.0);
            }
            p
        };
    let mut p = build(&jobs, log_db, rng);

    // Fix deadlines at first admission evaluation and reject the
    // provably infeasible: a hard deadline below the DAG's completion
    // lower bound cannot be met by any schedule.
    loop {
        let lbs = p.dag_lower_bounds();
        let slas: Vec<Sla> = jobs
            .iter()
            .enumerate()
            .map(|(d, j)| {
                let abs = *deadlines
                    .entry(j.dag.name.clone())
                    .or_insert(origin + sla.deadline_frac * lbs[d]);
                sla.sla_for(abs - origin)
            })
            .collect();
        p = p.with_slas(slas);
        if !sla.enforce {
            break;
        }
        let infeasible = p.sla_infeasible();
        if !infeasible.iter().any(|&x| x) {
            break;
        }
        *rejected += infeasible.iter().filter(|&&x| x).count();
        jobs = jobs
            .into_iter()
            .zip(infeasible)
            .filter(|&(_, bad)| !bad)
            .map(|(j, _)| j)
            .collect();
        if jobs.is_empty() {
            return Ok(None);
        }
        p = build(&jobs, log_db, rng);
    }

    // Plan; under hard enforcement, defer DAGs whose planned completion
    // misses their deadline — they rejoin the next trigger's batch with
    // the same absolute deadline (which only tightens in local time, so
    // a perpetually-crowded DAG converges to rejection).
    loop {
        let schedule = engine.plan(strategy, parallelism, &p, round, rng, overhead)?;
        let miss: Vec<bool> = if sla.enforce && sla.hard {
            planned_dag_completions(&p, &schedule)
                .iter()
                .zip(&p.slas)
                .map(|(&end, s)| !s.is_unbounded() && end > s.deadline)
                .collect()
        } else {
            vec![false; jobs.len()]
        };
        if !miss.iter().any(|&x| x) {
            let dags: Vec<Dag> = jobs.iter().map(|j| j.dag.clone()).collect();
            let report = engine.execute(&p, &dags, &schedule, round, rng);
            RoundEngine::feed_back(log_db, &p, &report);
            return Ok(Some((jobs, RoundOutcome { problem: p, report })));
        }
        let mut keep = Vec::new();
        for (j, bad) in jobs.into_iter().zip(miss) {
            if !bad {
                keep.push(j);
            } else if deferred_once.insert(j.dag.name.clone()) {
                deferred.push(j);
            } else {
                *rejected += 1;
            }
        }
        jobs = keep;
        if jobs.is_empty() {
            return Ok(None);
        }
        p = build(&jobs, log_db, rng);
        let slas: Vec<Sla> = jobs
            .iter()
            .map(|j| sla.sla_for(deadlines[&j.dag.name] - origin))
            .collect();
        p = p.with_slas(slas);
    }
}

/// Advance the virtual clock to the next interesting instant — the next
/// arrival, or the next interval tick while work is queued — or `None`
/// when the trace is fully served. Shared verbatim by both admission
/// modes, so their trigger firing sequences are identical per trace.
fn next_clock(
    jobs: &[TracedJob],
    next_job: usize,
    queue_empty: bool,
    last_round: f64,
    interval: f64,
    clock: f64,
) -> Option<f64> {
    if next_job < jobs.len() {
        let next_arrival = jobs[next_job].submit_time;
        let next_tick = last_round + interval;
        Some(if queue_empty {
            next_arrival.max(clock)
        } else {
            next_arrival.min(next_tick).max(clock + 1.0)
        })
    } else if !queue_empty {
        Some((last_round + interval).max(clock + 1.0))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{generate, TraceParams};

    fn tiny_run(strategy: Strategy, seed: u64) -> MacroReport {
        let params = TraceParams::tiny();
        let mut rng = Rng::new(7);
        let jobs = generate(&params, &mut rng);
        let mut runner = BatchRunner::new(
            params.batch_capacity(),
            ConfigSpace::standard(),
            strategy,
            seed,
        );
        runner.run(&jobs).expect("macro run")
    }

    #[test]
    fn airflow_strategy_completes_all_jobs() {
        let rep = tiny_run(Strategy::Airflow, 1);
        assert_eq!(rep.outcomes.len(), 12);
        assert!(rep.rounds >= 1);
        for o in &rep.outcomes {
            assert!(o.completion > 0.0, "{} has non-positive completion", o.name);
            assert!(o.cost > 0.0);
        }
    }

    #[test]
    fn agora_strategy_completes_all_jobs() {
        let rep = tiny_run(Strategy::Agora(Goal::Balanced), 1);
        assert_eq!(rep.outcomes.len(), 12);
        assert!(rep.optimizer_overhead > Duration::ZERO);
    }

    #[test]
    fn agora_beats_airflow_on_cost() {
        // The macro signature of Fig. 11: large cost reduction.
        let base = tiny_run(Strategy::Airflow, 2);
        let agora = tiny_run(Strategy::Agora(Goal::Balanced), 2);
        assert!(
            agora.total_cost < base.total_cost,
            "agora {} should beat airflow {}",
            agora.total_cost,
            base.total_cost
        );
    }

    #[test]
    fn portfolio_strategy_completes_all_jobs() {
        let params = TraceParams::tiny();
        let mut rng = Rng::new(7);
        let jobs = generate(&params, &mut rng);
        let mut runner = BatchRunner::new(
            params.batch_capacity(),
            ConfigSpace::standard(),
            Strategy::Agora(Goal::Balanced),
            5,
        )
        .with_parallelism(2);
        let rep = runner.run(&jobs).expect("macro run");
        assert_eq!(rep.outcomes.len(), 12);
        assert!(rep.optimizer_overhead > Duration::ZERO);
    }

    #[test]
    fn replanning_macro_run_completes_all_jobs() {
        use crate::sim::DivergenceSpec;
        let params = TraceParams::tiny();
        let mut rng = Rng::new(7);
        let jobs = generate(&params, &mut rng);
        let mut runner = BatchRunner::new(
            params.batch_capacity(),
            ConfigSpace::standard(),
            Strategy::Airflow,
            9,
        )
        .with_replan(ReplanPolicy {
            max_replans: 1,
            threshold: 0.1,
            iters: 30,
            divergence: DivergenceSpec {
                straggler_prob: 0.3,
                straggler_factor: 6.0,
                seed: 5,
                ..Default::default()
            },
            ..Default::default()
        });
        let rep = runner.run(&jobs).expect("macro run");
        assert_eq!(rep.outcomes.len(), 12);
        for o in &rep.outcomes {
            assert!(o.completion > 0.0);
            assert!(o.cost > 0.0);
        }
    }

    #[test]
    fn event_log_database_grows_across_rounds() {
        let params = TraceParams::tiny();
        let mut rng = Rng::new(7);
        let jobs = generate(&params, &mut rng);
        let mut runner = BatchRunner::new(
            params.batch_capacity(),
            ConfigSpace::standard(),
            Strategy::Airflow,
            3,
        );
        runner.run(&jobs).expect("macro run");
        assert!(!runner.log_db.is_empty());
        // every executed task has bootstrap + at least one real run
        let total_jobs: usize = jobs.iter().map(|j| j.dag.len()).sum();
        assert_eq!(runner.log_db.len(), total_jobs);
        assert!(runner.log_db.values().all(|l| l.len() >= 2));
        // the database key and the log's own name agree (the canonical
        // scoped task name) for every entry — bootstrap and write-back
        // address the same record.
        assert!(runner.log_db.iter().all(|(k, l)| *k == l.task));
    }

    #[test]
    fn realized_runs_feed_the_predictor_under_the_same_key() {
        // The same DAG submitted in two different rounds: round 2's
        // training history must contain round 1's realized run. This is
        // the regression pin for the bootstrap/write-back key contract —
        // a mismatch (e.g. bare task names on one side) would leave the
        // LearnedPredictor training on bootstrap data forever.
        use crate::dag::{Task, TaskProfile};
        let profile = TaskProfile {
            work: 800.0,
            alpha: 0.0,
            beta: 0.0,
            mem_gb: 4.0,
            spark_affinity: 0.0,
            noise_sigma: 0.0,
        };
        let dag = Dag::new(
            "etl",
            vec![Task {
                name: "t0".into(),
                profile,
            }],
            vec![],
        )
        .unwrap();
        let jobs = vec![
            TracedJob {
                dag: dag.clone(),
                submit_time: 0.0,
            },
            TracedJob {
                dag,
                submit_time: 1000.0,
            },
        ];
        let mut runner = BatchRunner::new(
            Capacity::micro(),
            ConfigSpace::standard(),
            Strategy::Airflow,
            11,
        );
        let rep = runner.run(&jobs).expect("macro run");
        assert_eq!(rep.outcomes.len(), 2);
        assert!(rep.rounds >= 2, "resubmission must land in a later round");
        let boot = default_profiling_configs().len();
        let log = runner.log_db.get("etl/t0").expect("scoped key present");
        assert_eq!(log.task, "etl/t0", "log name must match the scoped key");
        assert_eq!(
            log.len(),
            boot + 2,
            "each executed round appends exactly one realized run"
        );
        // No stray entry under the bare task name.
        assert!(runner.log_db.get("t0").is_none());
        assert_eq!(runner.log_db.len(), 1);
    }

    #[test]
    fn continuous_admission_completes_all_jobs_and_respects_submissions() {
        let params = TraceParams::tiny();
        let mut rng = Rng::new(7);
        let jobs = generate(&params, &mut rng);
        let mut runner = BatchRunner::new(
            params.batch_capacity(),
            ConfigSpace::standard(),
            Strategy::Airflow,
            3,
        )
        .with_admission(Admission::Continuous);
        let rep = runner.run(&jobs).expect("macro run");
        assert_eq!(rep.admission, "continuous");
        assert_eq!(rep.outcomes.len(), 12);
        for o in &rep.outcomes {
            assert!(o.completion > 0.0);
            assert!(o.cost > 0.0);
            // Arrivals landing mid-round: no task may launch before its
            // DAG was submitted.
            assert!(
                o.first_start + 1e-9 >= o.submit_time,
                "{} launched at {} before submission {}",
                o.name,
                o.first_start,
                o.submit_time
            );
            assert!(o.finish_time + 1e-9 >= o.first_start);
        }
        assert!(rep.utilization > 0.0 && rep.utilization <= 1.0 + 1e-9);
        assert!(rep.mean_completion > 0.0 && rep.p95_completion > 0.0);
    }
}
