//! Batch optimization rounds over a submission trace (virtual time).
//!
//! This is the macro-benchmark engine (Fig. 11): jobs arrive over a
//! window; the trigger policy groups them into rounds; each round is
//! co-optimized (or scheduled by a baseline) and executed on the
//! simulated cluster; completed runs feed event logs back into the
//! Predictor database (the §4.1 adaptive loop).

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{Context, Result};

use super::TriggerPolicy;
use crate::cluster::{Capacity, ConfigSpace, CostModel};
use crate::dag::Dag;
use crate::predictor::{
    bootstrap_history, default_profiling_configs, EventLog, LearnedPredictor, Predictor,
};
use crate::sim::{self, ReplanPolicy};
use crate::solver::{Agora, AgoraOptions, Goal, Mode, Problem};
use crate::trace::TracedJob;
use crate::util::Rng;

/// How each round is scheduled.
#[derive(Debug, Clone)]
pub enum Strategy {
    /// Default Airflow: default configs, priority-weight dispatch.
    Airflow,
    /// Full AGORA co-optimization with a goal.
    Agora(Goal),
    /// AGORA ablations (§5.2).
    AgoraMode(Goal, Mode),
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::Airflow => "airflow".into(),
            Strategy::Agora(g) => format!("agora[{}]", g.name()),
            Strategy::AgoraMode(g, m) => format!("{}[{}]", m.name(), g.name()),
        }
    }
}

/// Per-DAG outcome in a macro run.
#[derive(Debug, Clone)]
pub struct DagOutcome {
    pub name: String,
    pub submit_time: f64,
    /// Wall-clock completion instant (virtual time).
    pub finish_time: f64,
    /// finish - submit.
    pub completion: f64,
    pub cost: f64,
}

/// Full macro-run report.
#[derive(Debug, Clone)]
pub struct MacroReport {
    pub strategy: String,
    pub outcomes: Vec<DagOutcome>,
    pub total_cost: f64,
    /// Sum of per-DAG completion times (the paper's "total DAG completion
    /// time" metric).
    pub total_completion: f64,
    pub rounds: usize,
    pub optimizer_overhead: Duration,
    /// Mid-flight replans fired across all rounds (0 when the policy is
    /// off).
    pub replans: usize,
}

/// Virtual-time batch runner.
pub struct BatchRunner {
    pub capacity: Capacity,
    pub space: ConfigSpace,
    pub cost_model: CostModel,
    pub trigger: TriggerPolicy,
    pub strategy: Strategy,
    pub seed: u64,
    /// Portfolio chains handed to the co-optimizer per round
    /// (1 = deterministic single chain).
    pub parallelism: usize,
    /// Mid-flight re-planning + divergence injection applied to every
    /// round's execution (off by default).
    pub replan: ReplanPolicy,
    /// Event-log database (task name -> history), persisted across rounds.
    pub log_db: HashMap<String, EventLog>,
}

impl BatchRunner {
    pub fn new(capacity: Capacity, space: ConfigSpace, strategy: Strategy, seed: u64) -> Self {
        BatchRunner {
            capacity,
            space,
            cost_model: CostModel::OnDemand,
            trigger: TriggerPolicy::default(),
            strategy,
            seed,
            parallelism: 1,
            replan: ReplanPolicy::off(),
            log_db: HashMap::new(),
        }
    }

    /// Builder-style portfolio knob.
    pub fn with_parallelism(mut self, parallelism: usize) -> Self {
        self.parallelism = parallelism.max(1);
        self
    }

    /// Builder-style replan/divergence knob.
    pub fn with_replan(mut self, replan: ReplanPolicy) -> Self {
        self.replan = replan;
        self
    }

    /// History for a task: the database entry if present, else a
    /// bootstrap profiling run (the paper's "triggered test run").
    fn history(&mut self, dag: &Dag, rng: &mut Rng) -> Vec<EventLog> {
        dag.tasks
            .iter()
            .map(|t| {
                self.log_db
                    .entry(format!("{}/{}", dag.name, t.name))
                    .or_insert_with(|| {
                        bootstrap_history(
                            &t.name,
                            &t.profile,
                            &default_profiling_configs(),
                            rng,
                        )
                    })
                    .clone()
            })
            .collect()
    }

    /// Run the whole trace; returns the per-DAG outcomes. A failing
    /// per-round scheduler is propagated as an error (with round context)
    /// instead of panicking the coordinator.
    pub fn run(&mut self, jobs: &[TracedJob]) -> Result<MacroReport> {
        let mut rng = Rng::new(self.seed);
        let mut outcomes = Vec::new();
        let mut rounds = 0usize;
        let mut overhead = Duration::ZERO;
        let mut replans = 0usize;

        // Virtual clock: advance to each trigger firing.
        let mut queue: Vec<&TracedJob> = Vec::new();
        let mut next_job = 0usize;
        let mut clock = 0.0f64;
        let mut last_round = 0.0f64;
        // when the cluster frees up from the previous round
        let mut cluster_free = 0.0f64;

        let default_cores = {
            // queue demand measured at the default config
            let c = Agora::default_config(&self.space);
            self.space.configs[c].vcpus()
        };

        loop {
            // Admit arrivals up to the clock.
            while next_job < jobs.len() && jobs[next_job].submit_time <= clock {
                queue.push(&jobs[next_job]);
                next_job += 1;
            }

            let queued_demand: f64 = queue
                .iter()
                .map(|j| j.dag.len() as f64 * default_cores)
                .sum();
            let fire = self.trigger.should_fire(
                queued_demand,
                self.capacity.vcpus,
                clock - last_round,
                queue.len(),
            );

            if fire {
                rounds += 1;
                last_round = clock;
                let batch: Vec<TracedJob> = queue.drain(..).cloned().collect();
                let round_start = clock.max(cluster_free);

                // Build the problem: releases are relative to round start.
                let dags: Vec<Dag> = batch.iter().map(|j| j.dag.clone()).collect();
                let releases = vec![0.0f64; dags.len()];
                let logs: Vec<EventLog> = dags
                    .iter()
                    .flat_map(|d| self.history(d, &mut rng))
                    .collect();
                let predictor = LearnedPredictor::fit(&logs);
                let grid = predictor.predict(&self.space);
                let p = Problem::new(
                    &dags,
                    &releases,
                    self.capacity,
                    self.space.clone(),
                    grid,
                    self.cost_model.clone(),
                );

                // Plan the round.
                let schedule = match &self.strategy {
                    Strategy::Airflow => {
                        use crate::baselines::{AirflowScheduler, Scheduler};
                        AirflowScheduler::default()
                            .schedule(&p)
                            .with_context(|| format!("scheduling round {rounds}"))?
                    }
                    Strategy::Agora(goal) => {
                        let agora = Agora::new(AgoraOptions {
                            goal: *goal,
                            mode: Mode::CoOptimize,
                            params: crate::solver::AnnealParams::fast(),
                            seed: rng.next_u64(),
                            parallelism: self.parallelism,
                            ..Default::default()
                        });
                        let plan = agora.optimize(&p);
                        overhead += plan.overhead;
                        plan.schedule
                    }
                    Strategy::AgoraMode(goal, mode) => {
                        let agora = Agora::new(AgoraOptions {
                            goal: *goal,
                            mode: *mode,
                            params: crate::solver::AnnealParams::fast(),
                            seed: rng.next_u64(),
                            parallelism: self.parallelism,
                            ..Default::default()
                        });
                        let plan = agora.optimize(&p);
                        overhead += plan.overhead;
                        plan.schedule
                    }
                };

                // Execute on the simulated cluster (closed-loop when the
                // replan policy is armed; per-round seed derivation keeps
                // injected divergence decorrelated across rounds).
                let report = sim::execute_with_policy(
                    &p,
                    &dags,
                    &schedule,
                    &self.cost_model,
                    &mut rng,
                    &self.replan.for_round(rounds as u64 - 1),
                );
                replans += report.replans.len();
                cluster_free = round_start + report.makespan;

                // Record outcomes + feed logs back.
                for (d, job) in batch.iter().enumerate() {
                    let finish = round_start + report.dag_completion[d];
                    outcomes.push(DagOutcome {
                        name: job.dag.name.clone(),
                        submit_time: job.submit_time,
                        finish_time: finish,
                        completion: finish - job.submit_time,
                        cost: report
                            .records
                            .iter()
                            .filter(|r| p.tasks[r.task].dag == d)
                            .map(|r| {
                                self.cost_model
                                    .cost(&p.space.configs[r.config], r.runtime)
                            })
                            .sum(),
                    });
                }
                for (t, log) in report.new_logs.iter().enumerate() {
                    let key = p.tasks[t].name.clone();
                    let entry = self
                        .log_db
                        .entry(key)
                        .or_insert_with(|| EventLog::new(&p.tasks[t].name));
                    entry.runs.extend(log.runs.iter().cloned());
                }
            }

            // Advance virtual time.
            if next_job < jobs.len() {
                let next_arrival = jobs[next_job].submit_time;
                let next_tick = last_round + self.trigger.interval;
                clock = if queue.is_empty() {
                    next_arrival.max(clock)
                } else {
                    next_arrival.min(next_tick).max(clock + 1.0)
                };
            } else if !queue.is_empty() {
                clock = (last_round + self.trigger.interval).max(clock + 1.0);
            } else {
                break;
            }
        }

        let total_cost = outcomes.iter().map(|o| o.cost).sum();
        let total_completion = outcomes.iter().map(|o| o.completion).sum();
        Ok(MacroReport {
            strategy: self.strategy.name(),
            outcomes,
            total_cost,
            total_completion,
            rounds,
            optimizer_overhead: overhead,
            replans,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{generate, TraceParams};

    fn tiny_run(strategy: Strategy, seed: u64) -> MacroReport {
        let params = TraceParams::tiny();
        let mut rng = Rng::new(7);
        let jobs = generate(&params, &mut rng);
        let mut runner = BatchRunner::new(
            params.batch_capacity(),
            ConfigSpace::standard(),
            strategy,
            seed,
        );
        runner.run(&jobs).expect("macro run")
    }

    #[test]
    fn airflow_strategy_completes_all_jobs() {
        let rep = tiny_run(Strategy::Airflow, 1);
        assert_eq!(rep.outcomes.len(), 12);
        assert!(rep.rounds >= 1);
        for o in &rep.outcomes {
            assert!(o.completion > 0.0, "{} has non-positive completion", o.name);
            assert!(o.cost > 0.0);
        }
    }

    #[test]
    fn agora_strategy_completes_all_jobs() {
        let rep = tiny_run(Strategy::Agora(Goal::Balanced), 1);
        assert_eq!(rep.outcomes.len(), 12);
        assert!(rep.optimizer_overhead > Duration::ZERO);
    }

    #[test]
    fn agora_beats_airflow_on_cost() {
        // The macro signature of Fig. 11: large cost reduction.
        let base = tiny_run(Strategy::Airflow, 2);
        let agora = tiny_run(Strategy::Agora(Goal::Balanced), 2);
        assert!(
            agora.total_cost < base.total_cost,
            "agora {} should beat airflow {}",
            agora.total_cost,
            base.total_cost
        );
    }

    #[test]
    fn portfolio_strategy_completes_all_jobs() {
        let params = TraceParams::tiny();
        let mut rng = Rng::new(7);
        let jobs = generate(&params, &mut rng);
        let mut runner = BatchRunner::new(
            params.batch_capacity(),
            ConfigSpace::standard(),
            Strategy::Agora(Goal::Balanced),
            5,
        )
        .with_parallelism(2);
        let rep = runner.run(&jobs).expect("macro run");
        assert_eq!(rep.outcomes.len(), 12);
        assert!(rep.optimizer_overhead > Duration::ZERO);
    }

    #[test]
    fn replanning_macro_run_completes_all_jobs() {
        use crate::sim::DivergenceSpec;
        let params = TraceParams::tiny();
        let mut rng = Rng::new(7);
        let jobs = generate(&params, &mut rng);
        let mut runner = BatchRunner::new(
            params.batch_capacity(),
            ConfigSpace::standard(),
            Strategy::Airflow,
            9,
        )
        .with_replan(ReplanPolicy {
            max_replans: 1,
            threshold: 0.1,
            iters: 30,
            divergence: DivergenceSpec {
                straggler_prob: 0.3,
                straggler_factor: 6.0,
                seed: 5,
                ..Default::default()
            },
            ..Default::default()
        });
        let rep = runner.run(&jobs).expect("macro run");
        assert_eq!(rep.outcomes.len(), 12);
        for o in &rep.outcomes {
            assert!(o.completion > 0.0);
            assert!(o.cost > 0.0);
        }
    }

    #[test]
    fn event_log_database_grows_across_rounds() {
        let params = TraceParams::tiny();
        let mut rng = Rng::new(7);
        let jobs = generate(&params, &mut rng);
        let mut runner = BatchRunner::new(
            params.batch_capacity(),
            ConfigSpace::standard(),
            Strategy::Airflow,
            3,
        );
        runner.run(&jobs).expect("macro run");
        assert!(!runner.log_db.is_empty());
        // every executed task has bootstrap + at least one real run
        let total_jobs: usize = jobs.iter().map(|j| j.dag.len()).sum();
        assert_eq!(runner.log_db.len(), total_jobs);
        assert!(runner.log_db.values().all(|l| l.len() >= 2));
    }
}
