//! Threaded multi-tenant service front-end (std::thread + channels; the
//! offline vendor set has no tokio — the control plane is a plain
//! actor-style reactor, which for this workload is equivalent).
//!
//! Tenants submit DAGs through a [`ServiceHandle`] and get back a
//! [`Ticket`] (or explicit backpressure, [`SubmitError`]); the control
//! actor ([`super::control`]) batches submissions per the trigger
//! policy, hands the pure co-optimization of each round to a bounded
//! worker pool ([`super::pool`]), commits results strictly in round
//! order, retries failed rounds with bounded backoff
//! ([`super::retry`]), and answers every ticket with the realized
//! completion time and cost. Live state is observable through
//! [`ServiceHandle::status`] ([`super::status`]) and the configuration
//! can be swapped between rounds ([`ServiceHandle::reload`],
//! [`super::reload`]).
//!
//! Under [`Admission::Continuous`] the service keeps an occupancy
//! ledger of the simulated reservations of earlier rounds on a shared
//! virtual timeline: consecutive rounds sit one trigger interval (the
//! paper's 15 minutes, which a `batch_window` stands for) apart, so
//! each new round is admitted into the residual capacity left by the
//! previous rounds' in-flight work — the same semantics as the
//! continuous [`BatchRunner`](super::BatchRunner). The virtual clock is
//! indexed by round number (not scaled wall-clock time), so admission
//! behaviour is independent of optimizer latency and host load.
//!
//! With the default knobs (one worker, unbounded queues) the service
//! reproduces the pre-refactor single-threaded loop bit-for-bit — see
//! the determinism argument in [`super::control`] and the pin tests in
//! `tests/control_plane.rs`.

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::anyhow;

use super::ingress::{Mailbox, Priority, SubmitError, Ticket};
use super::reload::ConfigCell;
use super::retry::{FaultSpec, RetryPolicy};
use super::status::{ServiceStatus, StatusBoard};
use super::{control, Admission, SlaPolicy};
use crate::cluster::{Capacity, ConfigSpace, CostModel};
use crate::dag::Dag;
use crate::sim::ReplanPolicy;
use crate::solver::Goal;

/// Outcome returned to a tenant for one submitted DAG.
#[derive(Debug, Clone)]
pub struct SubmitResult {
    /// Tenant that submitted the DAG.
    pub tenant: String,
    /// Name of the submitted DAG.
    pub dag_name: String,
    /// Simulated completion time in seconds (from batch start).
    pub completion: f64,
    /// Realized dollar cost of the DAG's tasks.
    pub cost: f64,
    /// Which optimization round served this DAG.
    pub round: usize,
}

/// Service configuration.
///
/// Boot-only fields — fixed when [`Service::start`] spawns the control
/// plane and ignored by [`ServiceHandle::reload`]: [`workers`],
/// [`queue_bound`], [`seed`]. Everything else takes effect from the
/// next dispatched round after a reload.
///
/// [`workers`]: ServiceConfig::workers
/// [`queue_bound`]: ServiceConfig::queue_bound
/// [`seed`]: ServiceConfig::seed
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Simulated cluster capacity shared by every round.
    pub capacity: Capacity,
    /// Optimization goal of the per-round co-optimization.
    pub goal: Goal,
    /// Real-time batching window (stands in for the 15-minute trigger).
    pub batch_window: Duration,
    /// Demand trigger: optimize immediately once this many DAGs queue up.
    pub max_queue: usize,
    /// Seed of the service's RNG stream (boot-only).
    pub seed: u64,
    /// Portfolio chains per co-optimization round (1 = single chain).
    pub parallelism: usize,
    /// Mid-flight re-planning + divergence injection per round (off by
    /// default).
    pub replan: ReplanPolicy,
    /// Round-barrier (each round simulated on an empty cluster) or
    /// continuous admission onto the shared occupied timeline.
    pub admission: Admission,
    /// Candidate configuration space per round (the historical m5-only
    /// [`ConfigSpace::standard`] by default; [`ConfigSpace::market`] for
    /// heterogeneous-market service runs).
    pub space: ConfigSpace,
    /// Pricing model for planning and realized accounting (on-demand by
    /// default; [`CostModel::Market`] arms spot-aware pricing).
    pub cost_model: CostModel,
    /// Optimization worker threads (boot-only; 1 preserves the legacy
    /// serial RNG stream bit-for-bit).
    pub workers: usize,
    /// Per-tenant ingress queue bound; 0 = unbounded (boot-only). A full
    /// queue rejects with [`SubmitError::QueueFull`].
    pub queue_bound: usize,
    /// Largest batch one round may take; 0 = unbounded. Capped batches
    /// select by priority tier, then round-robin across tenants.
    pub max_batch: usize,
    /// Bounded-backoff retry ladder for failed round attempts.
    pub retry: RetryPolicy,
    /// Deterministic fault injection for retry tests (off by default).
    pub fault: FaultSpec,
    /// Per-DAG deadline/SLA policy (off by default). When armed, DAGs
    /// whose completion lower bound provably exceeds their hard deadline
    /// are rejected at dispatch with an error ticket; like `goal`, a
    /// reload applies from the next dispatched round.
    pub sla: SlaPolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            capacity: Capacity::micro(),
            goal: Goal::Balanced,
            batch_window: Duration::from_millis(50),
            max_queue: 8,
            seed: 0x5E21,
            parallelism: 1,
            replan: ReplanPolicy::off(),
            admission: Admission::Rounds,
            space: ConfigSpace::standard(),
            cost_model: CostModel::OnDemand,
            workers: 1,
            queue_bound: 0,
            max_batch: 0,
            retry: RetryPolicy::default(),
            fault: FaultSpec::default(),
            sla: SlaPolicy::off(),
        }
    }
}

/// State shared by the handle, the control thread and the worker pool.
pub(crate) struct Shared {
    /// Per-tenant submission queues + the control thread's mailbox.
    pub(crate) ingress: Mailbox,
    /// Live counters behind [`ServiceStatus`].
    pub(crate) status: StatusBoard,
    /// Versioned configuration cell ([`ServiceHandle::reload`]).
    pub(crate) config: ConfigCell,
    /// Worker-pool size, fixed at boot.
    pub(crate) workers: usize,
}

impl Shared {
    pub(crate) fn new(config: ServiceConfig) -> Shared {
        Shared {
            ingress: Mailbox::new(config.queue_bound),
            status: StatusBoard::default(),
            workers: config.workers.max(1),
            config: ConfigCell::new(config),
        }
    }
}

/// Handle cloned out to tenants.
#[derive(Clone)]
pub struct ServiceHandle {
    shared: Arc<Shared>,
}

impl ServiceHandle {
    /// Submit a DAG at [`Priority::Normal`]; returns a [`Ticket`] whose
    /// `recv`/`recv_timeout` yields the outcome after the round
    /// containing this DAG commits. Never panics: a full tenant queue or
    /// a shut-down service is an explicit [`SubmitError`].
    pub fn submit(&self, tenant: &str, dag: Dag) -> Result<Ticket, SubmitError> {
        self.submit_with_priority(tenant, dag, Priority::Normal)
    }

    /// [`submit`](ServiceHandle::submit) with an explicit batch-selection
    /// priority (orders across tenants when rounds are capped via
    /// [`ServiceConfig::max_batch`]; within a tenant, FIFO).
    pub fn submit_with_priority(
        &self,
        tenant: &str,
        dag: Dag,
        priority: Priority,
    ) -> Result<Ticket, SubmitError> {
        match self.shared.ingress.submit(tenant, dag, priority) {
            Ok(ticket) => {
                self.shared.status.record_accepted(tenant);
                Ok(ticket)
            }
            Err(e) => {
                if matches!(e, SubmitError::QueueFull { .. }) {
                    self.shared.status.record_rejected(tenant);
                }
                Err(e)
            }
        }
    }

    /// A consistent snapshot of queue depths, counters and latency
    /// digests (see [`ServiceStatus`]).
    pub fn status(&self) -> ServiceStatus {
        let snap = self.shared.config.load();
        self.shared.status.snapshot(
            snap.config.admission.name(),
            snap.config.capacity.vcpus,
            &self.shared.ingress.depths(),
            snap.version,
            self.shared.workers,
            self.shared.ingress.queued(),
        )
    }

    /// Swap the live configuration between rounds; returns the new
    /// config version. In-flight rounds finish on the configuration they
    /// were dispatched with; boot-only fields (`workers`, `queue_bound`,
    /// `seed`) are ignored (see [`ServiceConfig`]).
    pub fn reload(&self, config: ServiceConfig) -> u64 {
        self.shared.config.swap(config)
    }
}

/// The running service: control thread + handle factory.
pub struct Service {
    shared: Arc<Shared>,
    coordinator: Option<JoinHandle<usize>>,
}

impl Service {
    /// Spawn the control plane and start serving rounds.
    ///
    /// ```
    /// use std::time::Duration;
    /// use agora::coordinator::service::{Service, ServiceConfig};
    /// use agora::dag::workloads::dag1;
    ///
    /// let service = Service::start(ServiceConfig {
    ///     batch_window: Duration::from_millis(30),
    ///     ..Default::default()
    /// });
    /// let ticket = service.handle().submit("alice", dag1()).unwrap();
    /// let result = ticket.recv_timeout(Duration::from_secs(120)).unwrap();
    /// assert!(result.completion > 0.0 && result.cost > 0.0);
    /// assert!(service.shutdown().unwrap() >= 1);
    /// ```
    pub fn start(config: ServiceConfig) -> Service {
        let shared = Arc::new(Shared::new(config));
        let thread_shared = shared.clone();
        let coordinator = std::thread::Builder::new()
            .name("agora-control".to_string())
            .spawn(move || control::run(thread_shared))
            .expect("spawn control thread");
        Service {
            shared,
            coordinator: Some(coordinator),
        }
    }

    /// A new submission handle (cloneable, thread-safe).
    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle {
            shared: self.shared.clone(),
        }
    }

    /// [`ServiceHandle::status`] without cloning a handle.
    pub fn status(&self) -> ServiceStatus {
        self.handle().status()
    }

    /// Graceful shutdown: stop admitting, drain every queued and
    /// in-flight round (all tickets are answered), then join the control
    /// thread. Returns the number of rounds served, or an error carrying
    /// the panic message if the coordinator panicked instead of silently
    /// reporting 0 rounds.
    pub fn shutdown(mut self) -> anyhow::Result<usize> {
        self.shared.ingress.begin_shutdown();
        match self.coordinator.take() {
            Some(w) => w.join().map_err(|payload| {
                anyhow!(
                    "service coordinator panicked: {}",
                    super::pool::panic_message(payload)
                )
            }),
            None => Ok(0),
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shared.ingress.begin_shutdown();
        if let Some(w) = self.coordinator.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::workloads::{dag1, dag2, fig1_dag};

    #[test]
    fn serves_concurrent_tenants() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_millis(30),
            ..Default::default()
        });
        let handle = service.handle();

        let rx1 = handle.submit("alice", dag1()).unwrap();
        let rx2 = handle.submit("bob", dag2()).unwrap();
        let rx3 = handle.submit("carol", fig1_dag()).unwrap();

        let r1 = rx1.recv_timeout(Duration::from_secs(60)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(60)).unwrap();
        let r3 = rx3.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r1.tenant, "alice");
        assert_eq!(r2.dag_name, "DAG2");
        assert!(r1.completion > 0.0 && r2.completion > 0.0 && r3.completion > 0.0);
        assert!(r1.cost > 0.0);

        let rounds = service.shutdown().unwrap();
        assert!(rounds >= 1);
    }

    #[test]
    fn demand_trigger_fires_before_window() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_secs(30), // long window
            max_queue: 2,                          // low demand trigger
            ..Default::default()
        });
        let handle = service.handle();
        let rx1 = handle.submit("a", dag1()).unwrap();
        let rx2 = handle.submit("b", dag2()).unwrap();
        // Must be answered by the demand trigger, well within the window.
        let r1 = rx1.recv_timeout(Duration::from_secs(60)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r1.round, r2.round);
        service.shutdown().unwrap();
    }

    #[test]
    fn portfolio_service_round_trip() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_millis(30),
            parallelism: 2,
            ..Default::default()
        });
        let handle = service.handle();
        let rx = handle.submit("dora", dag1()).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r.completion > 0.0 && r.cost > 0.0);
        service.shutdown().unwrap();
    }

    #[test]
    fn replanning_service_round_trip() {
        use crate::sim::DivergenceSpec;
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_millis(30),
            replan: ReplanPolicy {
                max_replans: 1,
                threshold: 0.1,
                iters: 30,
                divergence: DivergenceSpec {
                    straggler_prob: 0.4,
                    straggler_factor: 5.0,
                    seed: 21,
                    ..Default::default()
                },
                ..Default::default()
            },
            ..Default::default()
        });
        let handle = service.handle();
        let rx = handle.submit("erin", dag2()).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r.completion > 0.0 && r.cost > 0.0);
        service.shutdown().unwrap();
    }

    #[test]
    fn demand_trigger_fires_exactly_at_max_queue() {
        // Exactly max_queue submissions: the demand trigger must serve
        // the round immediately, well before the (long) window elapses,
        // and all of them in the same round.
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_secs(30),
            max_queue: 3,
            ..Default::default()
        });
        let handle = service.handle();
        let rx1 = handle.submit("a", dag1()).unwrap();
        let rx2 = handle.submit("b", dag2()).unwrap();
        let rx3 = handle.submit("c", fig1_dag()).unwrap();
        let r1 = rx1.recv_timeout(Duration::from_secs(60)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(60)).unwrap();
        let r3 = rx3.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r1.round, r2.round);
        assert_eq!(r2.round, r3.round);
        service.shutdown().unwrap();
    }

    #[test]
    fn continuous_admission_service_round_trip() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_millis(30),
            admission: Admission::Continuous,
            ..Default::default()
        });
        let handle = service.handle();
        let rx1 = handle.submit("alice", dag1()).unwrap();
        let r1 = rx1.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r1.completion > 0.0 && r1.cost > 0.0);
        // A later round is admitted onto the occupied timeline; its
        // relative completion must still be positive and finite.
        let rx2 = handle.submit("bob", dag2()).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r2.completion > 0.0 && r2.completion.is_finite());
        assert!(r2.cost > 0.0);
        assert!(r2.round >= r1.round);
        service.shutdown().unwrap();
    }

    #[test]
    fn shutdown_flushes_pending_queue() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_secs(60),
            max_queue: 100,
            ..Default::default()
        });
        let handle = service.handle();
        let rx = handle.submit("late", fig1_dag()).unwrap();
        let rounds = service.shutdown().unwrap();
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.dag_name, "fig1");
        assert!(rounds >= 1);
    }

    #[test]
    fn submit_after_shutdown_errors_instead_of_panicking() {
        let service = Service::start(ServiceConfig::default());
        let handle = service.handle();
        service.shutdown().unwrap();
        // The coordinator is gone; the handle must keep working and
        // answer with an explicit error.
        match handle.submit("tardy", dag1()) {
            Err(SubmitError::ShuttingDown) => {}
            other => panic!("expected ShuttingDown, got {other:?}"),
        }
    }

    #[test]
    fn status_surfaces_served_rounds_and_tenants() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_millis(30),
            ..Default::default()
        });
        let handle = service.handle();
        let rx = handle.submit("alice", dag1()).unwrap();
        rx.recv_timeout(Duration::from_secs(120)).unwrap();
        let status = handle.status();
        assert_eq!(status.config_version, 1);
        assert_eq!(status.workers, 1);
        assert!(status.rounds_served >= 1);
        assert_eq!(status.accepted, 1);
        assert_eq!(status.dags_served, 1);
        assert!(status.stats.mean_completion > 0.0);
        assert!(status.stats.total_cost > 0.0);
        let alice = status.tenants.iter().find(|t| t.tenant == "alice");
        assert!(alice.map(|t| t.served == 1).unwrap_or(false));
        assert!(status.render().contains("rounds served"));
        service.shutdown().unwrap();
    }

    #[test]
    fn reload_swaps_config_between_rounds() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_millis(30),
            ..Default::default()
        });
        let handle = service.handle();
        let v = handle.reload(ServiceConfig {
            goal: Goal::Cost,
            batch_window: Duration::from_millis(30),
            ..Default::default()
        });
        assert_eq!(v, 2);
        let rx = handle.submit("alice", dag1()).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r.completion > 0.0 && r.cost > 0.0);
        assert_eq!(handle.status().config_version, 2);
        service.shutdown().unwrap();
    }
}
