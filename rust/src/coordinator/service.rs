//! Threaded multi-tenant service front-end (std::thread + mpsc; the
//! offline vendor set has no tokio — the event loop is a plain
//! channel-driven reactor, which for this workload is equivalent).
//!
//! Tenants submit DAGs through a [`ServiceHandle`]; the coordinator
//! thread batches submissions per the trigger policy (scaled to real
//! milliseconds for interactivity), co-optimizes each batch, executes it
//! on the simulated cluster, and answers every submission with its
//! realized completion time and cost.
//!
//! Under [`Admission::Continuous`] the service keeps an occupancy ledger
//! of the simulated reservations of earlier rounds on a shared virtual
//! timeline: consecutive rounds sit one trigger interval (the paper's
//! 15 minutes, which a `batch_window` stands for) apart, so each new
//! round is admitted into the residual capacity left by the previous
//! rounds' in-flight work — the same semantics as the continuous
//! [`BatchRunner`](super::BatchRunner). The virtual clock is indexed by
//! round number (not scaled wall-clock time), so admission behaviour is
//! independent of optimizer latency and host load.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::{Admission, OccupancyLedger, TriggerPolicy};
use crate::cluster::{Capacity, ConfigSpace, CostModel};
use crate::dag::Dag;
use crate::predictor::{
    bootstrap_history, profiling_configs_for, scoped_task_name, EventLog, LearnedPredictor,
    Predictor,
};
use crate::sim::{self, ReplanPolicy};
use crate::solver::{Agora, AgoraOptions, Goal, Mode, Problem};
use crate::util::Rng;

/// Outcome returned to a tenant for one submitted DAG.
#[derive(Debug, Clone)]
pub struct SubmitResult {
    /// Tenant that submitted the DAG.
    pub tenant: String,
    /// Name of the submitted DAG.
    pub dag_name: String,
    /// Simulated completion time in seconds (from batch start).
    pub completion: f64,
    /// Realized dollar cost of the DAG's tasks.
    pub cost: f64,
    /// Which optimization round served this DAG.
    pub round: usize,
}

struct Submission {
    tenant: String,
    dag: Dag,
    reply: Sender<SubmitResult>,
}

enum Msg {
    Submit(Submission),
    Shutdown,
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Simulated cluster capacity shared by every round.
    pub capacity: Capacity,
    /// Optimization goal of the per-round co-optimization.
    pub goal: Goal,
    /// Real-time batching window (stands in for the 15-minute trigger).
    pub batch_window: Duration,
    /// Demand trigger: optimize immediately once this many DAGs queue up.
    pub max_queue: usize,
    /// Seed of the service's RNG stream.
    pub seed: u64,
    /// Portfolio chains per co-optimization round (1 = single chain).
    pub parallelism: usize,
    /// Mid-flight re-planning + divergence injection per round (off by
    /// default).
    pub replan: ReplanPolicy,
    /// Round-barrier (each round simulated on an empty cluster) or
    /// continuous admission onto the shared occupied timeline.
    pub admission: Admission,
    /// Candidate configuration space per round (the historical m5-only
    /// [`ConfigSpace::standard`] by default; [`ConfigSpace::market`] for
    /// heterogeneous-market service runs).
    pub space: ConfigSpace,
    /// Pricing model for planning and realized accounting (on-demand by
    /// default; [`CostModel::Market`] arms spot-aware pricing).
    pub cost_model: CostModel,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            capacity: Capacity::micro(),
            goal: Goal::Balanced,
            batch_window: Duration::from_millis(50),
            max_queue: 8,
            seed: 0x5E21,
            parallelism: 1,
            replan: ReplanPolicy::off(),
            admission: Admission::Rounds,
            space: ConfigSpace::standard(),
            cost_model: CostModel::OnDemand,
        }
    }
}

/// Handle cloned out to tenants.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<Msg>,
}

impl ServiceHandle {
    /// Submit a DAG; returns a receiver that yields the outcome after the
    /// round containing this DAG executes.
    pub fn submit(&self, tenant: &str, dag: Dag) -> Receiver<SubmitResult> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Msg::Submit(Submission {
                tenant: tenant.to_string(),
                dag,
                reply: reply_tx,
            }))
            .expect("service thread alive");
        reply_rx
    }
}

/// The running service: coordinator thread + handle factory.
pub struct Service {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<usize>>,
}

impl Service {
    /// Spawn the coordinator thread and start serving rounds.
    ///
    /// ```
    /// use std::time::Duration;
    /// use agora::coordinator::service::{Service, ServiceConfig};
    /// use agora::dag::workloads::dag1;
    ///
    /// let service = Service::start(ServiceConfig {
    ///     batch_window: Duration::from_millis(30),
    ///     ..Default::default()
    /// });
    /// let result = service
    ///     .handle()
    ///     .submit("alice", dag1())
    ///     .recv_timeout(Duration::from_secs(120))
    ///     .unwrap();
    /// assert!(result.completion > 0.0 && result.cost > 0.0);
    /// assert!(service.shutdown() >= 1);
    /// ```
    pub fn start(config: ServiceConfig) -> Service {
        let (tx, rx) = channel::<Msg>();
        let worker = std::thread::spawn(move || run_loop(config, rx));
        Service {
            tx,
            worker: Some(worker),
        }
    }

    /// A new submission handle (cloneable, thread-safe).
    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle {
            tx: self.tx.clone(),
        }
    }

    /// Graceful shutdown; returns the number of rounds served.
    pub fn shutdown(mut self) -> usize {
        let _ = self.tx.send(Msg::Shutdown);
        self.worker
            .take()
            .map(|w| w.join().unwrap_or(0))
            .unwrap_or(0)
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn run_loop(config: ServiceConfig, rx: Receiver<Msg>) -> usize {
    let mut rng = Rng::new(config.seed);
    let space = config.space.clone();
    let cost_model = config.cost_model.clone();
    let mut log_db: HashMap<String, EventLog> = HashMap::new();
    let mut queue: Vec<Submission> = Vec::new();
    let mut round = 0usize;
    let mut window_start = Instant::now();
    // Continuous admission: in-flight reservations of earlier rounds on
    // the shared virtual timeline (see module docs).
    let mut ledger = OccupancyLedger::default();

    loop {
        let timeout = config
            .batch_window
            .saturating_sub(window_start.elapsed())
            .max(Duration::from_millis(1));
        let msg = rx.recv_timeout(timeout);

        match msg {
            Ok(Msg::Submit(s)) => queue.push(s),
            Ok(Msg::Shutdown) => {
                if !queue.is_empty() {
                    round += 1;
                    serve_round(
                        &config,
                        &space,
                        &cost_model,
                        &mut log_db,
                        &mut queue,
                        round,
                        &mut ledger,
                        &mut rng,
                    );
                }
                return round;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return round,
        }

        let window_elapsed = window_start.elapsed() >= config.batch_window;
        if !queue.is_empty() && (window_elapsed || queue.len() >= config.max_queue) {
            round += 1;
            serve_round(
                &config,
                &space,
                &cost_model,
                &mut log_db,
                &mut queue,
                round,
                &mut ledger,
                &mut rng,
            );
            window_start = Instant::now();
        } else if window_elapsed {
            window_start = Instant::now();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_round(
    config: &ServiceConfig,
    space: &ConfigSpace,
    cost_model: &CostModel,
    log_db: &mut HashMap<String, EventLog>,
    queue: &mut Vec<Submission>,
    round: usize,
    ledger: &mut OccupancyLedger,
    rng: &mut Rng,
) {
    // Virtual admission instant of this round: consecutive rounds sit
    // one trigger interval (the paper's 15 minutes, shared with the
    // macro runner's TriggerPolicy) apart on the shared timeline.
    // Round-indexed rather than scaled wall-clock time, so a slow
    // optimize cannot silently drain the ledger between rounds.
    let vnow = match config.admission {
        Admission::Rounds => 0.0,
        Admission::Continuous => (round as f64 - 1.0) * TriggerPolicy::default().interval,
    };
    let batch: Vec<Submission> = queue.drain(..).collect();
    let dags: Vec<Dag> = batch.iter().map(|s| s.dag.clone()).collect();
    // Every round simulates in round-local time (t = 0 at admission);
    // continuous rounds additionally pack into the residual capacity of
    // the occupied timeline, with the ledger shifted to the local origin.
    let releases = vec![0.0; dags.len()];

    // Histories from the DB (or bootstrap profiling runs), keyed by the
    // canonical scoped task name — the same key realized runs are
    // written back under.
    let mut logs: Vec<EventLog> = Vec::new();
    let profiling = profiling_configs_for(space);
    for d in &dags {
        for t in &d.tasks {
            let key = scoped_task_name(&d.name, &t.name);
            let entry = log_db.entry(key.clone()).or_insert_with(|| {
                bootstrap_history(&key, &t.profile, &profiling, rng)
            });
            logs.push(entry.clone());
        }
    }

    let predictor = LearnedPredictor::fit(&logs);
    let grid = predictor.predict(space);
    let mut p = Problem::new(
        &dags,
        &releases,
        config.capacity,
        space.clone(),
        grid,
        cost_model.clone(),
    );
    if config.admission == Admission::Continuous {
        p = p.with_occupancy(ledger.snapshot(vnow), 0.0);
    }

    let agora = Agora::new(AgoraOptions {
        goal: config.goal,
        mode: Mode::CoOptimize,
        params: crate::solver::AnnealParams::fast(),
        seed: rng.next_u64(),
        parallelism: config.parallelism.max(1),
        ..Default::default()
    });
    let plan = agora.optimize(&p);
    let report = sim::execute_with_policy(
        &p,
        &dags,
        &plan.schedule,
        cost_model,
        rng,
        &config.replan.for_round(round as u64 - 1),
    );
    if config.admission == Admission::Continuous {
        ledger.absorb(&p, &report, vnow);
    }

    // Feed logs back (adaptive loop) and answer tenants.
    for (t, log) in report.new_logs.iter().enumerate() {
        let key = p.tasks[t].name.clone();
        let entry = log_db
            .entry(key)
            .or_insert_with(|| EventLog::new(&p.tasks[t].name));
        entry.runs.extend(log.runs.iter().cloned());
    }
    for (d, sub) in batch.iter().enumerate() {
        let cost: f64 = report
            .records
            .iter()
            .filter(|r| p.tasks[r.task].dag == d)
            .map(|r| cost_model.realized_cost(&p.space.configs[r.config], r.runtime))
            .sum();
        let _ = sub.reply.send(SubmitResult {
            tenant: sub.tenant.clone(),
            dag_name: sub.dag.name.clone(),
            // Round-local completion ("time from batch start") in both
            // modes; under continuous admission it already includes any
            // wait for residual capacity.
            completion: report.dag_completion[d],
            cost,
            round,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::workloads::{dag1, dag2, fig1_dag};

    #[test]
    fn serves_concurrent_tenants() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_millis(30),
            ..Default::default()
        });
        let handle = service.handle();

        let rx1 = handle.submit("alice", dag1());
        let rx2 = handle.submit("bob", dag2());
        let rx3 = handle.submit("carol", fig1_dag());

        let r1 = rx1.recv_timeout(Duration::from_secs(60)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(60)).unwrap();
        let r3 = rx3.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r1.tenant, "alice");
        assert_eq!(r2.dag_name, "DAG2");
        assert!(r1.completion > 0.0 && r2.completion > 0.0 && r3.completion > 0.0);
        assert!(r1.cost > 0.0);

        let rounds = service.shutdown();
        assert!(rounds >= 1);
    }

    #[test]
    fn demand_trigger_fires_before_window() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_secs(30), // long window
            max_queue: 2,                          // low demand trigger
            ..Default::default()
        });
        let handle = service.handle();
        let rx1 = handle.submit("a", dag1());
        let rx2 = handle.submit("b", dag2());
        // Must be answered by the demand trigger, well within the window.
        let r1 = rx1.recv_timeout(Duration::from_secs(60)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r1.round, r2.round);
        service.shutdown();
    }

    #[test]
    fn portfolio_service_round_trip() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_millis(30),
            parallelism: 2,
            ..Default::default()
        });
        let handle = service.handle();
        let rx = handle.submit("dora", dag1());
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r.completion > 0.0 && r.cost > 0.0);
        service.shutdown();
    }

    #[test]
    fn replanning_service_round_trip() {
        use crate::sim::DivergenceSpec;
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_millis(30),
            replan: ReplanPolicy {
                max_replans: 1,
                threshold: 0.1,
                iters: 30,
                divergence: DivergenceSpec {
                    straggler_prob: 0.4,
                    straggler_factor: 5.0,
                    seed: 21,
                    ..Default::default()
                },
                ..Default::default()
            },
            ..Default::default()
        });
        let handle = service.handle();
        let rx = handle.submit("erin", dag2());
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r.completion > 0.0 && r.cost > 0.0);
        service.shutdown();
    }

    #[test]
    fn demand_trigger_fires_exactly_at_max_queue() {
        // Exactly max_queue submissions: the demand trigger must serve
        // the round immediately, well before the (long) window elapses,
        // and all of them in the same round.
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_secs(30),
            max_queue: 3,
            ..Default::default()
        });
        let handle = service.handle();
        let rx1 = handle.submit("a", dag1());
        let rx2 = handle.submit("b", dag2());
        let rx3 = handle.submit("c", fig1_dag());
        let r1 = rx1.recv_timeout(Duration::from_secs(60)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(60)).unwrap();
        let r3 = rx3.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r1.round, r2.round);
        assert_eq!(r2.round, r3.round);
        service.shutdown();
    }

    #[test]
    fn continuous_admission_service_round_trip() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_millis(30),
            admission: Admission::Continuous,
            ..Default::default()
        });
        let handle = service.handle();
        let rx1 = handle.submit("alice", dag1());
        let r1 = rx1.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r1.completion > 0.0 && r1.cost > 0.0);
        // A later round is admitted onto the occupied timeline; its
        // relative completion must still be positive and finite.
        let rx2 = handle.submit("bob", dag2());
        let r2 = rx2.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r2.completion > 0.0 && r2.completion.is_finite());
        assert!(r2.cost > 0.0);
        assert!(r2.round >= r1.round);
        service.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending_queue() {
        let service = Service::start(ServiceConfig {
            batch_window: Duration::from_secs(60),
            max_queue: 100,
            ..Default::default()
        });
        let handle = service.handle();
        let rx = handle.submit("late", fig1_dag());
        let rounds = service.shutdown();
        let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.dag_name, "fig1");
        assert!(rounds >= 1);
    }
}
