//! The multi-tenant coordination layer (L3): submission queue, trigger
//! policy, batch optimization rounds, the event-log database feeding the
//! Predictor's adaptive loop, and a threaded service front-end.
//!
//! §5.5.1 methodology: "AGORA is triggered to schedule jobs that have
//! been submitted every fifteen minutes or when the demands in the queue
//! are greater than three times the available cores in the cluster."

pub mod batch;
pub mod metrics;
pub mod service;

pub use batch::{BatchRunner, MacroReport, Strategy};
pub use metrics::{improvement_cdf, MacroSummary};
pub use service::{Service, ServiceHandle, SubmitResult};

/// Trigger policy for batching queued DAGs into optimization rounds.
#[derive(Debug, Clone)]
pub struct TriggerPolicy {
    /// Periodic trigger interval in seconds (paper: 15 minutes).
    pub interval: f64,
    /// Demand trigger: fire when queued core-demand exceeds this multiple
    /// of the cluster's cores (paper: 3x).
    pub demand_factor: f64,
}

impl Default for TriggerPolicy {
    fn default() -> Self {
        TriggerPolicy {
            interval: 15.0 * 60.0,
            demand_factor: 3.0,
        }
    }
}

impl TriggerPolicy {
    /// Should a round fire now?
    ///
    /// `queued_demand_cores`: sum of default-config core demands of
    /// queued tasks; `cluster_cores`: capacity; `since_last`: seconds
    /// since the previous round.
    pub fn should_fire(
        &self,
        queued_demand_cores: f64,
        cluster_cores: f64,
        since_last: f64,
        queue_len: usize,
    ) -> bool {
        if queue_len == 0 {
            return false;
        }
        since_last >= self.interval
            || queued_demand_cores > self.demand_factor * cluster_cores
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_on_interval() {
        let p = TriggerPolicy::default();
        assert!(!p.should_fire(10.0, 100.0, 899.0, 3));
        assert!(p.should_fire(10.0, 100.0, 900.0, 3));
    }

    #[test]
    fn fires_on_demand_pressure() {
        let p = TriggerPolicy::default();
        assert!(!p.should_fire(300.0, 100.0, 0.0, 5));
        assert!(p.should_fire(301.0, 100.0, 0.0, 5));
    }

    #[test]
    fn never_fires_on_empty_queue() {
        let p = TriggerPolicy::default();
        assert!(!p.should_fire(1e9, 100.0, 1e9, 0));
    }
}
