//! The multi-tenant coordination layer (L3): submission queue, trigger
//! policy, batch optimization rounds, the event-log database feeding the
//! Predictor's adaptive loop, and a threaded service front-end.
//!
//! §5.5.1 methodology: "AGORA is triggered to schedule jobs that have
//! been submitted every fifteen minutes or when the demands in the queue
//! are greater than three times the available cores in the cluster."

pub mod batch;
pub(crate) mod control;
pub mod ingress;
pub mod metrics;
pub(crate) mod pool;
pub(crate) mod reload;
pub mod retry;
pub(crate) mod round;
pub mod service;
pub mod status;

pub use batch::{BatchRunner, DagOutcome, MacroReport, SlaPolicy, Strategy};
pub use ingress::{Priority, SubmitError, Ticket};
pub use metrics::{improvement_cdf, AdmissionStats, MacroSummary, SlaStats};
pub use retry::{FaultSpec, RetryPolicy, RoundError};
pub use service::{Service, ServiceConfig, ServiceHandle, SubmitResult};
pub use status::{ServiceStatus, TenantStatus};

/// How the coordinator admits triggered batches onto the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Bulk-synchronous rounds (the historical behaviour): round *N+1*
    /// cannot place a single task until every DAG of round *N* has
    /// drained — head-of-line blocking that idles the cluster during a
    /// round's tail.
    Rounds,
    /// Continuous multi-tenant admission: at each trigger the coordinator
    /// snapshots the in-flight work of prior rounds as an occupancy
    /// ledger ([`crate::solver::Problem::with_occupancy`]) and
    /// co-optimizes the new batch *into the gaps*, so rounds overlap
    /// instead of queueing.
    Continuous,
}

impl Admission {
    /// Stable name used by reports and the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            Admission::Rounds => "rounds",
            Admission::Continuous => "continuous",
        }
    }

    /// Parse a CLI/JSON spelling; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Admission> {
        match s {
            "rounds" => Some(Admission::Rounds),
            "continuous" => Some(Admission::Continuous),
            _ => None,
        }
    }
}

/// Occupancy ledger shared by the continuous coordinators
/// ([`BatchRunner`] and the threaded [`Service`]): realized reservations
/// of admitted work in absolute virtual time, with one prune/shift/absorb
/// protocol so the two front-ends cannot drift semantically.
#[derive(Debug, Default)]
pub(crate) struct OccupancyLedger {
    reservations: Vec<crate::solver::Reservation>,
}

impl OccupancyLedger {
    /// Drop reservations ending at or before the admission instant
    /// `now` (they cannot constrain work floored at it), then return the
    /// survivors shifted into the round-local time base (origin `now`)
    /// for [`crate::solver::Problem::with_occupancy`], sorted by start.
    /// Sorted seeding keeps the block-indexed
    /// [`Timeline`](crate::solver::Timeline) kernel's construction in
    /// near-append order (each change-point lands at or near the tail of
    /// the last block, touching one block instead of forcing mid-profile
    /// inserts and splits). The change-
    /// point *set* is order-independent; per-segment usage sums are
    /// order-independent here because reservation demands come from
    /// `Config::vcpus`/`memory_gb` — integer-valued doubles whose sums
    /// are exact in any order. (Non-representable demands could differ
    /// by an ULP across orders; nothing in the repo produces them.)
    pub(crate) fn snapshot(&mut self, now: f64) -> Vec<crate::solver::Reservation> {
        self.reservations.retain(|&(s, d, _, _)| s + d > now);
        let mut shifted: Vec<crate::solver::Reservation> = self
            .reservations
            .iter()
            .map(|&(s, d, cpu, mem)| (s - now, d, cpu, mem))
            .collect();
        shifted.sort_by(|a, b| a.0.total_cmp(&b.0));
        shifted
    }

    /// Absorb one executed round's realized records (round-local times,
    /// origin `now`) as absolute-time reservations later rounds must
    /// pack around.
    pub(crate) fn absorb(
        &mut self,
        p: &crate::solver::Problem,
        report: &crate::sim::ExecutionReport,
        now: f64,
    ) {
        for r in &report.records {
            let cfg = p.space.configs[r.config];
            self.reservations
                .push((now + r.start, r.runtime, cfg.vcpus(), cfg.memory_gb()));
        }
    }
}

/// Trigger policy for batching queued DAGs into optimization rounds.
#[derive(Debug, Clone)]
pub struct TriggerPolicy {
    /// Periodic trigger interval in seconds (paper: 15 minutes).
    pub interval: f64,
    /// Demand trigger: fire when queued core-demand exceeds this multiple
    /// of the cluster's cores (paper: 3x).
    pub demand_factor: f64,
}

impl Default for TriggerPolicy {
    fn default() -> Self {
        TriggerPolicy {
            interval: 15.0 * 60.0,
            demand_factor: 3.0,
        }
    }
}

impl TriggerPolicy {
    /// Should a round fire now?
    ///
    /// `queued_demand_cores`: sum of default-config core demands of
    /// queued tasks; `cluster_cores`: capacity; `since_last`: seconds
    /// since the previous round.
    pub fn should_fire(
        &self,
        queued_demand_cores: f64,
        cluster_cores: f64,
        since_last: f64,
        queue_len: usize,
    ) -> bool {
        if queue_len == 0 {
            return false;
        }
        since_last >= self.interval
            || queued_demand_cores > self.demand_factor * cluster_cores
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_on_interval() {
        let p = TriggerPolicy::default();
        assert!(!p.should_fire(10.0, 100.0, 899.0, 3));
        assert!(p.should_fire(10.0, 100.0, 900.0, 3));
    }

    #[test]
    fn fires_on_demand_pressure() {
        let p = TriggerPolicy::default();
        assert!(!p.should_fire(300.0, 100.0, 0.0, 5));
        assert!(p.should_fire(301.0, 100.0, 0.0, 5));
    }

    #[test]
    fn never_fires_on_empty_queue() {
        let p = TriggerPolicy::default();
        assert!(!p.should_fire(1e9, 100.0, 1e9, 0));
    }

    #[test]
    fn interval_elapsed_with_empty_queue_stays_quiet() {
        // The periodic trigger alone must never produce an empty round:
        // exactly at the interval boundary (and far past it) with nothing
        // queued, the policy stays quiet; one queued DAG arms it again.
        let p = TriggerPolicy::default();
        assert!(!p.should_fire(0.0, 100.0, p.interval, 0));
        assert!(!p.should_fire(0.0, 100.0, p.interval * 10.0, 0));
        assert!(p.should_fire(0.0, 100.0, p.interval, 1));
    }

    #[test]
    fn demand_exactly_at_threshold_waits_for_strict_excess() {
        // §5.5.1: fire when demand is *greater than* 3x the cores — the
        // boundary itself does not fire.
        let p = TriggerPolicy::default();
        let cores = 128.0;
        assert!(!p.should_fire(3.0 * cores, cores, 0.0, 4));
        assert!(p.should_fire(3.0 * cores + 1e-9, cores, 0.0, 4));
    }

    #[test]
    fn admission_parses_and_names_round_trip() {
        assert_eq!(Admission::parse("rounds"), Some(Admission::Rounds));
        assert_eq!(Admission::parse("continuous"), Some(Admission::Continuous));
        assert_eq!(Admission::parse("overlapped"), None);
        for a in [Admission::Rounds, Admission::Continuous] {
            assert_eq!(Admission::parse(a.name()), Some(a));
        }
    }
}
