//! Live configuration reload for the control plane.
//!
//! [`ConfigCell`] holds the service's current [`ServiceConfig`] behind a
//! versioned `Arc` snapshot. [`ServiceHandle::reload`] swaps in a new
//! snapshot atomically *between* rounds: the control thread re-loads the
//! cell before dispatching each round, and every in-flight round keeps
//! the `Arc` it captured at dispatch, so it finishes on the exact
//! configuration (goal, capacity, space, pricing, replan/retry policy)
//! it started with.
//!
//! Boot-only fields of a swapped-in config are ignored by the running
//! service and documented as such on [`ServiceConfig`]: `workers` (pool
//! size is fixed at spawn), `queue_bound` (ingress bound is fixed at
//! spawn) and `seed` (the coordinator RNG stream is seeded once).
//!
//! [`ServiceConfig`]: super::service::ServiceConfig
//! [`ServiceHandle::reload`]: super::service::ServiceHandle::reload

use std::sync::{Arc, Mutex};

use super::service::ServiceConfig;

/// One immutable configuration generation.
#[derive(Debug)]
pub(crate) struct ConfigSnapshot {
    /// Monotonic generation counter; 1 at boot, +1 per reload.
    pub(crate) version: u64,
    /// The configuration of this generation.
    pub(crate) config: ServiceConfig,
}

/// Versioned atomic `ServiceConfig` holder shared by the handle (writer)
/// and the control thread (reader).
#[derive(Debug)]
pub(crate) struct ConfigCell {
    current: Mutex<Arc<ConfigSnapshot>>,
}

impl ConfigCell {
    /// A cell holding the boot configuration as version 1.
    pub(crate) fn new(config: ServiceConfig) -> ConfigCell {
        ConfigCell {
            current: Mutex::new(Arc::new(ConfigSnapshot { version: 1, config })),
        }
    }

    /// The current snapshot (cheap: one lock + `Arc` clone).
    pub(crate) fn load(&self) -> Arc<ConfigSnapshot> {
        self.current
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// Swap in a new configuration; returns the new version. Readers
    /// holding the previous snapshot are unaffected.
    pub(crate) fn swap(&self, config: ServiceConfig) -> u64 {
        let mut cur = self.current.lock().unwrap_or_else(|e| e.into_inner());
        let version = cur.version + 1;
        *cur = Arc::new(ConfigSnapshot { version, config });
        version
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::Goal;

    #[test]
    fn versions_are_monotonic_and_snapshots_immutable() {
        let cell = ConfigCell::new(ServiceConfig::default());
        let boot = cell.load();
        assert_eq!(boot.version, 1);

        let v2 = cell.swap(ServiceConfig {
            goal: Goal::Cost,
            ..Default::default()
        });
        assert_eq!(v2, 2);
        // The old snapshot is untouched; the new one is visible.
        assert_eq!(boot.version, 1);
        assert_eq!(boot.config.goal, Goal::Balanced);
        let now = cell.load();
        assert_eq!(now.version, 2);
        assert_eq!(now.config.goal, Goal::Cost);

        assert_eq!(cell.swap(ServiceConfig::default()), 3);
    }

    #[test]
    fn concurrent_readers_see_a_consistent_generation() {
        let cell = std::sync::Arc::new(ConfigCell::new(ServiceConfig::default()));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let cell = cell.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        let snap = cell.load();
                        // goal and version always travel together
                        if snap.version == 1 {
                            assert_eq!(snap.config.goal, Goal::Balanced);
                        } else {
                            assert_eq!(snap.config.goal, Goal::Runtime);
                        }
                    }
                })
            })
            .collect();
        for _ in 0..50 {
            cell.swap(ServiceConfig {
                goal: Goal::Runtime,
                ..Default::default()
            });
        }
        for r in readers {
            r.join().unwrap();
        }
    }
}
