//! Observable control-plane state: counters, latency digests and the
//! queryable [`ServiceStatus`] snapshot.
//!
//! Every commit, rejection and retry updates the shared [`StatusBoard`];
//! [`ServiceHandle::status`] and the `agora serve --status-interval`
//! ticker render the same snapshot, so the programmatic and the human
//! surface cannot drift.
//!
//! Two time bases coexist deliberately: *completion* statistics are in
//! simulated seconds (the virtual cluster timeline tenants are billed
//! on, reusing [`AdmissionStats`]), while *queue delay* is real
//! wall-clock time from admission to round dispatch — the quantity
//! backpressure and pool sizing actually control.
//!
//! [`ServiceHandle::status`]: super::service::ServiceHandle::status

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Mutex;
use std::time::Duration;

use super::metrics::AdmissionStats;
use crate::util::stats;

/// Live queue/served counters of one tenant.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStatus {
    /// Tenant name.
    pub tenant: String,
    /// Submissions currently waiting in the tenant's ingress queue.
    pub queued: usize,
    /// Submissions admitted since boot.
    pub accepted: usize,
    /// Submissions answered with a served round since boot.
    pub served: usize,
    /// Submissions rejected with backpressure
    /// ([`SubmitError::QueueFull`](super::SubmitError::QueueFull)) since
    /// boot.
    pub rejected: usize,
}

/// One consistent snapshot of the control plane, returned by
/// [`ServiceHandle::status`](super::service::ServiceHandle::status).
#[derive(Debug, Clone)]
pub struct ServiceStatus {
    /// Configuration generation currently live (1 at boot, +1 per
    /// [`reload`](super::service::ServiceHandle::reload)).
    pub config_version: u64,
    /// Worker-pool size (fixed at boot).
    pub workers: usize,
    /// Rounds currently dispatched to the pool and not yet committed.
    pub in_flight: usize,
    /// Submissions queued across all tenants.
    pub queued: usize,
    /// Rounds committed since boot.
    pub rounds_served: usize,
    /// Round attempts re-queued by the retry ladder since boot.
    pub rounds_retried: usize,
    /// Rounds that exhausted their retries since boot.
    pub rounds_failed: usize,
    /// DAGs answered with a served outcome since boot.
    pub dags_served: usize,
    /// Submissions admitted since boot.
    pub accepted: usize,
    /// Submissions rejected with backpressure since boot.
    pub rejected: usize,
    /// Mean/p95 completion, mean queue delay, utilization and cost in
    /// the macro-report shape (completion/utilization in simulated time).
    pub stats: AdmissionStats,
    /// Median simulated completion (seconds).
    pub p50_completion: f64,
    /// Median wall-clock queue delay (seconds, admission → dispatch).
    pub p50_queue_delay: f64,
    /// 95th-percentile wall-clock queue delay (seconds).
    pub p95_queue_delay: f64,
    /// Total optimizer wall-clock overhead across committed rounds.
    pub optimizer_overhead: Duration,
    /// Per-tenant counters, tenants in name order.
    pub tenants: Vec<TenantStatus>,
}

impl ServiceStatus {
    /// Render the snapshot as a compact multi-line status block (the
    /// `agora serve --status-interval` ticker format).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "[status] config v{} | workers {} | in-flight {} | queued {}",
            self.config_version, self.workers, self.in_flight, self.queued
        );
        let _ = writeln!(
            out,
            "[status] rounds served {} retried {} failed {} | dags served {} | accepted {} rejected {}",
            self.rounds_served,
            self.rounds_retried,
            self.rounds_failed,
            self.dags_served,
            self.accepted,
            self.rejected
        );
        let _ = writeln!(
            out,
            "[status] completion p50 {:.1}s p95 {:.1}s | queue delay p50 {:.3}s p95 {:.3}s | util {:.2} | cost ${:.2} | opt {:.2}s",
            self.p50_completion,
            self.stats.p95_completion,
            self.p50_queue_delay,
            self.p95_queue_delay,
            self.stats.utilization,
            self.stats.total_cost,
            self.optimizer_overhead.as_secs_f64()
        );
        for t in &self.tenants {
            let _ = writeln!(
                out,
                "[status]   {}: queued {} accepted {} served {} rejected {}",
                t.tenant, t.queued, t.accepted, t.served, t.rejected
            );
        }
        out
    }
}

#[derive(Debug, Default, Clone)]
struct TenantCounters {
    accepted: usize,
    served: usize,
    rejected: usize,
}

#[derive(Debug, Default)]
struct Board {
    completions: Vec<f64>,
    delays: Vec<f64>,
    total_cost: f64,
    busy_core_seconds: f64,
    horizon: f64,
    rounds_served: usize,
    rounds_retried: usize,
    rounds_failed: usize,
    in_flight: usize,
    accepted: usize,
    rejected: usize,
    optimizer_overhead: Duration,
    tenants: BTreeMap<String, TenantCounters>,
}

/// Shared mutable counters behind [`ServiceStatus`]; written by the
/// handle (admission) and the control thread (commits), read by anyone.
#[derive(Debug, Default)]
pub(crate) struct StatusBoard {
    inner: Mutex<Board>,
}

impl StatusBoard {
    fn lock(&self) -> std::sync::MutexGuard<'_, Board> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// One submission admitted.
    pub(crate) fn record_accepted(&self, tenant: &str) {
        let mut b = self.lock();
        b.accepted += 1;
        b.tenants.entry(tenant.to_string()).or_default().accepted += 1;
    }

    /// One submission rejected with backpressure.
    pub(crate) fn record_rejected(&self, tenant: &str) {
        let mut b = self.lock();
        b.rejected += 1;
        b.tenants.entry(tenant.to_string()).or_default().rejected += 1;
    }

    /// Rounds currently dispatched and uncommitted.
    pub(crate) fn set_in_flight(&self, n: usize) {
        self.lock().in_flight = n;
    }

    /// One failed attempt re-queued by the retry ladder.
    pub(crate) fn round_retried(&self) {
        self.lock().rounds_retried += 1;
    }

    /// One round gave up after exhausting its retries.
    pub(crate) fn round_failed(&self) {
        self.lock().rounds_failed += 1;
    }

    /// Optimizer wall-clock spent by one attempt.
    pub(crate) fn add_overhead(&self, overhead: Duration) {
        self.lock().optimizer_overhead += overhead;
    }

    /// One round committed: per-DAG simulated completions, wall-clock
    /// queue delays, realized cost, busy core-seconds and the new
    /// absolute virtual-time horizon.
    pub(crate) fn round_committed(
        &self,
        tenants: &[String],
        completions: &[f64],
        delays: &[f64],
        cost: f64,
        busy_core_seconds: f64,
        horizon: f64,
    ) {
        let mut b = self.lock();
        b.rounds_served += 1;
        b.completions.extend_from_slice(completions);
        b.delays.extend_from_slice(delays);
        b.total_cost += cost;
        b.busy_core_seconds += busy_core_seconds;
        b.horizon = b.horizon.max(horizon);
        for t in tenants {
            b.tenants.entry(t.clone()).or_default().served += 1;
        }
    }

    /// Assemble a consistent snapshot. `depths` carries the live
    /// per-tenant queue depths from the ingress mailbox.
    pub(crate) fn snapshot(
        &self,
        admission: &str,
        capacity_vcpus: f64,
        depths: &[(String, usize)],
        config_version: u64,
        workers: usize,
        queued: usize,
    ) -> ServiceStatus {
        let b = self.lock();
        let utilization = if b.horizon > 0.0 && capacity_vcpus > 0.0 {
            b.busy_core_seconds / (capacity_vcpus * b.horizon)
        } else {
            0.0
        };
        let stats = AdmissionStats {
            admission: admission.to_string(),
            mean_completion: stats::mean(&b.completions),
            p95_completion: stats::percentile(&b.completions, 95.0),
            mean_queue_delay: stats::mean(&b.delays),
            utilization,
            total_cost: b.total_cost,
        };
        let mut names: Vec<String> = b.tenants.keys().cloned().collect();
        for (t, _) in depths {
            if !b.tenants.contains_key(t) {
                names.push(t.clone());
            }
        }
        names.sort();
        names.dedup();
        let tenants = names
            .into_iter()
            .map(|name| {
                let c = b.tenants.get(&name).cloned().unwrap_or_default();
                let queued = depths
                    .iter()
                    .find(|(t, _)| *t == name)
                    .map(|(_, q)| *q)
                    .unwrap_or(0);
                TenantStatus {
                    tenant: name,
                    queued,
                    accepted: c.accepted,
                    served: c.served,
                    rejected: c.rejected,
                }
            })
            .collect();
        ServiceStatus {
            config_version,
            workers,
            in_flight: b.in_flight,
            queued,
            rounds_served: b.rounds_served,
            rounds_retried: b.rounds_retried,
            rounds_failed: b.rounds_failed,
            dags_served: b.completions.len(),
            accepted: b.accepted,
            rejected: b.rejected,
            stats,
            p50_completion: stats::percentile(&b.completions, 50.0),
            p50_queue_delay: stats::percentile(&b.delays, 50.0),
            p95_queue_delay: stats::percentile(&b.delays, 95.0),
            optimizer_overhead: b.optimizer_overhead,
            tenants,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_into_a_snapshot() {
        let board = StatusBoard::default();
        board.record_accepted("a");
        board.record_accepted("a");
        board.record_accepted("b");
        board.record_rejected("b");
        board.round_retried();
        board.add_overhead(Duration::from_millis(250));
        board.round_committed(
            &["a".into(), "a".into()],
            &[100.0, 300.0],
            &[0.1, 0.2],
            5.0,
            400.0,
            300.0,
        );
        board.round_committed(&["b".into()], &[200.0], &[0.4], 2.5, 200.0, 500.0);
        board.set_in_flight(1);

        let s = board.snapshot(
            "rounds",
            16.0,
            &[("b".to_string(), 3)],
            2,
            4,
            3,
        );
        assert_eq!(s.config_version, 2);
        assert_eq!(s.workers, 4);
        assert_eq!(s.in_flight, 1);
        assert_eq!(s.queued, 3);
        assert_eq!(s.rounds_served, 2);
        assert_eq!(s.rounds_retried, 1);
        assert_eq!(s.rounds_failed, 0);
        assert_eq!(s.dags_served, 3);
        assert_eq!(s.accepted, 3);
        assert_eq!(s.rejected, 1);
        assert!((s.stats.mean_completion - 200.0).abs() < 1e-9);
        assert!((s.stats.total_cost - 7.5).abs() < 1e-9);
        // busy 600 core-s over 16 cores * horizon 500s
        assert!((s.stats.utilization - 600.0 / (16.0 * 500.0)).abs() < 1e-9);
        assert_eq!(s.optimizer_overhead, Duration::from_millis(250));
        assert!(s.p50_completion >= 100.0 && s.p50_completion <= 300.0);
        assert!(s.p95_queue_delay >= s.p50_queue_delay);

        assert_eq!(s.tenants.len(), 2);
        let a = &s.tenants[0];
        assert_eq!((a.tenant.as_str(), a.accepted, a.served, a.rejected, a.queued),
                   ("a", 2, 2, 0, 0));
        let b = &s.tenants[1];
        assert_eq!((b.tenant.as_str(), b.accepted, b.served, b.rejected, b.queued),
                   ("b", 1, 1, 1, 3));
    }

    #[test]
    fn queue_only_tenants_appear_in_the_snapshot() {
        let board = StatusBoard::default();
        let s = board.snapshot("rounds", 16.0, &[("ghost".to_string(), 2)], 1, 1, 2);
        assert_eq!(s.tenants.len(), 1);
        assert_eq!(s.tenants[0].tenant, "ghost");
        assert_eq!(s.tenants[0].queued, 2);
        assert_eq!(s.tenants[0].accepted, 0);
    }

    #[test]
    fn empty_board_snapshot_is_finite() {
        let board = StatusBoard::default();
        let s = board.snapshot("continuous", 16.0, &[], 1, 2, 0);
        assert_eq!(s.rounds_served, 0);
        assert_eq!(s.stats.utilization, 0.0);
        assert!(s.stats.mean_completion == 0.0 || s.stats.mean_completion.is_finite());
        let text = s.render();
        assert!(text.contains("config v1"));
        assert!(text.contains("workers 2"));
    }

    #[test]
    fn render_lists_tenants() {
        let board = StatusBoard::default();
        board.record_accepted("alice");
        let s = board.snapshot("rounds", 16.0, &[("alice".to_string(), 1)], 1, 1, 1);
        let text = s.render();
        assert!(text.contains("alice: queued 1 accepted 1"));
    }
}
