//! Default Apache Airflow scheduling (the paper's industry baseline).
//!
//! "Airflow internally calculates job priority weights by how many
//! children a job has in a DAG and schedules jobs accordingly. FIFO
//! heuristic is applied when multiple jobs have the same topological
//! order." No resource optimization: every task keeps the user's default
//! configuration (the expert-chosen Spark setup of §5).

use anyhow::Result;

use super::Scheduler;
use crate::solver::cooptimizer::Agora;
use crate::solver::sgs::serial_sgs;
use crate::solver::{Problem, Schedule};

/// Default Airflow scheduling: expert-default configs, priority-weight
/// dispatch (see module docs).
#[derive(Debug, Clone, Default)]
pub struct AirflowScheduler {
    /// Override the default config index (None = 4 x m5.4xlarge balanced).
    pub config: Option<usize>,
}

impl AirflowScheduler {
    /// Airflow priority weight: 1 + number of transitive downstream tasks.
    pub fn priority_weights(p: &Problem) -> Vec<f64> {
        let order = p.topo_order();
        let mut weight = vec![1.0f64; p.len()];
        for &u in order.iter().rev() {
            weight[u] = 1.0
                + p.succs(u)
                    .iter()
                    .map(|&v| weight[v])
                    .sum::<f64>();
        }
        weight
    }
}

impl Scheduler for AirflowScheduler {
    fn name(&self) -> &'static str {
        "airflow"
    }

    fn schedule(&self, p: &Problem) -> Result<Schedule> {
        let cfg = self.config.unwrap_or_else(|| Agora::default_config(&p.space));
        let assignment = vec![cfg; p.len()];
        // Priority weight with FIFO tie-break (task index): encode as
        // weight - epsilon * index so earlier-submitted tasks win ties.
        let weights = Self::priority_weights(p);
        let prio: Vec<f64> = weights
            .iter()
            .enumerate()
            .map(|(i, &w)| w - 1e-9 * i as f64)
            .collect();
        serial_sgs(p, &assignment, &prio)
    }
}

/// Dispatch-time visibility helper used by tests: which task would
/// Airflow launch first among a ready set.
pub fn first_dispatched(p: &Problem, ready: &[usize]) -> usize {
    let w = AirflowScheduler::priority_weights(p);
    *ready
        .iter()
        .max_by(|&&a, &&b| {
            w[a].total_cmp(&w[b]).then(b.cmp(&a)) // FIFO: lower index wins ties
        })
        .expect("non-empty ready set")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::{dag1, fig1_dag};
    use crate::predictor::OraclePredictor;
    use crate::Predictor;

    fn problem(dag: crate::Dag) -> Problem {
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &[dag],
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    #[test]
    fn priority_counts_transitive_children() {
        let p = problem(dag1());
        let w = AirflowScheduler::priority_weights(&p);
        // root (task 0) dominates everything
        assert!(w[0] > w[1]);
        // sinks have weight 1
        assert_eq!(w[6], 1.0);
        assert_eq!(w[7], 1.0);
    }

    #[test]
    fn produces_valid_schedule_with_default_configs() {
        let p = problem(fig1_dag());
        let s = AirflowScheduler::default().schedule(&p).unwrap();
        s.validate(&p).unwrap();
        let def = Agora::default_config(&p.space);
        assert!(s.assignment.iter().all(|&c| c == def));
    }

    #[test]
    fn fifo_breaks_ties() {
        let p = problem(fig1_dag());
        // tasks 1..3 are all sinks with equal weight -> FIFO picks 1
        assert_eq!(first_dispatched(&p, &[2, 1, 3]), 1);
    }

    #[test]
    fn higher_priority_dispatches_first() {
        let p = problem(dag1());
        // root vs a sink
        assert_eq!(first_dispatched(&p, &[7, 0]), 0);
    }
}
