//! CEDCES-style evolutionary deadline-constrained scheduler — the
//! cost-effective deadline-aware evolutionary baseline the Fig. 13
//! comparison pits against AGORA's simulated annealing under an equal
//! evaluation budget.
//!
//! A genome is a per-task configuration assignment; decoding runs the
//! same critical-path serial SGS every other scheduler uses, so fitness
//! is measured on exactly feasible schedules. Fitness is realized
//! dollar cost plus a deadline-violation penalty (hard SLAs use a large
//! constant per violated DAG on top of the linear overshoot term, so
//! any deadline-feasible genome dominates every infeasible one). A
//! CEDCES-style repair operator upgrades random tasks of a violating
//! DAG to their fastest configuration before evaluation.

use anyhow::Result;

use super::Scheduler;
use crate::solver::sgs::{priorities, serial_sgs, Rule};
use crate::solver::{Problem, Schedule};
use crate::util::Rng;

/// Large per-DAG fitness penalty for a violated hard deadline; dwarfs
/// any realistic dollar cost so evolution always prefers feasibility.
const HARD_VIOLATION_PENALTY: f64 = 1e6;

/// Deadline-aware evolutionary (genetic) scheduler.
#[derive(Debug, Clone)]
pub struct EvolutionaryScheduler {
    /// Genomes per generation.
    pub population: usize,
    /// Generations evolved after the seeded initial population.
    pub generations: usize,
    /// Per-gene mutation probability.
    pub mutation: f64,
    /// Repair attempts per violating DAG per evaluation.
    pub repairs: usize,
    /// RNG seed — the search is fully deterministic given the problem.
    pub seed: u64,
}

impl Default for EvolutionaryScheduler {
    fn default() -> Self {
        EvolutionaryScheduler {
            population: 16,
            generations: 24,
            mutation: 0.15,
            repairs: 4,
            seed: 0xCEDCE5,
        }
    }
}

impl EvolutionaryScheduler {
    /// Size the search to an evaluation budget comparable to an SA run
    /// of `evals` energy evaluations (population x (generations + 1)
    /// schedule decodings).
    pub fn with_budget(evals: usize) -> Self {
        let base = EvolutionaryScheduler::default();
        EvolutionaryScheduler {
            generations: (evals / base.population).saturating_sub(1).max(1),
            ..base
        }
    }

    /// Total schedule evaluations this configuration spends.
    pub fn evals(&self) -> usize {
        self.population * (self.generations + 1)
    }

    /// Decode a genome with the shared critical-path serial SGS.
    fn decode(p: &Problem, genome: &[usize]) -> Result<Schedule> {
        let prio = priorities(p, genome, Rule::CriticalPath);
        serial_sgs(p, genome, &prio)
    }

    /// Fitness: cost plus deadline penalties (lower is better).
    fn fitness(p: &Problem, s: &Schedule) -> f64 {
        let mut f = s.cost(p);
        for (d, sla) in p.slas.iter().enumerate() {
            if sla.is_unbounded() {
                continue;
            }
            let end = s.dag_completion(p, d);
            if end > sla.deadline {
                f += (end - sla.deadline) * sla.penalty_per_sec;
                if sla.hard {
                    f += HARD_VIOLATION_PENALTY + (end - sla.deadline);
                }
            }
        }
        f
    }

    /// CEDCES repair: upgrade random tasks of deadline-violating DAGs
    /// to their fastest feasible configuration. Every repair probe is a
    /// schedule decode and is charged to `decodes` — the historically
    /// uncounted part of the GA's budget.
    fn repair(
        &self,
        p: &Problem,
        genome: &mut [usize],
        rng: &mut Rng,
        decodes: &mut usize,
    ) -> Result<()> {
        for _ in 0..self.repairs {
            let s = Self::decode(p, genome)?;
            *decodes += 1;
            let violating: Vec<usize> = p
                .slas
                .iter()
                .enumerate()
                .filter(|(d, sla)| !sla.is_unbounded() && s.dag_completion(p, *d) > sla.deadline)
                .map(|(d, _)| d)
                .collect();
            if violating.is_empty() {
                return Ok(());
            }
            for d in violating {
                let tasks: Vec<usize> = (0..p.len()).filter(|&t| p.tasks[t].dag == d).collect();
                let t = *rng.choice(&tasks);
                if let Some(&fast) = p
                    .feasible
                    .iter()
                    .min_by(|&&a, &&b| p.duration(t, a).total_cmp(&p.duration(t, b)))
                {
                    genome[t] = fast;
                }
            }
        }
        Ok(())
    }
}

impl EvolutionaryScheduler {
    /// Like [`Scheduler::schedule`], but also returns the number of
    /// schedule decodes actually spent — fitness evaluations *and* repair
    /// probes (the final materialization of the winner is excluded, like
    /// SA's polish). This is the budget currency for fair equal-cost
    /// duels against the annealer.
    pub fn schedule_counted(&self, p: &Problem) -> Result<(Schedule, usize)> {
        let mut decodes = 0usize;
        let n = p.len();
        let mut rng = Rng::new(self.seed);
        let pop_size = self.population.max(2);

        // Seeded initial population: all-cheapest, all-fastest, then
        // uniform random genomes over the feasible configurations.
        let cheapest: Vec<usize> = (0..n)
            .map(|t| {
                *p.feasible
                    .iter()
                    .min_by(|&&a, &&b| p.cost(t, a).total_cmp(&p.cost(t, b)))
                    .expect("non-empty feasible set")
            })
            .collect();
        let fastest: Vec<usize> = (0..n)
            .map(|t| {
                *p.feasible
                    .iter()
                    .min_by(|&&a, &&b| p.duration(t, a).total_cmp(&p.duration(t, b)))
                    .expect("non-empty feasible set")
            })
            .collect();
        let mut population: Vec<Vec<usize>> = vec![cheapest, fastest];
        while population.len() < pop_size {
            population.push((0..n).map(|_| *rng.choice(&p.feasible)).collect());
        }

        let mut scored: Vec<(f64, Vec<usize>)> = Vec::with_capacity(pop_size);
        for mut genome in population {
            self.repair(p, &mut genome, &mut rng, &mut decodes)?;
            let s = Self::decode(p, &genome)?;
            decodes += 1;
            scored.push((Self::fitness(p, &s), genome));
        }
        scored.sort_by(|a, b| a.0.total_cmp(&b.0));

        for _ in 0..self.generations {
            let mut next: Vec<(f64, Vec<usize>)> = Vec::with_capacity(pop_size);
            // Elitism: the incumbent survives unchanged.
            next.push(scored[0].clone());
            while next.len() < pop_size {
                // Binary-tournament parents.
                let pick = |rng: &mut Rng| {
                    let a = rng.below(scored.len());
                    let b = rng.below(scored.len());
                    a.min(b) // scored is sorted: lower index = fitter
                };
                let pa = &scored[pick(&mut rng)].1;
                let pb = &scored[pick(&mut rng)].1;
                // Uniform crossover + per-gene mutation.
                let mut child: Vec<usize> = (0..n)
                    .map(|t| if rng.chance(0.5) { pa[t] } else { pb[t] })
                    .collect();
                for gene in child.iter_mut() {
                    if rng.chance(self.mutation) {
                        *gene = *rng.choice(&p.feasible);
                    }
                }
                self.repair(p, &mut child, &mut rng, &mut decodes)?;
                let s = Self::decode(p, &child)?;
                decodes += 1;
                next.push((Self::fitness(p, &s), child));
            }
            next.sort_by(|a, b| a.0.total_cmp(&b.0));
            scored = next;
        }

        Ok((Self::decode(p, &scored[0].1)?, decodes))
    }
}

impl Scheduler for EvolutionaryScheduler {
    fn name(&self) -> &'static str {
        "cedces-ga"
    }

    fn schedule(&self, p: &Problem) -> Result<Schedule> {
        self.schedule_counted(p).map(|(s, _)| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::solver::Sla;
    use crate::Predictor;

    fn problem(dags: Vec<crate::Dag>) -> Problem {
        let releases = vec![0.0; dags.len()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags
            .iter()
            .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
            .collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &dags,
            &releases,
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    #[test]
    fn produces_valid_schedules_and_is_deterministic() {
        let p = problem(vec![dag1(), dag2()]);
        let ga = EvolutionaryScheduler {
            population: 8,
            generations: 4,
            ..Default::default()
        };
        let a = ga.schedule(&p).unwrap();
        let b = ga.schedule(&p).unwrap();
        a.validate(&p).unwrap();
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(
            a.makespan(&p).to_bits(),
            b.makespan(&p).to_bits(),
            "same seed, same problem, same schedule"
        );
    }

    #[test]
    fn meets_a_loose_hard_deadline_when_one_exists() {
        let p = problem(vec![dag1()]);
        // A deadline 3x the completion lower bound is easily meetable.
        let lb = p.dag_lower_bounds()[0];
        let p = p.with_slas(vec![Sla::hard(3.0 * lb)]);
        let ga = EvolutionaryScheduler {
            population: 8,
            generations: 6,
            ..Default::default()
        };
        let s = ga.schedule(&p).unwrap();
        s.validate(&p).unwrap();
        assert!(s.dag_completion(&p, 0) <= 3.0 * lb + 1e-9);
    }

    #[test]
    fn budget_sizing_matches_requested_evals() {
        let ga = EvolutionaryScheduler::with_budget(400);
        assert_eq!(ga.population, 16);
        assert_eq!(ga.generations, 24);
        assert_eq!(ga.evals(), 400);
    }

    #[test]
    fn counted_decodes_cover_fitness_and_repair_probes() {
        let p = problem(vec![dag1()]);
        let ga = EvolutionaryScheduler {
            population: 8,
            generations: 4,
            ..Default::default()
        };
        let (s, decodes) = ga.schedule_counted(&p).unwrap();
        s.validate(&p).unwrap();
        // One fitness decode per evaluated genome (the elite clone is
        // carried over, not re-decoded) plus one repair probe each —
        // nothing violates without SLAs, so repair stops after its first
        // decode. The nominal `evals()` never counted the probes.
        let evaluated = ga.population + ga.generations * (ga.population - 1);
        assert_eq!(decodes, 2 * evaluated, "fitness + one repair probe each");
        let (s2, decodes2) = ga.schedule_counted(&p).unwrap();
        assert_eq!(s.assignment, s2.assignment);
        assert_eq!(decodes, decodes2, "counting must be deterministic");
    }
}
