//! MILP-style scheduler (TetriSched [40] flavour) — the paper's
//! representative optimization-based scheduler ("Ernest+MILP" in Fig. 7).
//!
//! TetriSched translates resource requests into a time-indexed MILP and
//! solves it to proven optimality. We reproduce that formulation's
//! structure: time is discretized into buckets, each task gets an integer
//! start-bucket variable, and a branch-and-bound over the integral
//! variables minimizes makespan under bucketized capacity constraints.
//! Durations are rounded UP to whole buckets, so any bucket-feasible
//! solution is feasible in continuous time (validated downstream) — the
//! cost of discretization is the quantization slack, the classic MILP
//! granularity/solve-time trade-off (`buckets` knob).

use std::time::{Duration, Instant};

use anyhow::Result;

use super::ernest::{ernest_selection, ErnestGoal};
use super::Scheduler;
use crate::solver::sgs::{priorities, serial_sgs, Rule};
use crate::solver::timeline::Timeline;
use crate::solver::{Problem, Schedule};

/// Ernest VM selection + time-indexed MILP scheduling ("Ernest+MILP").
#[derive(Debug, Clone)]
pub struct MilpScheduler {
    /// How per-task configs are chosen before scheduling.
    pub ernest_goal: Option<ErnestGoal>,
    /// Fixed assignment override (scheduler-only ablations).
    pub assignment: Option<Vec<usize>>,
    /// Time-discretization granularity (number of buckets in the horizon).
    pub buckets: usize,
    /// Branch-and-bound node budget.
    pub max_nodes: u64,
    /// Branch-and-bound wall-clock budget.
    pub max_time: Duration,
}

impl MilpScheduler {
    /// Two-step pipeline: Ernest picks configs, the MILP schedules them.
    pub fn with_ernest(goal: ErnestGoal) -> Self {
        MilpScheduler {
            ernest_goal: Some(goal),
            assignment: None,
            buckets: 64,
            max_nodes: 100_000,
            max_time: Duration::from_secs(5),
        }
    }

    /// Schedule a fixed externally chosen assignment.
    pub fn with_assignment(assignment: Vec<usize>) -> Self {
        MilpScheduler {
            ernest_goal: None,
            assignment: Some(assignment),
            buckets: 64,
            max_nodes: 100_000,
            max_time: Duration::from_secs(5),
        }
    }
}

struct MilpSearch<'a> {
    p: &'a Problem,
    /// duration in buckets per task
    dur: Vec<usize>,
    demands: Vec<(f64, f64)>,
    /// bottom level in buckets
    bottom: Vec<usize>,
    order: Vec<usize>,
    /// capacity usage per bucket (cpu, mem), pre-loaded with the
    /// problem's occupancy reservations
    cpu_used: Vec<f64>,
    mem_used: Vec<f64>,
    /// bucket indices where occupancy reservations end (extra candidate
    /// start points; empty for unseeded problems)
    reserve_ends: Vec<usize>,
    /// earliest allowed start bucket per task (release / admission floor,
    /// rounded up so bucket starts never precede the continuous release)
    rel: Vec<usize>,
    start: Vec<usize>,
    best: Option<Vec<usize>>,
    best_makespan: usize,
    nodes: u64,
    max_nodes: u64,
    deadline: Instant,
}

impl<'a> MilpSearch<'a> {
    fn fits(&self, t: usize, s: usize) -> bool {
        let (cpu, mem) = self.demands[t];
        for b in s..s + self.dur[t] {
            if b >= self.cpu_used.len() {
                return false;
            }
            if self.cpu_used[b] + cpu > self.p.capacity.vcpus + 1e-6
                || self.mem_used[b] + mem > self.p.capacity.memory_gb + 1e-6
            {
                return false;
            }
        }
        true
    }

    fn apply(&mut self, t: usize, s: usize, sign: f64) {
        let (cpu, mem) = self.demands[t];
        for b in s..s + self.dur[t] {
            self.cpu_used[b] += sign * cpu;
            self.mem_used[b] += sign * mem;
        }
    }

    fn dfs(&mut self, depth: usize, max_end: usize) {
        self.nodes += 1;
        if self.nodes >= self.max_nodes
            || (self.nodes % 1024 == 0 && Instant::now() >= self.deadline)
        {
            return;
        }
        if depth == self.order.len() {
            if max_end < self.best_makespan {
                self.best_makespan = max_end;
                self.best = Some(self.start.clone());
            }
            return;
        }
        let t = self.order[depth];
        let est = self
            .p
            .preds(t)
            .iter()
            .map(|&q| self.start[q] + self.dur[q])
            .fold(self.rel[t], usize::max);

        // Candidate start buckets: est plus ends of already-placed tasks
        // and of occupancy reservations.
        let mut candidates: Vec<usize> = vec![est];
        for d in 0..depth {
            let q = self.order[d];
            let end = self.start[q] + self.dur[q];
            if end > est {
                candidates.push(end);
            }
        }
        for &e in &self.reserve_ends {
            if e > est {
                candidates.push(e);
            }
        }
        candidates.sort_unstable();
        candidates.dedup();

        for s in candidates {
            let end = s + self.dur[t];
            let lb = (s + self.bottom[t]).max(max_end);
            if lb >= self.best_makespan {
                continue;
            }
            if !self.fits(t, s) {
                continue;
            }
            self.apply(t, s, 1.0);
            self.start[t] = s;
            self.dfs(depth + 1, max_end.max(end));
            self.apply(t, s, -1.0);
            if self.nodes >= self.max_nodes {
                return;
            }
        }
    }
}

impl Scheduler for MilpScheduler {
    fn name(&self) -> &'static str {
        "ernest+milp"
    }

    fn schedule(&self, p: &Problem) -> Result<Schedule> {
        let assignment = match (&self.assignment, self.ernest_goal) {
            (Some(a), _) => a.clone(),
            (None, Some(goal)) => ernest_selection(p, goal),
            (None, None) => {
                let c = crate::solver::cooptimizer::Agora::default_config(&p.space);
                vec![c; p.len()]
            }
        };

        // Horizon from a heuristic schedule; bucket size from it.
        let prio = priorities(p, &assignment, Rule::CriticalPath);
        let fallback = serial_sgs(p, &assignment, &prio)?;
        let horizon = fallback.makespan(p) * 1.05 + 1.0;
        let bucket = horizon / self.buckets as f64;

        let dur: Vec<usize> = (0..p.len())
            .map(|t| (p.duration(t, assignment[t]) / bucket).ceil().max(1.0) as usize)
            .collect();
        let demands: Vec<(f64, f64)> = (0..p.len()).map(|t| p.demand(assignment[t])).collect();
        let order = p.topo_order();
        let bottom = {
            let mut b = vec![0usize; p.len()];
            for &u in order.iter().rev() {
                b[u] = dur[u] + p.succs(u).iter().map(|&v| b[v]).max().unwrap_or(0);
            }
            b
        };
        // Generous bucket horizon: sequential worst case, extended past
        // the end of any occupancy reservation so seeded problems retain
        // free buckets after the reserved window.
        let reserved_horizon: usize = p
            .preplaced
            .iter()
            .map(|&(s, d, _, _)| (((s + d) / bucket).ceil().max(0.0)) as usize)
            .max()
            .unwrap_or(0);
        let total_buckets: usize = dur.iter().sum::<usize>() + 1 + reserved_horizon;

        // Pre-load the occupancy reservations (continuous admission)
        // through the shared block-indexed kernel: each bucket is charged
        // the maximum concurrent reservation usage over its window (an
        // aggregate query on the block maxima, not a segment rescan).
        // Still conservative (bucketized tasks cover their whole bucket,
        // so the max-usage instant binds), equal to the historical
        // rounded-outward per-reservation sum whenever reservations do
        // not share a bucket, and tighter when they do.
        let reserved = Timeline::seeded(p.capacity.vcpus, p.capacity.memory_gb, &p.preplaced);
        let mut cpu_used = vec![0.0; total_buckets];
        let mut mem_used = vec![0.0; total_buckets];
        for b in 0..total_buckets {
            let (c, m) = reserved.max_usage_in(b as f64 * bucket, (b + 1) as f64 * bucket);
            cpu_used[b] = c;
            mem_used[b] = m;
        }

        let mut reserve_ends: Vec<usize> = p
            .preplaced
            .iter()
            .map(|&(s, d, _, _)| (((s + d) / bucket).ceil().max(0.0)) as usize)
            .collect();
        reserve_ends.sort_unstable();
        reserve_ends.dedup();

        // Release / admission-floor anchoring, rounded up: a start at
        // bucket rel[t] is at or after the continuous-time release.
        let rel: Vec<usize> = (0..p.len())
            .map(|t| ((p.release[t] / bucket).ceil().max(0.0)) as usize)
            .collect();

        let mut search = MilpSearch {
            p,
            dur,
            demands,
            bottom,
            order,
            cpu_used,
            mem_used,
            reserve_ends,
            rel,
            start: vec![0usize; p.len()],
            best: None,
            best_makespan: usize::MAX,
            nodes: 0,
            max_nodes: self.max_nodes,
            deadline: Instant::now() + self.max_time,
        };
        search.dfs(0, 0);

        Ok(match search.best {
            Some(start_buckets) => {
                let start: Vec<f64> = start_buckets.iter().map(|&s| s as f64 * bucket).collect();
                // Continuous-time durations are <= bucketized ones, so the
                // bucket solution is feasible as-is.
                let s = Schedule {
                    assignment,
                    start,
                    optimal: false,
                };
                // Releases/occupancy are bucket-anchored conservatively,
                // but keep the seed-aware fallback as the safety net.
                if s.validate(p).is_ok() {
                    s
                } else {
                    fallback
                }
            }
            None => fallback,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::{dag1, dag2, fig1_dag};
    use crate::predictor::OraclePredictor;
    use crate::solver::cp::{CpSolver, Limits};
    use crate::solver::Goal;
    use crate::Predictor;

    fn problem(dag: crate::Dag) -> Problem {
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &[dag],
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    #[test]
    fn valid_schedules_on_evaluation_dags() {
        for dag in [fig1_dag(), dag1(), dag2()] {
            let p = problem(dag);
            let s = MilpScheduler::with_ernest(ErnestGoal(Goal::Balanced))
                .schedule(&p)
                .unwrap();
            s.validate(&p).unwrap();
        }
    }

    #[test]
    fn milp_respects_occupancy_seed() {
        // Full-capacity reservation over [0, 50): the returned schedule
        // (bucket solution or the seed-aware fallback) must stay clear of
        // the reserved window and pass the occupancy-aware validation.
        let cap = Capacity::micro();
        let p = problem(fig1_dag())
            .with_occupancy(vec![(0.0, 50.0, cap.vcpus, cap.memory_gb)], 50.0);
        let s = MilpScheduler::with_ernest(ErnestGoal(Goal::Balanced))
            .schedule(&p)
            .unwrap();
        s.validate(&p).unwrap();
        for t in 0..p.len() {
            assert!(
                s.start[t] + 1e-9 >= 50.0,
                "task {t} scheduled at {} inside the reservation",
                s.start[t]
            );
        }
    }

    #[test]
    fn milp_respects_admission_floor_without_reservations() {
        // Floor only, no reservation rectangles: the bucket search must
        // anchor starts at the release the floor was folded into (not
        // merely survive via the validate-fallback path).
        let p = problem(fig1_dag()).with_occupancy(Vec::new(), 40.0);
        let s = MilpScheduler::with_ernest(ErnestGoal(Goal::Balanced))
            .schedule(&p)
            .unwrap();
        s.validate(&p).unwrap();
        for t in 0..p.len() {
            assert!(
                s.start[t] + 1e-9 >= 40.0,
                "task {t} scheduled at {} before the floor",
                s.start[t]
            );
        }
    }

    #[test]
    fn close_to_cp_solver_within_quantization() {
        // MILP's makespan should be within one-bucket-per-task slack of
        // the exact continuous solver for the same assignment.
        let p = problem(dag1());
        let a = ernest_selection(&p, ErnestGoal(Goal::Runtime));
        let milp = MilpScheduler::with_assignment(a.clone()).schedule(&p).unwrap();
        let (exact, _) = CpSolver::new(Limits::default()).solve(&p, &a).unwrap();
        let slack = 1.3; // quantization overhead bound
        assert!(
            milp.makespan(&p) <= exact.makespan(&p) * slack + 1e-6,
            "milp {} vs exact {}",
            milp.makespan(&p),
            exact.makespan(&p)
        );
    }

    #[test]
    fn finer_buckets_do_not_hurt() {
        let p = problem(dag2());
        let a = ernest_selection(&p, ErnestGoal(Goal::Balanced));
        let coarse = MilpScheduler {
            buckets: 16,
            ..MilpScheduler::with_assignment(a.clone())
        }
        .schedule(&p)
        .unwrap();
        let fine = MilpScheduler {
            buckets: 128,
            ..MilpScheduler::with_assignment(a)
        }
        .schedule(&p)
        .unwrap();
        assert!(fine.makespan(&p) <= coarse.makespan(&p) * 1.05 + 1e-6);
    }
}
