//! Critical-Path (CP) list scheduling [Graham 1969] — the paper's
//! representative heuristic scheduler, combined with Ernest VM selection
//! ("Ernest+CP" in Fig. 7).

use anyhow::Result;

use super::ernest::{ernest_selection, ErnestGoal};
use super::Scheduler;
use crate::solver::sgs::{priorities, serial_sgs, Rule};
use crate::solver::{Problem, Schedule};

/// Ernest VM selection + critical-path list scheduling ("Ernest+CP").
#[derive(Debug, Clone)]
pub struct CriticalPathScheduler {
    /// How per-task configs are chosen before scheduling (the "separate"
    /// two-step pipeline the paper critiques).
    pub ernest_goal: Option<ErnestGoal>,
    /// Fixed assignment override (scheduler-only ablations).
    pub assignment: Option<Vec<usize>>,
}

impl CriticalPathScheduler {
    /// Two-step pipeline: Ernest picks configs, CP-list schedules them.
    pub fn with_ernest(goal: ErnestGoal) -> Self {
        CriticalPathScheduler {
            ernest_goal: Some(goal),
            assignment: None,
        }
    }

    /// Schedule a fixed externally chosen assignment.
    pub fn with_assignment(assignment: Vec<usize>) -> Self {
        CriticalPathScheduler {
            ernest_goal: None,
            assignment: Some(assignment),
        }
    }
}

impl Scheduler for CriticalPathScheduler {
    fn name(&self) -> &'static str {
        "ernest+cp"
    }

    fn schedule(&self, p: &Problem) -> Result<Schedule> {
        let assignment = match (&self.assignment, self.ernest_goal) {
            (Some(a), _) => a.clone(),
            (None, Some(goal)) => ernest_selection(p, goal),
            (None, None) => {
                let c = crate::solver::cooptimizer::Agora::default_config(&p.space);
                vec![c; p.len()]
            }
        };
        let prio = priorities(p, &assignment, Rule::CriticalPath);
        serial_sgs(p, &assignment, &prio)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::solver::Goal;
    use crate::Predictor;

    fn problem(dag: crate::Dag) -> Problem {
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &[dag],
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    #[test]
    fn valid_on_both_evaluation_dags() {
        for dag in [dag1(), dag2()] {
            let p = problem(dag);
            let s = CriticalPathScheduler::with_ernest(ErnestGoal(Goal::Balanced))
                .schedule(&p)
                .unwrap();
            s.validate(&p).unwrap();
        }
    }

    #[test]
    fn graham_bound_holds() {
        // List scheduling is within 2x of the resource LB + CP LB
        // (loose Graham-style sanity bound).
        let p = problem(dag2());
        let s = CriticalPathScheduler::with_ernest(ErnestGoal(Goal::Runtime))
            .schedule(&p)
            .unwrap();
        let lb = p.lower_bound(&s.assignment);
        assert!(s.makespan(&p) <= 2.5 * lb + 1e-6);
    }

    #[test]
    fn fixed_assignment_is_respected() {
        let p = problem(dag1());
        let a = vec![p.feasible[3]; p.len()];
        let s = CriticalPathScheduler::with_assignment(a.clone())
            .schedule(&p)
            .unwrap();
        assert_eq!(s.assignment, a);
    }
}
