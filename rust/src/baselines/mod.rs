//! Baseline schedulers from the paper's evaluation (§5.1): default
//! Airflow, Ernest VM selection combined with Critical-Path, MILP and
//! DAGPS troublesome-subgraph scheduling, and Stratus cost-aware
//! packing.
//!
//! Every baseline implements [`Scheduler`] over the same extended-RCPSP
//! [`Problem`] AGORA solves, so results are directly comparable and all
//! schedules pass the same feasibility validation.

pub mod airflow;
pub mod critical_path;
pub mod dagps;
pub mod ernest;
pub mod evolutionary;
pub mod milp;
pub mod stratus;

use anyhow::Result;

use crate::solver::{Problem, Schedule};

/// A scheduling policy producing a complete (assignment, start-times)
/// solution for a problem.
///
/// `schedule` returns `Result` so a degenerate problem (e.g. a capacity
/// with no feasible candidate slice for a policy's selection rule) is an
/// error the coordinator can handle per-round instead of a panic that
/// aborts a multi-tenant run.
pub trait Scheduler {
    /// Stable policy name for report tables.
    fn name(&self) -> &'static str;
    /// Produce a complete feasible schedule for the problem.
    fn schedule(&self, p: &Problem) -> Result<Schedule>;
}

pub use airflow::AirflowScheduler;
pub use critical_path::CriticalPathScheduler;
pub use dagps::DagpsScheduler;
pub use ernest::{ernest_selection, ErnestGoal};
pub use evolutionary::EvolutionaryScheduler;
pub use milp::MilpScheduler;
pub use stratus::StratusScheduler;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::solver::Goal;
    use crate::Predictor;

    fn problem() -> Problem {
        let dags = vec![dag1(), dag2()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags
            .iter()
            .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
            .collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &dags,
            &[0.0, 0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    #[test]
    fn every_baseline_produces_valid_schedules() -> anyhow::Result<()> {
        use anyhow::Context;
        let p = problem();
        let baselines: Vec<Box<dyn Scheduler>> = vec![
            Box::new(AirflowScheduler::default()),
            Box::new(CriticalPathScheduler::with_ernest(ErnestGoal::from(Goal::Balanced))),
            Box::new(DagpsScheduler::with_ernest(ErnestGoal::from(Goal::Balanced))),
            Box::new(MilpScheduler::with_ernest(ErnestGoal::from(Goal::Balanced))),
            Box::new(StratusScheduler::default()),
            Box::new(EvolutionaryScheduler {
                population: 6,
                generations: 3,
                ..Default::default()
            }),
        ];
        for b in baselines {
            let s = b.schedule(&p).with_context(|| b.name().to_string())?;
            s.validate(&p).with_context(|| b.name().to_string())?;
            assert!(s.makespan(&p) > 0.0, "{}", b.name());
        }
        Ok(())
    }
}
