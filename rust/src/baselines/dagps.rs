//! DAGPS/Graphene-style troublesome-subgraph packing [Grandl et al.,
//! OSDI 2016] — a topology-aware list scheduler that identifies the
//! tasks hardest to place late (long, resource-skewed, deep) and packs
//! them *first*, as whole precedence-connected subgraphs, before filling
//! the remaining tasks in criticality order.
//!
//! Scoring and subgraph growth live in [`crate::solver::sgs`]
//! ([`troublesome_scores`](crate::solver::sgs::troublesome_scores) /
//! [`troublesome_components`](crate::solver::sgs::troublesome_components))
//! so the same signal also seeds the annealer's portfolio and
//! prioritizes the replanner's suffix cone:
//!
//! - every task is scored `(duration / max duration) × resource skew ×
//!   (bottom level / max bottom level)` — normalized length times how
//!   lopsided its CPU:memory demand is times how deep a chain hangs off
//!   it;
//! - tasks scoring at least half the maximum are *troublesome*, and the
//!   maximal precedence-connected groups of troublesome tasks form the
//!   subgraphs, ranked by their peak score;
//! - [`Rule::Troublesome`](crate::solver::sgs::Rule::Troublesome) turns
//!   the ranked subgraphs into serial-SGS priorities: each subgraph gets
//!   a boost that dominates every plain criticality value, so subgraphs
//!   are packed whole and in rank order onto the shared [`Timeline`]
//!   before any filler task, and the remaining tasks follow by
//!   criticality.
//!
//! [`Timeline`]: crate::solver::timeline::Timeline

use anyhow::Result;

use super::ernest::{ernest_selection, ErnestGoal};
use super::Scheduler;
use crate::solver::sgs::{priorities, serial_sgs, Rule};
use crate::solver::{Problem, Schedule};

/// Ernest VM selection + DAGPS troublesome-subgraph-first packing
/// ("Ernest+DAGPS" in the fig7/fig11 baseline tables).
#[derive(Debug, Clone)]
pub struct DagpsScheduler {
    /// How per-task configs are chosen before scheduling (same two-step
    /// pipeline as the other Ernest-combined baselines).
    pub ernest_goal: Option<ErnestGoal>,
    /// Fixed assignment override (scheduler-only ablations).
    pub assignment: Option<Vec<usize>>,
}

impl DagpsScheduler {
    /// Two-step pipeline: Ernest picks configs, DAGPS packs them.
    pub fn with_ernest(goal: ErnestGoal) -> Self {
        DagpsScheduler {
            ernest_goal: Some(goal),
            assignment: None,
        }
    }

    /// Schedule a fixed externally chosen assignment.
    pub fn with_assignment(assignment: Vec<usize>) -> Self {
        DagpsScheduler {
            ernest_goal: None,
            assignment: Some(assignment),
        }
    }
}

impl Scheduler for DagpsScheduler {
    fn name(&self) -> &'static str {
        "ernest+dagps"
    }

    fn schedule(&self, p: &Problem) -> Result<Schedule> {
        let assignment = match (&self.assignment, self.ernest_goal) {
            (Some(a), _) => a.clone(),
            (None, Some(goal)) => ernest_selection(p, goal),
            (None, None) => {
                let c = crate::solver::cooptimizer::Agora::default_config(&p.space);
                vec![c; p.len()]
            }
        };
        let prio = priorities(p, &assignment, Rule::Troublesome);
        serial_sgs(p, &assignment, &prio)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::solver::Goal;
    use crate::Predictor;

    fn problem(dag: crate::Dag) -> Problem {
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &[dag],
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    #[test]
    fn valid_on_both_evaluation_dags() {
        for dag in [dag1(), dag2()] {
            let p = problem(dag);
            let s = DagpsScheduler::with_ernest(ErnestGoal(Goal::Balanced))
                .schedule(&p)
                .unwrap();
            s.validate(&p).unwrap();
        }
    }

    #[test]
    fn fixed_assignment_is_respected() {
        let p = problem(dag1());
        let a = vec![p.feasible[3]; p.len()];
        let s = DagpsScheduler::with_assignment(a.clone()).schedule(&p).unwrap();
        assert_eq!(s.assignment, a);
    }

    #[test]
    fn deterministic_across_runs() {
        let p = problem(dag2());
        let run = || {
            DagpsScheduler::with_ernest(ErnestGoal(Goal::Runtime))
                .schedule(&p)
                .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.start, b.start);
    }
}
