//! Ernest-style per-task VM selection (§2.1, §5.1).
//!
//! Ernest predicts each job's runtime across machine counts and picks the
//! configuration closest to the goal — *per task, in isolation*: it sees
//! neither the DAG structure nor cluster contention, which is exactly the
//! gap the paper's motivational study exposes. Our implementation selects
//! from the Ernest slice of the config space (instance x nodes, default
//! Spark preset — Ernest does not tune application parameters).

use crate::solver::{Goal, Problem};

/// Ernest's optimization target for each task.
#[derive(Debug, Clone, Copy)]
pub struct ErnestGoal(pub Goal);

impl From<Goal> for ErnestGoal {
    fn from(g: Goal) -> Self {
        ErnestGoal(g)
    }
}

/// Pick each task's configuration in isolation (no DAG/cluster view).
/// Restricted to balanced-Spark configs: Ernest selects VMs, not Spark
/// parameters.
pub fn ernest_selection(p: &Problem, goal: ErnestGoal) -> Vec<usize> {
    let w = goal.0.weight();
    let candidates: Vec<usize> = p
        .feasible
        .iter()
        .copied()
        .filter(|&c| p.space.configs[c].spark == 1)
        .collect();
    let candidates = if candidates.is_empty() {
        p.feasible.clone()
    } else {
        candidates
    };

    (0..p.len())
        .map(|t| {
            let min_d = candidates
                .iter()
                .map(|&c| p.duration(t, c))
                .fold(f64::INFINITY, f64::min)
                .max(1e-9);
            let min_cost = candidates
                .iter()
                .map(|&c| p.cost(t, c))
                .fold(f64::INFINITY, f64::min)
                .max(1e-9);
            let score =
                |c: usize| w * p.duration(t, c) / min_d + (1.0 - w) * p.cost(t, c) / min_cost;
            *candidates
                .iter()
                .min_by(|&&a, &&b| score(a).total_cmp(&score(b)))
                .unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::fig1_dag;
    use crate::predictor::OraclePredictor;
    use crate::Predictor;

    fn problem() -> Problem {
        let dag = fig1_dag();
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &[dag],
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    #[test]
    fn runtime_goal_picks_fastest_per_task() {
        let p = problem();
        let sel = ernest_selection(&p, ErnestGoal(Goal::Runtime));
        for (t, &c) in sel.iter().enumerate() {
            let d = p.duration(t, c);
            for &other in &p.feasible {
                if p.space.configs[other].spark == 1 {
                    assert!(
                        d <= p.duration(t, other) + 1e-9,
                        "task {t}: picked {d}, but config {other} gives {}",
                        p.duration(t, other)
                    );
                }
            }
        }
    }

    #[test]
    fn cost_goal_picks_cheapest_per_task() {
        let p = problem();
        let sel = ernest_selection(&p, ErnestGoal(Goal::Cost));
        for (t, &c) in sel.iter().enumerate() {
            let cost = p.cost(t, c);
            for &other in &p.feasible {
                if p.space.configs[other].spark == 1 {
                    assert!(cost <= p.cost(t, other) + 1e-9);
                }
            }
        }
    }

    #[test]
    fn selection_avoids_spark_tuning() {
        let p = problem();
        for goal in [Goal::Cost, Goal::Balanced, Goal::Runtime] {
            let sel = ernest_selection(&p, ErnestGoal(goal));
            assert!(sel.iter().all(|&c| p.space.configs[c].spark == 1));
        }
    }

    #[test]
    fn runtime_goal_uses_more_resources_than_cost_goal() {
        let p = problem();
        let fast = ernest_selection(&p, ErnestGoal(Goal::Runtime));
        let cheap = ernest_selection(&p, ErnestGoal(Goal::Cost));
        let vcpus = |sel: &[usize]| -> f64 {
            sel.iter().map(|&c| p.space.configs[c].vcpus()).sum()
        };
        assert!(vcpus(&fast) >= vcpus(&cheap));
    }
}
