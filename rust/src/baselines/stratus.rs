//! Stratus [14] — cost-aware container scheduling in the public cloud,
//! the closest prior work to AGORA (§2.2).
//!
//! Stratus (a) selects VMs per task to minimize cost given *predefined*
//! resource demands, and (b) packs workloads with similar remaining
//! runtimes onto the same instances (runtime binning) to keep VMs fully
//! utilized until they can be released. It is not DAG-aware and optimizes
//! cost only; per the paper we "embedded DAG dependencies into Stratus"
//! so it at least respects precedence.
//!
//! Adaptation to our substrate: runtime binning is expressed by choosing,
//! per task, the cheapest configuration whose predicted runtime lands in
//! the same power-of-two bin as the task's fastest achievable runtime —
//! Stratus' "scale up while cheap, align completion times" behaviour.
//! Its empirical signature in the paper (Fig. 7: lowest runtime, but
//! higher cost than AGORA because "it simply utilizes any resources
//! available") emerges from that rule.

use anyhow::{anyhow, Result};

use super::Scheduler;
use crate::solver::sgs::serial_sgs;
use crate::solver::{Problem, Schedule};

/// Stratus cost-aware packing with runtime binning (see module docs).
#[derive(Debug, Clone)]
pub struct StratusScheduler {
    /// Runtime-bin width in powers of two (1.0 = one octave).
    pub bin_octaves: f64,
}

impl Default for StratusScheduler {
    fn default() -> Self {
        StratusScheduler { bin_octaves: 0.5 }
    }
}

impl StratusScheduler {
    /// Stratus VM selection: cheapest config inside the fastest runtime
    /// bin. Spark parameters stay at the predefined default (Stratus
    /// assumes fixed per-task demands). Errors when the policy's
    /// candidate slice (balanced-Spark feasible configs) is empty —
    /// propagated instead of panicking so one degenerate tenant problem
    /// cannot abort a coordinator round.
    pub fn select(&self, p: &Problem) -> Result<Vec<usize>> {
        let candidates: Vec<usize> = p
            .feasible
            .iter()
            .copied()
            .filter(|&c| p.space.configs[c].spark == 1)
            .collect();
        if candidates.is_empty() {
            return Err(anyhow!(
                "stratus: no feasible balanced-Spark configuration fits the cluster"
            ));
        }
        (0..p.len())
            .map(|t| {
                let fastest = candidates
                    .iter()
                    .map(|&c| p.duration(t, c))
                    .fold(f64::INFINITY, f64::min);
                // The bin: [fastest, fastest * 2^octaves)
                let ceiling = fastest * 2.0f64.powf(self.bin_octaves);
                candidates
                    .iter()
                    .copied()
                    .filter(|&c| p.duration(t, c) <= ceiling)
                    .min_by(|&a, &b| p.cost(t, a).total_cmp(&p.cost(t, b)))
                    .ok_or_else(|| {
                        anyhow!("stratus: task {t} has an empty runtime bin")
                    })
            })
            .collect()
    }

    /// Runtime-aligned dispatch priority: tasks whose durations are
    /// similar get similar priorities so they co-locate in time
    /// (completion-time alignment), with longer-first as the primary key.
    fn alignment_priorities(p: &Problem, assignment: &[usize]) -> Vec<f64> {
        (0..p.len())
            .map(|t| {
                let d = p.duration(t, assignment[t]).max(1.0);
                // quantize to octaves: tasks in the same bin tie, then
                // FIFO by index
                let bin = d.log2().floor();
                bin * 1000.0 - t as f64 * 1e-6
            })
            .collect()
    }
}

impl Scheduler for StratusScheduler {
    fn name(&self) -> &'static str {
        "stratus"
    }

    fn schedule(&self, p: &Problem) -> Result<Schedule> {
        let assignment = self.select(p)?;
        let prio = Self::alignment_priorities(p, &assignment);
        serial_sgs(p, &assignment, &prio)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::solver::Goal;
    use crate::Predictor;

    fn problem(dag: crate::Dag) -> Problem {
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &[dag],
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    #[test]
    fn valid_schedule() {
        for dag in [dag1(), dag2()] {
            let p = problem(dag);
            let s = StratusScheduler::default().schedule(&p).unwrap();
            s.validate(&p).unwrap();
        }
    }

    #[test]
    fn faster_but_pricier_than_pure_cost_selection() {
        // The paper's Fig. 7 signature: Stratus shows the lowest runtime
        // but not the lowest cost.
        let p = problem(dag2());
        let stratus = StratusScheduler::default().schedule(&p).unwrap();
        let cheap = super::super::ernest::ernest_selection(
            &p,
            super::super::ernest::ErnestGoal(Goal::Cost),
        );
        let cheap_sched = serial_sgs(
            &p,
            &cheap,
            &crate::solver::sgs::priorities(&p, &cheap, crate::solver::sgs::Rule::CriticalPath),
        )
        .unwrap();
        assert!(stratus.makespan(&p) <= cheap_sched.makespan(&p) + 1e-6);
        assert!(stratus.cost(&p) >= cheap_sched.cost(&p) - 1e-6);
    }

    #[test]
    fn selection_is_within_runtime_bin() {
        let p = problem(dag1());
        let sched = StratusScheduler::default();
        let sel = sched.select(&p).unwrap();
        for (t, &c) in sel.iter().enumerate() {
            let fastest = p
                .feasible
                .iter()
                .filter(|&&c| p.space.configs[c].spark == 1)
                .map(|&c| p.duration(t, c))
                .fold(f64::INFINITY, f64::min);
            assert!(p.duration(t, c) <= fastest * 2.0f64.powf(0.5) + 1e-9);
        }
    }
}
