//! The shared capacity-timeline kernel: a block-indexed **capacity
//! profile** over (vcpus, memory) usage that every scheduling primitive
//! in the repo packs against.
//!
//! Every plan the optimizer evaluates — thousands of annealing probes per
//! round, each CP branch-and-bound node, every executor dispatch, every
//! `Schedule::validate` — bottoms out in [`Timeline::earliest_fit`] /
//! [`Timeline::place`]. Three generations of the kernel coexist here:
//!
//! * [`reference`] — the original flat rectangle list that rescanned all
//!   placements at every event point: O(n²) per feasibility query, O(n³)
//!   per serial-SGS pass. Retained verbatim as the executable
//!   specification.
//! * [`flat`] — the PR 4 sweep-line profile: one sorted `Vec` of
//!   change-points with absolute per-segment usage. O(log n + k) queries,
//!   but `place` pays an O(n) contiguous memmove per newly inserted
//!   change-point, so a full n-placement SGS pass is O(n²). Retained as
//!   a second executable reference that scales far enough (10⁴–10⁵
//!   tasks) to cross-check the production kernel at every bench size.
//! * [`Timeline`] (this type) — the production kernel: the same profile
//!   **block-decomposed** into bounded runs of change-points, each block
//!   carrying `(max_cpu, max_mem)` range aggregates over its segments.
//!
//! | operation      | rectangle list | flat profile | indexed profile |
//! |----------------|----------------|--------------|-----------------|
//! | `place`        | O(1) push      | O(k) update + O(n) memmove | O(log n + k) locate + update; O(√-ish block) insert, amortized splits |
//! | `earliest_fit` | O(n²)          | O(log n + k) | O(log n + B + k′): clear blocks skip in O(1) via aggregates |
//! | `max_usage_in` | O(n²)          | O(log n + k) | O(log n + B + boundary blocks) aggregate query |
//! | backtrack      | O(1) pop       | O(k) exact [`Timeline::rollback`] | O(k + touched blocks) exact [`Timeline::rollback`] |
//! | full validate  | O(n²)          | O(n log n) build + O(n) scan | O(n log n) build + O(n) scan |
//!
//! (`k` = segments a placement window crosses; `B` = number of blocks,
//! ≈ n / [`BLOCK_CAP`]; `k′` = segments inside *dirty* blocks only — a
//! block whose aggregate leaves room for the demand is skipped whole,
//! which is what keeps a 10⁵-task serial-SGS pass out of the O(n²)
//! regime the flat kernel hits through its per-insert memmove.)
//!
//! ## Checkpoint / rollback
//!
//! Explicit epoch marks carry over from the flat kernel **bit-exactly**:
//! [`Timeline::checkpoint`] returns a [`Mark`], and
//! [`Timeline::rollback`] restores the timeline to that mark exactly
//! (bit-for-bit, via an undo journal of overwritten segment values — not
//! by re-subtracting floats, which would accumulate rounding drift over
//! the millions of place/undo cycles an annealing run performs). Journal
//! entries are keyed by the placement's *time window* rather than by
//! physical indices: blocks split and shift, but the LIFO discipline
//! guarantees the point set at undo time is identical to the point set
//! right after the corresponding place, so a time-keyed walk restores
//! exactly the segments that were raised. Rollback is LIFO: marks must
//! be released in reverse order of creation, which is the natural
//! discipline of both the CP solver's DFS and the incremental
//! evaluators' shared-prefix reuse.
//!
//! ## Infeasible demands and non-finite windows
//!
//! [`Timeline::earliest_fit`] returns `None` when the demand can never
//! run on this cluster (it exceeds total capacity on its own) **and**
//! when any of `est`/`d`/`cpu`/`mem` is non-finite. The latter is a
//! bugfix: NaN windows made every sweep comparison false, so the flat
//! kernel fell through to `Some(est)` — handing the caller a NaN start
//! that `place` then silently journaled as a no-op rectangle, i.e. a
//! corrupted schedule with no error. [`Timeline::max_usage_in`] is
//! likewise explicitly `(0, 0)` on non-finite bounds. Callers surface
//! `None` through their `anyhow::Result` paths (see `sgs::serial_sgs`).
//!
//! ## Equivalence contract
//!
//! The kernel produces **bit-identical schedules** to both retained
//! kernels: `earliest_fit` returns either `est` or the exact stored end
//! of a placed rectangle, and feasibility uses the same `1e-6` capacity
//! tolerance. Block aggregates never change an answer: a block is
//! skipped only when `max + demand` fits capacity, which (addition is
//! monotone in IEEE) implies no segment inside could have moved the
//! candidate start, and `max_usage_in`'s block shortcut contributes the
//! exact per-block maximum the segment-wise sweep would have folded in.
//! One caveat bounds the claim against [`reference`]: the rectangle list
//! probed usage at `point + 1e-9`, while the profile kernels use exact
//! half-open segments; the two can disagree only when two *distinct*
//! change-points lie within 1e-9 of each other, which this codebase's
//! identical-float-expression times never produce. Property tests (here
//! and in `sgs`/`invariants`) and the `scaling_timeline` bench run the
//! three kernels side by side on random seeded/occupied problems to keep
//! the equivalence honest empirically — the bench asserts bit-identical
//! schedules at every measured size up to 10⁵ tasks.

use super::rcpsp::Reservation;

/// Capacity slack mirrored from the historical kernel: usage may
/// overshoot capacity by at most this before a window is infeasible.
const CAP_EPS: f64 = 1e-6;

/// Split threshold for profile blocks: a block that grows past this many
/// change-points splits in two. 512 keeps a block's three parallel
/// arrays ≈ 12 KiB (cache-resident for the segment walks) while holding
/// the per-insert memmove to a bounded ~4 KiB `memcpy`.
const BLOCK_CAP: usize = 512;

/// An epoch mark returned by [`Timeline::checkpoint`]: the number of
/// placements journaled so far. [`Timeline::rollback`] restores the
/// timeline to the state it had when the mark was taken.
pub type Mark = usize;

/// One journaled placement, keyed by its time window: which
/// change-points it inserted and where its overwritten usage values
/// start on the save stack. Undo replays these exactly (LIFO) — by the
/// LIFO contract the change-point *set* at undo time equals the set
/// right after the place, so locating by time is exact even though
/// physical block indices have shifted across splits.
#[derive(Debug, Clone, Copy)]
struct JournalEntry {
    /// Window start (a change-point of the profile while this entry is
    /// live, unless `noop`).
    s: f64,
    /// Window end (likewise a live change-point unless `noop`).
    e: f64,
    /// Whether the placement inserted the change-point at `s`.
    ins_lo: bool,
    /// Whether the placement inserted the change-point at `e`.
    ins_hi: bool,
    /// Offset into [`Timeline::saved`] of this placement's overwritten
    /// `(cpu, mem)` values (one pair per raised segment).
    saved_off: usize,
    /// Non-positive or NaN window: nothing was touched.
    noop: bool,
}

/// One bounded run of consecutive change-points with the absolute
/// (cpu, mem) usage of the constant segment starting at each, plus the
/// range aggregate over those segments. Blocks partition the profile in
/// time order; every block is non-empty.
#[derive(Debug, Clone)]
struct Block {
    /// Sorted distinct change-points of this block.
    points: Vec<f64>,
    /// Usage on the segment starting at `points[i]` (extending to the
    /// next point, possibly in the next block; the global final segment
    /// extends to infinity and always carries zero usage).
    seg_cpu: Vec<f64>,
    seg_mem: Vec<f64>,
    /// `max(seg_cpu)` over the block (floored at 0.0, like every usage
    /// fold in the kernel): the aggregate that lets `earliest_fit` and
    /// `max_usage_in` treat the whole block as one unit.
    max_cpu: f64,
    /// `max(seg_mem)` over the block, same convention.
    max_mem: f64,
}

impl Block {
    fn recompute_max(&mut self) {
        let mut mc = 0.0f64;
        let mut mm = 0.0f64;
        for (&c, &m) in self.seg_cpu.iter().zip(self.seg_mem.iter()) {
            mc = mc.max(c);
            mm = mm.max(m);
        }
        self.max_cpu = mc;
        self.max_mem = mm;
    }

    fn last_point(&self) -> f64 {
        *self.points.last().expect("blocks are never empty")
    }
}

/// Resource timeline of placed rectangular tasks, stored as a
/// block-indexed capacity profile: sorted change-points with the
/// absolute (cpu, mem) usage of the constant segment starting at each,
/// decomposed into bounded blocks carrying `(max_cpu, max_mem)` range
/// aggregates. See the module docs for the representation, complexity,
/// and rollback contract.
#[derive(Debug, Clone)]
pub struct Timeline {
    cap_cpu: f64,
    cap_mem: f64,
    /// Time-ordered profile blocks (all non-empty).
    blocks: Vec<Block>,
    /// Undo journal, one entry per `place` call (including no-ops).
    journal: Vec<JournalEntry>,
    /// Stack of overwritten segment usage values, LIFO with `journal`.
    saved: Vec<(f64, f64)>,
}

impl Timeline {
    /// Empty timeline with the given capacity.
    pub fn new(cap_cpu: f64, cap_mem: f64) -> Self {
        Timeline {
            cap_cpu,
            cap_mem,
            blocks: Vec::new(),
            journal: Vec::new(),
            saved: Vec::new(),
        }
    }

    /// Timeline pre-seeded with occupancy reservations (continuous
    /// multi-tenant admission, committed work during a replan, outage
    /// blockers). The seed rectangles are ordinary journaled placements:
    /// a [`checkpoint`](Timeline::checkpoint) taken right after
    /// construction protects them from any later rollback.
    pub fn seeded(cap_cpu: f64, cap_mem: f64, reservations: &[Reservation]) -> Self {
        let mut tl = Timeline::new(cap_cpu, cap_mem);
        for &(s, d, cpu, mem) in reservations {
            tl.place(s, d, cpu, mem);
        }
        tl
    }

    /// Cluster vCPU capacity this timeline packs against.
    pub fn cap_cpu(&self) -> f64 {
        self.cap_cpu
    }

    /// Cluster memory capacity (GiB) this timeline packs against.
    pub fn cap_mem(&self) -> f64 {
        self.cap_mem
    }

    /// Number of blocks the profile currently spans (bench/test
    /// introspection; ≈ change-points / [`BLOCK_CAP`]).
    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }

    /// Segment containing `t`: the block index and in-block index of the
    /// last change-point at or before `t` (total order, like every
    /// profile lookup). `None` when `t` precedes every point.
    fn locate_seg(&self, t: f64) -> Option<(usize, usize)> {
        let nb = self
            .blocks
            .partition_point(|b| b.points[0].total_cmp(&t).is_le());
        let bi = nb.checked_sub(1)?;
        let si = self.blocks[bi]
            .points
            .partition_point(|p| p.total_cmp(&t).is_le());
        // `si >= 1` because this block's first point is <= t.
        Some((bi, si - 1))
    }

    /// End of segment `(bi, si)`: the next change-point, crossing into
    /// the following block when needed; infinity past the last point.
    fn seg_end(&self, bi: usize, si: usize) -> f64 {
        let b = &self.blocks[bi];
        if si + 1 < b.points.len() {
            b.points[si + 1]
        } else if bi + 1 < self.blocks.len() {
            self.blocks[bi + 1].points[0]
        } else {
            f64::INFINITY
        }
    }

    /// Insert change-point `t` (with the usage of the segment it splits)
    /// when absent; returns whether it was inserted.
    fn ensure_point(&mut self, t: f64) -> bool {
        if self.blocks.is_empty() {
            self.blocks.push(Block {
                points: vec![t],
                seg_cpu: vec![0.0],
                seg_mem: vec![0.0],
                max_cpu: 0.0,
                max_mem: 0.0,
            });
            return true;
        }
        let nb = self
            .blocks
            .partition_point(|b| b.points[0].total_cmp(&t).is_le());
        // `t` before every point lands at the front of block 0.
        let bi = nb.saturating_sub(1);
        match self.blocks[bi].points.binary_search_by(|p| p.total_cmp(&t)) {
            Ok(_) => false,
            Err(pos) => {
                let (c, m) = if pos > 0 {
                    (self.blocks[bi].seg_cpu[pos - 1], self.blocks[bi].seg_mem[pos - 1])
                } else if bi > 0 {
                    // Defensive: unreachable given how `bi` is chosen
                    // (pos == 0 implies t precedes block 0's first point).
                    let pb = &self.blocks[bi - 1];
                    (*pb.seg_cpu.last().unwrap(), *pb.seg_mem.last().unwrap())
                } else {
                    (0.0, 0.0)
                };
                let b = &mut self.blocks[bi];
                b.points.insert(pos, t);
                b.seg_cpu.insert(pos, c);
                b.seg_mem.insert(pos, m);
                // A split segment inherits its usage: the aggregate can
                // only be confirmed, never raised past the old max — but
                // fold it in anyway (cheap, and exact when the inherited
                // value crossed a block boundary).
                b.max_cpu = b.max_cpu.max(c);
                b.max_mem = b.max_mem.max(m);
                if b.points.len() > BLOCK_CAP {
                    self.split_block(bi);
                }
                true
            }
        }
    }

    /// Split block `bi` in half, recomputing both aggregates. O(block)
    /// plus an O(B) shift of the block directory — amortized across the
    /// ≥ `BLOCK_CAP`/2 inserts that grew the block.
    fn split_block(&mut self, bi: usize) {
        let half = self.blocks[bi].points.len() / 2;
        let b = &mut self.blocks[bi];
        let points = b.points.split_off(half);
        let seg_cpu = b.seg_cpu.split_off(half);
        let seg_mem = b.seg_mem.split_off(half);
        b.recompute_max();
        let mut tail = Block {
            points,
            seg_cpu,
            seg_mem,
            max_cpu: 0.0,
            max_mem: 0.0,
        };
        tail.recompute_max();
        self.blocks.insert(bi + 1, tail);
    }

    /// Remove change-point `t` (which must exist — it came from the
    /// journal), dropping its block when that leaves the block empty.
    fn remove_point(&mut self, t: f64) {
        let nb = self
            .blocks
            .partition_point(|b| b.points[0].total_cmp(&t).is_le());
        let bi = nb.checked_sub(1).expect("journaled change-point must exist");
        let b = &mut self.blocks[bi];
        let pos = b
            .points
            .binary_search_by(|p| p.total_cmp(&t))
            .expect("journaled change-point must exist");
        b.points.remove(pos);
        b.seg_cpu.remove(pos);
        b.seg_mem.remove(pos);
        if self.blocks[bi].points.is_empty() {
            self.blocks.remove(bi);
        } else {
            self.blocks[bi].recompute_max();
        }
    }

    /// Reserve a (cpu, mem) rectangle over `[s, s+d)`. Non-positive
    /// durations are journaled as no-ops so mark arithmetic stays 1:1
    /// with `place` calls.
    pub fn place(&mut self, s: f64, d: f64, cpu: f64, mem: f64) {
        let e = s + d;
        // NaN-safe "not strictly after": NaN windows are no-ops too.
        if e.partial_cmp(&s) != Some(std::cmp::Ordering::Greater) {
            self.journal.push(JournalEntry {
                s,
                e,
                ins_lo: false,
                ins_hi: false,
                saved_off: self.saved.len(),
                noop: true,
            });
            return;
        }
        let ins_lo = self.ensure_point(s);
        let ins_hi = self.ensure_point(e);
        let saved_off = self.saved.len();
        // Raise every segment in [s, e): a forward walk from the segment
        // starting exactly at `s` (just ensured) to the one starting at
        // `e`, saving the overwritten values for exact undo.
        let (mut bi, mut si) = self.locate_seg(s).expect("start point was just ensured");
        let nb = self.blocks.len();
        loop {
            if self.blocks[bi].points[si].total_cmp(&e).is_ge() {
                break;
            }
            let b = &mut self.blocks[bi];
            let oc = b.seg_cpu[si];
            let om = b.seg_mem[si];
            b.seg_cpu[si] = oc + cpu;
            b.seg_mem[si] = om + mem;
            b.max_cpu = b.max_cpu.max(oc + cpu);
            b.max_mem = b.max_mem.max(om + mem);
            self.saved.push((oc, om));
            si += 1;
            if si >= self.blocks[bi].points.len() {
                bi += 1;
                si = 0;
                if bi >= nb {
                    break;
                }
            }
        }
        self.journal.push(JournalEntry {
            s,
            e,
            ins_lo,
            ins_hi,
            saved_off,
            noop: false,
        });
    }

    /// Undo the most recent journaled placement exactly (restores the
    /// overwritten usage bytes; removes the change-points it inserted;
    /// recomputes the aggregates of the touched blocks from the restored
    /// bytes, so they too are bit-identical to their pre-place values).
    fn unplace(&mut self) {
        let entry = self
            .journal
            .pop()
            .expect("rollback below the empty timeline");
        if entry.noop {
            debug_assert_eq!(entry.saved_off, self.saved.len());
            return;
        }
        let (mut bi, mut si) = self
            .locate_seg(entry.s)
            .expect("journaled start point must exist while its entry is live");
        let first_block = bi;
        let nb = self.blocks.len();
        let mut k = entry.saved_off;
        loop {
            if self.blocks[bi].points[si].total_cmp(&entry.e).is_ge() {
                break;
            }
            let (c, m) = self.saved[k];
            k += 1;
            let b = &mut self.blocks[bi];
            b.seg_cpu[si] = c;
            b.seg_mem[si] = m;
            si += 1;
            if si >= self.blocks[bi].points.len() {
                bi += 1;
                si = 0;
                if bi >= nb {
                    break;
                }
            }
        }
        debug_assert_eq!(k, self.saved.len(), "undo must consume exactly its saves");
        self.saved.truncate(entry.saved_off);
        for b in first_block..=bi.min(nb - 1) {
            self.blocks[b].recompute_max();
        }
        // Remove the later point first: removing `e` can never disturb
        // the lookup of `s`.
        if entry.ins_hi {
            self.remove_point(entry.e);
        }
        if entry.ins_lo {
            self.remove_point(entry.s);
        }
    }

    /// Take an epoch mark capturing the current set of placements.
    pub fn checkpoint(&self) -> Mark {
        self.journal.len()
    }

    /// Restore the timeline to the state captured by `mark`, undoing
    /// every placement made since — bit-exact (see the module docs).
    /// Marks are LIFO: rolling back past a mark invalidates every mark
    /// taken after it.
    ///
    /// # Panics
    ///
    /// Panics if `mark` lies in the future (greater than the current
    /// placement count).
    pub fn rollback(&mut self, mark: Mark) {
        assert!(
            mark <= self.journal.len(),
            "rollback to future mark {mark} (placed: {})",
            self.journal.len()
        );
        while self.journal.len() > mark {
            self.unplace();
        }
    }

    /// Number of placements currently journaled (reservation seeds
    /// included).
    pub fn len(&self) -> usize {
        self.journal.len()
    }

    /// Whether nothing is placed.
    pub fn is_empty(&self) -> bool {
        self.journal.is_empty()
    }

    /// Earliest `s >= est` such that `(cpu, mem)` more fits throughout
    /// `[s, s+d)`; `None` when the demand alone exceeds the cluster
    /// capacity (no start can ever fit) **or** when any argument is
    /// non-finite (a NaN window used to fall through every sweep
    /// comparison and come back as `Some(NaN)` — the caller must surface
    /// the error instead of placing a corrupted rectangle).
    ///
    /// One forward sweep over the profile: start the candidate window at
    /// `est`; whenever a segment inside the window lacks free capacity,
    /// restart the window at that segment's end and keep scanning. A
    /// block whose `(max_cpu, max_mem)` aggregate leaves room for the
    /// demand cannot contain such a segment, so the sweep skips it in
    /// O(1) — the candidate `t` is provably unchanged across it, and if
    /// the block reaches past `t + d` the answer is `t` exactly as the
    /// segment-wise sweep would conclude. The result is always `est`
    /// itself or the exact end of a placed rectangle (the left-shift
    /// argument: any feasible start that is neither can be shifted left
    /// to one without losing feasibility), which is what keeps schedules
    /// bit-identical to both retained kernels.
    pub fn earliest_fit(&self, est: f64, d: f64, cpu: f64, mem: f64) -> Option<f64> {
        if !est.is_finite() || !d.is_finite() || !cpu.is_finite() || !mem.is_finite() {
            return None;
        }
        if cpu > self.cap_cpu + CAP_EPS || mem > self.cap_mem + CAP_EPS {
            return None;
        }
        let mut t = est;
        let nb = self.blocks.len();
        if nb == 0 {
            return Some(t);
        }
        // First segment whose interior can reach t: the one containing t
        // (last point <= t), or the very first segment when t precedes
        // every point.
        let (mut bi, mut si) = self.locate_seg(t).unwrap_or((0, 0));
        loop {
            let b = &self.blocks[bi];
            if si == 0
                && b.max_cpu + cpu <= self.cap_cpu + CAP_EPS
                && b.max_mem + mem <= self.cap_mem + CAP_EPS
            {
                // Aggregate skip: no segment in this block can violate
                // capacity (IEEE addition is monotone: seg <= max implies
                // seg + cpu <= max + cpu), so t survives the whole block.
                if b.last_point() >= t + d {
                    // Some point in the block ends the search exactly as
                    // the segment-wise sweep would: window [t, t+d) is
                    // clear.
                    return Some(t);
                }
                bi += 1;
                if bi >= nb {
                    return Some(t);
                }
                continue;
            }
            // Segment-wise sweep, mirroring the flat kernel bit for bit.
            if b.points[si] >= t + d {
                // Every remaining segment starts at or after the window
                // end: [t, t+d) is clear.
                return Some(t);
            }
            let last = bi + 1 >= nb && si + 1 >= b.points.len();
            let end = self.seg_end(bi, si);
            if end > t
                && (b.seg_cpu[si] + cpu > self.cap_cpu + CAP_EPS
                    || b.seg_mem[si] + mem > self.cap_mem + CAP_EPS)
            {
                // Window hits an over-full segment: restart just past it.
                // The final segment always has zero usage (it begins at
                // the latest placement end) and the demand fits capacity,
                // so a violation here is unreachable — guarded anyway.
                if last {
                    return None;
                }
                t = end;
            }
            si += 1;
            if si >= b.points.len() {
                bi += 1;
                si = 0;
                if bi >= nb {
                    return Some(t);
                }
            }
        }
    }

    /// Usage `(cpu, mem)` of the segment containing instant `t`.
    pub fn usage_at(&self, t: f64) -> (f64, f64) {
        match self.locate_seg(t) {
            Some((bi, si)) => (self.blocks[bi].seg_cpu[si], self.blocks[bi].seg_mem[si]),
            None => (0.0, 0.0),
        }
    }

    /// Maximum usage `(cpu, mem)` over any instant in `[t0, t1)` — the
    /// conservative per-bucket pre-load of the time-indexed MILP
    /// baseline. `(0, 0)` for an empty window, a window past every
    /// placement, or non-finite bounds (which used to walk the sweep
    /// with NaN comparisons). Blocks that lie entirely inside the window
    /// contribute their precomputed aggregate in O(1).
    pub fn max_usage_in(&self, t0: f64, t1: f64) -> (f64, f64) {
        if !t0.is_finite() || !t1.is_finite() || t1 <= t0 {
            return (0.0, 0.0);
        }
        let nb = self.blocks.len();
        if nb == 0 {
            return (0.0, 0.0);
        }
        let mut mc = 0.0f64;
        let mut mm = 0.0f64;
        let (mut bi, mut si) = self.locate_seg(t0).unwrap_or((0, 0));
        // The segment containing t0 needs its own end-check (its end can
        // coincide with t0 in the ±0.0 corner); every later segment ends
        // strictly past t0, so whole later blocks can use the aggregate.
        let mut first = true;
        loop {
            let b = &self.blocks[bi];
            if !first && si == 0 && b.last_point() < t1 {
                mc = mc.max(b.max_cpu);
                mm = mm.max(b.max_mem);
                bi += 1;
                if bi >= nb {
                    return (mc, mm);
                }
                continue;
            }
            if b.points[si] >= t1 {
                return (mc, mm);
            }
            if self.seg_end(bi, si) > t0 {
                mc = mc.max(b.seg_cpu[si]);
                mm = mm.max(b.seg_mem[si]);
            }
            first = false;
            si += 1;
            if si >= b.points.len() {
                bi += 1;
                si = 0;
                if bi >= nb {
                    return (mc, mm);
                }
            }
        }
    }

    /// Integrated usage `(cpu·time, mem·time)` over `[t0, t1)` — the
    /// occupied area the CP solver's capacity-envelope prune subtracts
    /// from the cluster's total area budget. `(0, 0)` for an empty
    /// window or non-finite bounds. Plain segment walk: the prune runs
    /// only on CP-sized problems (≤ 128 tasks), where the profile is a
    /// handful of blocks.
    pub fn area_in(&self, t0: f64, t1: f64) -> (f64, f64) {
        if !t0.is_finite() || !t1.is_finite() || t1 <= t0 {
            return (0.0, 0.0);
        }
        let nb = self.blocks.len();
        if nb == 0 {
            return (0.0, 0.0);
        }
        let mut ac = 0.0f64;
        let mut am = 0.0f64;
        let (mut bi, mut si) = self.locate_seg(t0).unwrap_or((0, 0));
        loop {
            let b = &self.blocks[bi];
            let p = b.points[si];
            if p >= t1 {
                return (ac, am);
            }
            let hi = self.seg_end(bi, si).min(t1);
            let lo = p.max(t0);
            if hi > lo {
                ac += b.seg_cpu[si] * (hi - lo);
                am += b.seg_mem[si] * (hi - lo);
            }
            si += 1;
            if si >= b.points.len() {
                bi += 1;
                si = 0;
                if bi >= nb {
                    return (ac, am);
                }
            }
        }
    }

    /// Every maximal constant-usage segment as `(start, end, cpu, mem)`,
    /// in time order; the final segment's end is `f64::INFINITY`. Used by
    /// `Schedule::validate`'s Eq.-4 sweep and by the property tests.
    pub fn segments(&self) -> impl Iterator<Item = (f64, f64, f64, f64)> + '_ {
        let total: usize = self.blocks.iter().map(|b| b.points.len()).sum();
        let mut bi = 0usize;
        let mut si = 0usize;
        (0..total).map(move |_| {
            let b = &self.blocks[bi];
            let start = b.points[si];
            let cpu = b.seg_cpu[si];
            let mem = b.seg_mem[si];
            let end = self.seg_end(bi, si);
            si += 1;
            if si >= b.points.len() {
                bi += 1;
                si = 0;
            }
            (start, end, cpu, mem)
        })
    }

    /// Structural invariants, asserted by the property tests after every
    /// fuzz op: non-empty blocks within capacity, globally sorted
    /// strictly-increasing points, exact aggregates, zero-usage final
    /// segment.
    #[cfg(test)]
    fn assert_invariants(&self) {
        let mut prev: Option<f64> = None;
        for b in &self.blocks {
            assert!(!b.points.is_empty(), "empty block survived");
            assert!(b.points.len() <= BLOCK_CAP, "block over capacity");
            assert_eq!(b.points.len(), b.seg_cpu.len());
            assert_eq!(b.points.len(), b.seg_mem.len());
            let mut mc = 0.0f64;
            let mut mm = 0.0f64;
            for (i, &p) in b.points.iter().enumerate() {
                if let Some(q) = prev {
                    assert!(
                        q.total_cmp(&p).is_lt(),
                        "points not strictly increasing: {q} then {p}"
                    );
                }
                prev = Some(p);
                mc = mc.max(b.seg_cpu[i]);
                mm = mm.max(b.seg_mem[i]);
            }
            assert_eq!(mc.to_bits(), b.max_cpu.to_bits(), "stale cpu aggregate");
            assert_eq!(mm.to_bits(), b.max_mem.to_bits(), "stale mem aggregate");
        }
        if let Some(b) = self.blocks.last() {
            assert_eq!(*b.seg_cpu.last().unwrap(), 0.0, "final segment not idle");
            assert_eq!(*b.seg_mem.last().unwrap(), 0.0, "final segment not idle");
        }
    }
}

pub mod flat {
    //! The PR 4 sweep-line kernel, retained as an executable reference:
    //! one flat sorted `Vec` of change-points with absolute per-segment
    //! usage. Queries are O(log n + k), but every newly inserted
    //! change-point pays an O(n) contiguous memmove, so a full
    //! n-placement SGS pass is O(n²) — which is exactly why it was
    //! superseded by the block-indexed [`Timeline`](super::Timeline).
    //! Unlike the O(n³) rectangle list in [`reference`](super::reference)
    //! (capped at `REF_MAX_TASKS` in the scaling bench), this kernel
    //! scales far enough to cross-check bit-identical schedules at every
    //! measured size up to 10⁵ tasks. It carries the same non-finite
    //! guards as the production kernel so the two stay answer-identical
    //! on every input. Never use this from production paths.

    use crate::solver::rcpsp::{Problem, Reservation};
    use crate::solver::schedule::Schedule;
    use crate::solver::sgs::selection_order;

    use super::{Mark, CAP_EPS};

    /// One journaled placement of the flat kernel (physical segment
    /// indices are stable here — no blocks shift underneath them).
    #[derive(Debug, Clone, Copy)]
    struct FlatJournalEntry {
        lo: usize,
        hi: usize,
        ins_lo: bool,
        ins_hi: bool,
        saved_off: usize,
    }

    /// The flat capacity profile: sorted change-points with the absolute
    /// (cpu, mem) usage of the constant segment starting at each point.
    #[derive(Debug, Clone)]
    pub struct FlatTimeline {
        cap_cpu: f64,
        cap_mem: f64,
        points: Vec<f64>,
        seg_cpu: Vec<f64>,
        seg_mem: Vec<f64>,
        journal: Vec<FlatJournalEntry>,
        saved: Vec<(f64, f64)>,
    }

    impl FlatTimeline {
        /// Empty timeline with the given capacity.
        pub fn new(cap_cpu: f64, cap_mem: f64) -> Self {
            FlatTimeline {
                cap_cpu,
                cap_mem,
                points: Vec::new(),
                seg_cpu: Vec::new(),
                seg_mem: Vec::new(),
                journal: Vec::new(),
                saved: Vec::new(),
            }
        }

        /// Timeline pre-seeded with occupancy reservations, mirroring
        /// [`Timeline::seeded`](super::Timeline::seeded).
        pub fn seeded(cap_cpu: f64, cap_mem: f64, reservations: &[Reservation]) -> Self {
            let mut tl = FlatTimeline::new(cap_cpu, cap_mem);
            for &(s, d, cpu, mem) in reservations {
                tl.place(s, d, cpu, mem);
            }
            tl
        }

        fn ensure_point(&mut self, t: f64) -> (usize, bool) {
            match self.points.binary_search_by(|p| p.total_cmp(&t)) {
                Ok(i) => (i, false),
                Err(i) => {
                    let (c, m) = if i == 0 {
                        (0.0, 0.0)
                    } else {
                        (self.seg_cpu[i - 1], self.seg_mem[i - 1])
                    };
                    self.points.insert(i, t);
                    self.seg_cpu.insert(i, c);
                    self.seg_mem.insert(i, m);
                    (i, true)
                }
            }
        }

        /// Reserve a (cpu, mem) rectangle over `[s, s+d)`; non-positive
        /// and NaN windows are journaled no-ops.
        pub fn place(&mut self, s: f64, d: f64, cpu: f64, mem: f64) {
            let e = s + d;
            if e.partial_cmp(&s) != Some(std::cmp::Ordering::Greater) {
                self.journal.push(FlatJournalEntry {
                    lo: 0,
                    hi: 0,
                    ins_lo: false,
                    ins_hi: false,
                    saved_off: self.saved.len(),
                });
                return;
            }
            let (lo, ins_lo) = self.ensure_point(s);
            let (hi, ins_hi) = self.ensure_point(e);
            let saved_off = self.saved.len();
            for i in lo..hi {
                self.saved.push((self.seg_cpu[i], self.seg_mem[i]));
                self.seg_cpu[i] += cpu;
                self.seg_mem[i] += mem;
            }
            self.journal.push(FlatJournalEntry {
                lo,
                hi,
                ins_lo,
                ins_hi,
                saved_off,
            });
        }

        fn unplace(&mut self) {
            let e = self
                .journal
                .pop()
                .expect("rollback below the empty timeline");
            for (k, i) in (e.lo..e.hi).enumerate() {
                let (c, m) = self.saved[e.saved_off + k];
                self.seg_cpu[i] = c;
                self.seg_mem[i] = m;
            }
            self.saved.truncate(e.saved_off);
            if e.ins_hi {
                self.points.remove(e.hi);
                self.seg_cpu.remove(e.hi);
                self.seg_mem.remove(e.hi);
            }
            if e.ins_lo {
                self.points.remove(e.lo);
                self.seg_cpu.remove(e.lo);
                self.seg_mem.remove(e.lo);
            }
        }

        /// Take an epoch mark capturing the current set of placements.
        pub fn checkpoint(&self) -> Mark {
            self.journal.len()
        }

        /// Restore the timeline to the state captured by `mark` —
        /// bit-exact, same LIFO contract as the production kernel.
        pub fn rollback(&mut self, mark: Mark) {
            assert!(
                mark <= self.journal.len(),
                "rollback to future mark {mark} (placed: {})",
                self.journal.len()
            );
            while self.journal.len() > mark {
                self.unplace();
            }
        }

        /// Number of placements currently journaled.
        pub fn len(&self) -> usize {
            self.journal.len()
        }

        /// Whether nothing is placed.
        pub fn is_empty(&self) -> bool {
            self.journal.is_empty()
        }

        /// Earliest fit, mirroring
        /// [`Timeline::earliest_fit`](super::Timeline::earliest_fit)
        /// including its `None`-on-non-finite guard.
        pub fn earliest_fit(&self, est: f64, d: f64, cpu: f64, mem: f64) -> Option<f64> {
            if !est.is_finite() || !d.is_finite() || !cpu.is_finite() || !mem.is_finite() {
                return None;
            }
            if cpu > self.cap_cpu + CAP_EPS || mem > self.cap_mem + CAP_EPS {
                return None;
            }
            let n = self.points.len();
            let mut t = est;
            let first_after = self.points.partition_point(|p| p.total_cmp(&t).is_le());
            let mut idx = first_after.saturating_sub(1);
            while idx < n {
                if self.points[idx] >= t + d {
                    return Some(t);
                }
                let end = if idx + 1 < n {
                    self.points[idx + 1]
                } else {
                    f64::INFINITY
                };
                if end > t
                    && (self.seg_cpu[idx] + cpu > self.cap_cpu + CAP_EPS
                        || self.seg_mem[idx] + mem > self.cap_mem + CAP_EPS)
                {
                    if idx + 1 >= n {
                        return None;
                    }
                    t = end;
                }
                idx += 1;
            }
            Some(t)
        }

        /// Usage `(cpu, mem)` of the segment containing instant `t`.
        pub fn usage_at(&self, t: f64) -> (f64, f64) {
            let j = self.points.partition_point(|p| p.total_cmp(&t).is_le());
            if j == 0 {
                (0.0, 0.0)
            } else {
                (self.seg_cpu[j - 1], self.seg_mem[j - 1])
            }
        }

        /// Maximum usage over `[t0, t1)`, `(0, 0)` on empty or
        /// non-finite windows — mirroring the production kernel.
        pub fn max_usage_in(&self, t0: f64, t1: f64) -> (f64, f64) {
            let mut mc = 0.0f64;
            let mut mm = 0.0f64;
            if !t0.is_finite() || !t1.is_finite() || t1 <= t0 {
                return (mc, mm);
            }
            let first_after = self.points.partition_point(|p| p.total_cmp(&t0).is_le());
            for i in first_after.saturating_sub(1)..self.points.len() {
                if self.points[i] >= t1 {
                    break;
                }
                let end = if i + 1 < self.points.len() {
                    self.points[i + 1]
                } else {
                    f64::INFINITY
                };
                if end > t0 {
                    mc = mc.max(self.seg_cpu[i]);
                    mm = mm.max(self.seg_mem[i]);
                }
            }
            (mc, mm)
        }

        /// Every maximal constant-usage segment, in time order; the
        /// final segment's end is `f64::INFINITY`.
        pub fn segments(&self) -> impl Iterator<Item = (f64, f64, f64, f64)> + '_ {
            let n = self.points.len();
            (0..n).map(move |i| {
                let end = if i + 1 < n {
                    self.points[i + 1]
                } else {
                    f64::INFINITY
                };
                (self.points[i], end, self.seg_cpu[i], self.seg_mem[i])
            })
        }
    }

    /// The production serial SGS, verbatim, over [`FlatTimeline`] —
    /// same occupancy seeding, same `selection_order`, so any schedule
    /// difference against `sgs::serial_sgs` isolates a timeline-kernel
    /// divergence. The assignment must draw from `Problem::feasible`.
    pub fn serial_sgs_flat(p: &Problem, assignment: &[usize], prio: &[f64]) -> Schedule {
        let n = p.len();
        let order = selection_order(p, prio);
        let mut start = vec![0.0f64; n];
        let mut timeline = FlatTimeline::new(p.capacity.vcpus, p.capacity.memory_gb);
        for &(s, d, cpu, mem) in &p.preplaced {
            timeline.place(s, d, cpu, mem);
        }
        for &t in &order {
            let est = p
                .preds(t)
                .iter()
                .map(|&q| start[q] + p.duration(q, assignment[q]))
                .fold(p.release[t], f64::max);
            let d = p.duration(t, assignment[t]);
            let (cpu, mem) = p.demand(assignment[t]);
            let s = timeline
                .earliest_fit(est, d, cpu, mem)
                .expect("assignments must draw from Problem::feasible");
            timeline.place(s, d, cpu, mem);
            start[t] = s;
        }
        Schedule {
            assignment: assignment.to_vec(),
            start,
            optimal: false,
        }
    }
}

pub mod reference {
    //! The historical rectangle-list kernel, retained **verbatim** as the
    //! executable specification of [`Timeline`](super::Timeline): a flat
    //! list of placed rectangles, O(n²) feasibility queries, O(n³)
    //! placement scans. Property tests (`timeline`, `sgs`, `invariants`)
    //! and the `scaling_timeline` bench run it side by side with the
    //! production kernel to pin bit-identical schedules and measure the
    //! speedup — capped at `REF_MAX_TASKS` there, where the cheaper
    //! [`flat`](super::flat) reference takes over. Never use this from
    //! production paths.

    use crate::solver::rcpsp::Problem;
    use crate::solver::schedule::Schedule;
    use crate::solver::sgs::selection_order;

    /// Flat rectangle-list timeline (the historical implementation).
    pub struct RefTimeline {
        /// (start, end, cpu, mem) of each placed task.
        placed: Vec<(f64, f64, f64, f64)>,
        cap_cpu: f64,
        cap_mem: f64,
    }

    impl RefTimeline {
        /// Empty timeline with the given capacity.
        pub fn new(cap_cpu: f64, cap_mem: f64) -> Self {
            RefTimeline {
                placed: Vec::new(),
                cap_cpu,
                cap_mem,
            }
        }

        /// Can a (cpu, mem) demand run throughout [s, s+d)?
        fn fits(&self, s: f64, d: f64, cpu: f64, mem: f64) -> bool {
            // Capacity must hold at every event point in the window;
            // events are the window start and starts of overlapping
            // placed tasks.
            let e = s + d;
            let mut points = vec![s];
            for &(ps, pe, _, _) in &self.placed {
                if ps > s && ps < e && pe > s {
                    points.push(ps);
                }
            }
            for &point in &points {
                let mut used_cpu = cpu;
                let mut used_mem = mem;
                for &(ps, pe, pc, pm) in &self.placed {
                    if ps <= point + 1e-9 && point + 1e-9 < pe {
                        used_cpu += pc;
                        used_mem += pm;
                    }
                }
                if used_cpu > self.cap_cpu + 1e-6 || used_mem > self.cap_mem + 1e-6 {
                    return false;
                }
            }
            true
        }

        /// Earliest s >= est such that the demand fits throughout
        /// [s, s+d). Keeps the historical fallback: for a demand that
        /// exceeds cluster capacity alone, the returned start is
        /// meaningless (the production kernel returns `None` there).
        pub fn earliest_fit(&self, est: f64, d: f64, cpu: f64, mem: f64) -> f64 {
            if self.fits(est, d, cpu, mem) {
                return est;
            }
            // Candidate starts: ends of placed tasks after est, sorted.
            let mut candidates: Vec<f64> = self
                .placed
                .iter()
                .map(|&(_, e, _, _)| e)
                .filter(|&e| e > est)
                .collect();
            candidates.sort_by(|a, b| a.total_cmp(b));
            for s in candidates {
                if self.fits(s, d, cpu, mem) {
                    return s;
                }
            }
            // Fallback: after everything ends (always feasible for a
            // demand that fits capacity alone).
            self.placed
                .iter()
                .map(|&(_, e, _, _)| e)
                .fold(est, f64::max)
        }

        /// Reserve a (cpu, mem) rectangle over [s, s+d).
        pub fn place(&mut self, s: f64, d: f64, cpu: f64, mem: f64) {
            self.placed.push((s, s + d, cpu, mem));
        }

        /// Remove the most recently placed rectangle.
        pub fn pop(&mut self) {
            self.placed.pop();
        }

        /// Keep only the first `len` placements.
        pub fn truncate(&mut self, len: usize) {
            self.placed.truncate(len);
        }

        /// Number of placed rectangles.
        pub fn len(&self) -> usize {
            self.placed.len()
        }

        /// Whether nothing is placed.
        pub fn is_empty(&self) -> bool {
            self.placed.is_empty()
        }

        /// Exact usage at instant `t` under the historical membership
        /// test (`ps <= t + 1e-9 < pe`).
        pub fn usage_at(&self, t: f64) -> (f64, f64) {
            let mut cpu = 0.0;
            let mut mem = 0.0;
            for &(ps, pe, pc, pm) in &self.placed {
                if ps <= t + 1e-9 && t + 1e-9 < pe {
                    cpu += pc;
                    mem += pm;
                }
            }
            (cpu, mem)
        }
    }

    /// The historical serial SGS, verbatim, over [`RefTimeline`] —
    /// seeded with the problem's occupancy reservations like the
    /// production `sgs::serial_sgs`. The assignment must draw from
    /// `Problem::feasible` (the historical kernel has no infeasibility
    /// reporting).
    pub fn serial_sgs_ref(p: &Problem, assignment: &[usize], prio: &[f64]) -> Schedule {
        let n = p.len();
        let order = selection_order(p, prio);
        let mut start = vec![0.0f64; n];
        let mut timeline = RefTimeline::new(p.capacity.vcpus, p.capacity.memory_gb);
        for &(s, d, cpu, mem) in &p.preplaced {
            timeline.place(s, d, cpu, mem);
        }
        for &t in &order {
            let est = p
                .preds(t)
                .iter()
                .map(|&q| start[q] + p.duration(q, assignment[q]))
                .fold(p.release[t], f64::max);
            let d = p.duration(t, assignment[t]);
            let (cpu, mem) = p.demand(assignment[t]);
            let s = timeline.earliest_fit(est, d, cpu, mem);
            timeline.place(s, d, cpu, mem);
            start[t] = s;
        }
        Schedule {
            assignment: assignment.to_vec(),
            start,
            optimal: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::flat::FlatTimeline;
    use super::reference::RefTimeline;
    use super::*;
    use crate::util::{propcheck, Rng};

    #[test]
    fn earliest_fit_respects_capacity() {
        let mut tl = Timeline::new(10.0, 100.0);
        tl.place(0.0, 10.0, 8.0, 50.0);
        // demand 4 cpus cannot run concurrently with the 8-cpu task
        assert_eq!(tl.earliest_fit(0.0, 5.0, 4.0, 10.0), Some(10.0));
        // but 2 cpus can
        assert_eq!(tl.earliest_fit(0.0, 5.0, 2.0, 10.0), Some(0.0));
    }

    #[test]
    fn finds_gap_between_tasks() {
        let mut tl = Timeline::new(10.0, 100.0);
        tl.place(0.0, 5.0, 10.0, 10.0);
        tl.place(8.0, 5.0, 10.0, 10.0);
        // a 3-second task fits exactly in the [5, 8) gap
        assert_eq!(tl.earliest_fit(0.0, 3.0, 10.0, 10.0), Some(5.0));
        // a 4-second task does not; next fit is after the second task
        assert_eq!(tl.earliest_fit(0.0, 4.0, 10.0, 10.0), Some(13.0));
    }

    #[test]
    fn memory_constrains_like_cpu() {
        let mut tl = Timeline::new(100.0, 10.0);
        tl.place(0.0, 10.0, 1.0, 8.0);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 1.0, 4.0), Some(10.0));
        assert_eq!(tl.earliest_fit(0.0, 5.0, 1.0, 2.0), Some(0.0));
    }

    #[test]
    fn over_capacity_demand_is_rejected_not_placed() {
        let tl = Timeline::new(10.0, 100.0);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 10.5, 10.0), None);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 5.0, 200.0), None);
        // Exactly at capacity (within the historical 1e-6 slack) fits.
        assert_eq!(tl.earliest_fit(0.0, 5.0, 10.0, 100.0), Some(0.0));
    }

    #[test]
    fn non_finite_windows_are_rejected_not_nan() {
        // The satellite bugfix: a NaN window used to sail through every
        // sweep comparison and come back as Some(NaN); `place` then
        // journaled the NaN rectangle as a silent no-op. All three
        // non-finite classes must be refused outright, on both profile
        // kernels.
        let mut tl = Timeline::new(10.0, 100.0);
        let mut fl = FlatTimeline::new(10.0, 100.0);
        tl.place(0.0, 10.0, 4.0, 10.0);
        fl.place(0.0, 10.0, 4.0, 10.0);
        for (est, d, cpu, mem) in [
            (f64::NAN, 5.0, 1.0, 1.0),
            (0.0, f64::NAN, 1.0, 1.0),
            (0.0, 5.0, f64::NAN, 1.0),
            (0.0, 5.0, 1.0, f64::NAN),
            (f64::INFINITY, 5.0, 1.0, 1.0),
            (f64::NEG_INFINITY, 5.0, 1.0, 1.0),
            (0.0, f64::INFINITY, 1.0, 1.0),
            (0.0, 5.0, f64::INFINITY, 1.0),
            (0.0, 5.0, 1.0, f64::NEG_INFINITY),
        ] {
            assert_eq!(
                tl.earliest_fit(est, d, cpu, mem),
                None,
                "indexed kernel accepted non-finite window ({est}, {d}, {cpu}, {mem})"
            );
            assert_eq!(
                fl.earliest_fit(est, d, cpu, mem),
                None,
                "flat kernel accepted non-finite window ({est}, {d}, {cpu}, {mem})"
            );
        }
        // max_usage_in: explicitly (0, 0) on non-finite bounds.
        for (t0, t1) in [
            (f64::NAN, 5.0),
            (0.0, f64::NAN),
            (f64::NEG_INFINITY, f64::INFINITY),
            (0.0, f64::INFINITY),
        ] {
            assert_eq!(tl.max_usage_in(t0, t1), (0.0, 0.0));
            assert_eq!(fl.max_usage_in(t0, t1), (0.0, 0.0));
            assert_eq!(tl.area_in(t0, t1), (0.0, 0.0));
        }
        // A NaN place stays a journaled no-op and unwinds cleanly.
        let mark = tl.checkpoint();
        tl.place(f64::NAN, 5.0, 3.0, 3.0);
        tl.place(1.0, f64::NAN, 3.0, 3.0);
        assert_eq!(tl.len(), mark + 2);
        assert_eq!(tl.usage_at(1.0), (4.0, 10.0));
        tl.rollback(mark);
        assert_eq!(tl.usage_at(1.0), (4.0, 10.0));
        tl.assert_invariants();
    }

    #[test]
    fn checkpoint_rollback_restores_exactly() {
        let mut tl = Timeline::new(10.0, 100.0);
        tl.place(0.0, 10.0, 4.0, 10.0);
        let before: Vec<_> = tl.segments().collect();
        let mark = tl.checkpoint();
        tl.place(2.0, 5.0, 6.0, 20.0);
        tl.place(7.0, 9.0, 3.0, 5.0);
        assert_eq!(tl.len(), 3);
        tl.rollback(mark);
        assert_eq!(tl.len(), 1);
        let after: Vec<_> = tl.segments().collect();
        assert_eq!(before.len(), after.len());
        for (b, a) in before.iter().zip(after.iter()) {
            assert_eq!(b.0.to_bits(), a.0.to_bits());
            assert_eq!(b.2.to_bits(), a.2.to_bits());
            assert_eq!(b.3.to_bits(), a.3.to_bits());
        }
        tl.assert_invariants();
    }

    #[test]
    fn nested_marks_unwind_in_lifo_order() {
        let mut tl = Timeline::new(16.0, 64.0);
        let m0 = tl.checkpoint();
        tl.place(0.0, 4.0, 8.0, 16.0);
        let m1 = tl.checkpoint();
        tl.place(1.0, 4.0, 8.0, 16.0);
        // [1, 4) is saturated: the earliest 2-wide window for another
        // 8-cpu task opens when the second placement ends at t = 4.
        assert_eq!(tl.earliest_fit(0.0, 2.0, 8.0, 1.0), Some(4.0));
        tl.rollback(m1);
        assert_eq!(tl.earliest_fit(0.0, 2.0, 8.0, 1.0), Some(0.0));
        tl.rollback(m0);
        assert!(tl.is_empty());
        assert_eq!(tl.segments().count(), 0);
        assert_eq!(tl.block_count(), 0);
    }

    #[test]
    fn zero_duration_placements_are_journaled_noops() {
        let mut tl = Timeline::new(8.0, 8.0);
        let mark = tl.checkpoint();
        tl.place(3.0, 0.0, 8.0, 8.0);
        assert_eq!(tl.len(), 1);
        assert_eq!(tl.usage_at(3.0), (0.0, 0.0));
        tl.rollback(mark);
        assert!(tl.is_empty());
    }

    #[test]
    #[should_panic(expected = "future mark")]
    fn rollback_to_future_mark_panics() {
        let mut tl = Timeline::new(1.0, 1.0);
        tl.rollback(3);
    }

    #[test]
    fn zero_duration_fit_matches_reference_semantics() {
        // A zero-length window occupies nothing, but both kernels treat
        // it as a point probe: inside a saturated segment it defers to
        // the segment end, in free space it returns est. Pinned here so
        // the edge cannot drift silently between the kernels.
        let mut tl = Timeline::new(10.0, 100.0);
        let mut rf = RefTimeline::new(10.0, 100.0);
        tl.place(5.0, 10.0, 8.0, 10.0);
        rf.place(5.0, 10.0, 8.0, 10.0);
        for (est, cpu) in [(0.0, 4.0), (7.0, 4.0), (7.0, 1.0), (20.0, 9.0)] {
            let got = tl.earliest_fit(est, 0.0, cpu, 1.0);
            let want = rf.earliest_fit(est, 0.0, cpu, 1.0);
            assert_eq!(
                got.map(f64::to_bits),
                Some(want.to_bits()),
                "zero-duration fit at est {est} cpu {cpu}: {got:?} vs ref {want}"
            );
        }
        // In particular: a point probe in free space is est itself...
        assert_eq!(tl.earliest_fit(0.0, 0.0, 4.0, 1.0), Some(0.0));
        // ...and inside the saturated window it defers to the boundary.
        assert_eq!(tl.earliest_fit(7.0, 0.0, 4.0, 1.0), Some(15.0));
    }

    #[test]
    fn demand_exactly_at_residual_capacity_fits_at_est() {
        // Eq. 4 is an inclusive bound (<= R_m within the 1e-6 slack):
        // a demand that tops usage to exactly capacity must start at
        // est, one that exceeds the residual by more than the slack
        // must wait for the release.
        let mut tl = Timeline::new(16.0, 64.0);
        tl.place(0.0, 10.0, 10.0, 40.0);
        // Exactly the residual (16 - 10 cpu, 64 - 40 mem).
        assert_eq!(tl.earliest_fit(0.0, 5.0, 6.0, 24.0), Some(0.0));
        // Within the historical 1e-6 capacity slack: still fits.
        assert_eq!(tl.earliest_fit(0.0, 5.0, 6.0 + 5e-7, 24.0), Some(0.0));
        // Past the slack on either resource: deferred to the release.
        assert_eq!(tl.earliest_fit(0.0, 5.0, 6.0 + 2e-6, 24.0), Some(10.0));
        assert_eq!(tl.earliest_fit(0.0, 5.0, 6.0, 24.0 + 2e-6), Some(10.0));
        // Demand exactly at full cluster capacity on an empty stretch.
        assert_eq!(tl.earliest_fit(10.0, 5.0, 16.0, 64.0), Some(10.0));
    }

    #[test]
    fn earliest_fit_none_is_stable_across_checkpoint_rollback() {
        // `None` means the demand alone exceeds the cluster — no
        // place/checkpoint/rollback interleaving may change that verdict,
        // and in-capacity answers must come back bit-identical after a
        // rollback round-trip.
        let mut tl = Timeline::new(8.0, 32.0);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 8.5, 1.0), None);
        let before = tl.earliest_fit(0.0, 5.0, 4.0, 16.0);

        let m0 = tl.checkpoint();
        tl.place(0.0, 20.0, 8.0, 32.0);
        let m1 = tl.checkpoint();
        tl.place(20.0, 20.0, 8.0, 32.0);
        // Over-capacity demand: still None with the cluster fully packed.
        assert_eq!(tl.earliest_fit(0.0, 5.0, 8.5, 1.0), None);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 1.0, 32.5), None);
        // In-capacity demand: deferred past the packed prefix.
        assert_eq!(tl.earliest_fit(0.0, 5.0, 4.0, 16.0), Some(40.0));

        tl.rollback(m1);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 8.5, 1.0), None);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 4.0, 16.0), Some(20.0));
        tl.rollback(m0);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 8.5, 1.0), None);
        let after = tl.earliest_fit(0.0, 5.0, 4.0, 16.0);
        assert_eq!(
            before.map(f64::to_bits),
            after.map(f64::to_bits),
            "rollback round-trip changed an in-capacity answer"
        );
    }

    /// Drive the indexed, flat, and rectangle-list kernels through an
    /// identical random op sequence — the three-way differential of the
    /// satellite task — cross-checking occupancy (against a brute-force
    /// per-event-point recomputation) and every `earliest_fit` answer,
    /// with reservations, floored queries, zero-duration placements,
    /// demands at capacity within the 1e-6 slack, and
    /// checkpoint/rollback interleavings.
    #[test]
    fn property_fuzz_against_reference_and_brute_force() {
        propcheck::check(40, |rng| {
            let cap_cpu = rng.uniform(8.0, 64.0);
            let cap_mem = rng.uniform(32.0, 256.0);
            // Random occupancy seed (possibly negative starts, like a
            // ledger snapshot shifted into round-local time).
            let n_res = rng.below(4);
            let reservations: Vec<Reservation> = (0..n_res)
                .map(|_| {
                    (
                        rng.uniform(-50.0, 100.0),
                        rng.uniform(1.0, 80.0),
                        cap_cpu * rng.uniform(0.1, 0.9),
                        cap_mem * rng.uniform(0.1, 0.9),
                    )
                })
                .collect();
            let mut tl = Timeline::seeded(cap_cpu, cap_mem, &reservations);
            let mut fl = FlatTimeline::seeded(cap_cpu, cap_mem, &reservations);
            let mut rf = RefTimeline::new(cap_cpu, cap_mem);
            for &(s, d, cpu, mem) in &reservations {
                rf.place(s, d, cpu, mem);
            }
            // Rectangles mirrored into all kernels, for brute-force
            // usage recomputation and LIFO undo.
            let mut rects: Vec<Reservation> = reservations.clone();
            let mut marks: Vec<(Mark, usize)> = Vec::new();

            for step in 0..60 {
                match rng.below(12) {
                    // place (occasionally zero-duration)
                    0..=4 => {
                        let s = rng.uniform(0.0, 200.0);
                        let d = if rng.chance(0.1) {
                            0.0
                        } else {
                            rng.uniform(0.5, 60.0)
                        };
                        let cpu = cap_cpu * rng.uniform(0.05, 0.8);
                        let mem = cap_mem * rng.uniform(0.05, 0.8);
                        tl.place(s, d, cpu, mem);
                        fl.place(s, d, cpu, mem);
                        rf.place(s, d, cpu, mem);
                        rects.push((s, d, cpu, mem));
                    }
                    // checkpoint
                    5 => marks.push((tl.checkpoint(), rects.len())),
                    // rollback to the most recent mark
                    6 => {
                        if let Some((mark, kept)) = marks.pop() {
                            tl.rollback(mark);
                            fl.rollback(mark);
                            rf.truncate(mark);
                            rects.truncate(kept);
                        }
                    }
                    // demand at the residual-capacity boundary, within
                    // the 1e-6 slack — all three kernels must agree on
                    // whether it fits at est
                    7 => {
                        let t = rng.uniform(0.0, 200.0);
                        let (uc, um) = tl.usage_at(t);
                        let cpu = (cap_cpu - uc + rng.uniform(-1e-7, 5e-7)).max(0.0);
                        let mem = (cap_mem - um).max(0.0) * rng.uniform(0.1, 0.9);
                        let got = tl.earliest_fit(t, 0.5, cpu, mem);
                        let flat = fl.earliest_fit(t, 0.5, cpu, mem);
                        if got.map(f64::to_bits) != flat.map(f64::to_bits) {
                            return Err(format!(
                                "step {step}: slack-boundary fit {got:?} != flat {flat:?}"
                            ));
                        }
                        let want = rf.earliest_fit(t, 0.5, cpu, mem);
                        if got.map(f64::to_bits) != Some(want.to_bits()) {
                            return Err(format!(
                                "step {step}: slack-boundary fit {got:?} != ref {want}"
                            ));
                        }
                    }
                    // earliest_fit cross-check (random admission floor)
                    _ => {
                        let est = rng.uniform(-10.0, 250.0);
                        let d = rng.uniform(0.5, 40.0);
                        let cpu = cap_cpu * rng.uniform(0.05, 0.95);
                        let mem = cap_mem * rng.uniform(0.05, 0.95);
                        let got = tl.earliest_fit(est, d, cpu, mem);
                        let flat = fl.earliest_fit(est, d, cpu, mem);
                        if got.map(f64::to_bits) != flat.map(f64::to_bits) {
                            return Err(format!(
                                "step {step}: earliest_fit {got:?} != flat {flat:?}"
                            ));
                        }
                        let want = rf.earliest_fit(est, d, cpu, mem);
                        match got {
                            None => {
                                return Err(format!(
                                    "step {step}: fit None for in-capacity demand"
                                ))
                            }
                            Some(got) => {
                                if got.to_bits() != want.to_bits() {
                                    return Err(format!(
                                        "step {step}: earliest_fit {got} != ref {want}"
                                    ));
                                }
                            }
                        }
                    }
                }
                tl.assert_invariants();

                // The two profile kernels must agree segment-for-segment,
                // bit for bit, after every op.
                let a: Vec<_> = tl.segments().collect();
                let b: Vec<_> = fl.segments().collect();
                if a.len() != b.len() {
                    return Err(format!(
                        "step {step}: segment counts diverge: {} vs flat {}",
                        a.len(),
                        b.len()
                    ));
                }
                for (x, y) in a.iter().zip(b.iter()) {
                    if x.0.to_bits() != y.0.to_bits()
                        || x.2.to_bits() != y.2.to_bits()
                        || x.3.to_bits() != y.3.to_bits()
                    {
                        return Err(format!("step {step}: segments diverge: {x:?} vs {y:?}"));
                    }
                }

                // Brute-force occupancy cross-check at every event point
                // (and just before/after, to catch off-by-one-segment
                // errors), against a from-scratch recomputation.
                let mut probes: Vec<f64> = Vec::new();
                for &(s, d, _, _) in &rects {
                    probes.push(s);
                    probes.push(s + d);
                    probes.push(s + d * 0.5);
                }
                probes.push(-1e3);
                probes.push(1e4);
                for &t in &probes {
                    let (c, m) = tl.usage_at(t);
                    let mut bc = 0.0;
                    let mut bm = 0.0;
                    for &(s, d, cpu, mem) in &rects {
                        // Exact half-open membership, matching the
                        // profile's [start, end) segments.
                        if s <= t && t < s + d {
                            bc += cpu;
                            bm += mem;
                        }
                    }
                    if (c - bc).abs() > 1e-9 * (1.0 + bc.abs())
                        || (m - bm).abs() > 1e-9 * (1.0 + bm.abs())
                    {
                        return Err(format!(
                            "step {step}: usage at {t} = ({c}, {m}), brute force ({bc}, {bm})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// After an arbitrary place/rollback history, the profile must be
    /// byte-identical to one freshly built from the surviving rectangles
    /// — the no-rounding-drift guarantee of the undo journal, now also
    /// covering block splits and the aggregate recomputation on undo.
    #[test]
    fn property_rollback_leaves_no_float_drift() {
        propcheck::check(30, |rng| {
            let cap = 32.0;
            let mut tl = Timeline::new(cap, cap * 4.0);
            let mut rects: Vec<Reservation> = Vec::new();
            for _ in 0..40 {
                if rng.chance(0.35) && !tl.is_empty() {
                    // rollback a random suffix
                    let keep = rng.below(tl.len() + 1);
                    tl.rollback(keep);
                    rects.truncate(keep);
                } else {
                    let r = (
                        rng.uniform(0.0, 100.0),
                        rng.uniform(0.1, 30.0),
                        // adversarial fractional demands (0.1 + 0.3-style
                        // sums that do not round-trip under subtraction)
                        rng.uniform(0.1, 0.7),
                        rng.uniform(0.1, 0.7),
                    );
                    tl.place(r.0, r.1, r.2, r.3);
                    rects.push(r);
                }
                tl.assert_invariants();
            }
            let fresh = Timeline::seeded(tl.cap_cpu(), tl.cap_mem(), &rects);
            let a: Vec<_> = tl.segments().collect();
            let b: Vec<_> = fresh.segments().collect();
            if a.len() != b.len() {
                return Err(format!("segment counts differ: {} vs {}", a.len(), b.len()));
            }
            for (x, y) in a.iter().zip(b.iter()) {
                if x.0.to_bits() != y.0.to_bits()
                    || x.2.to_bits() != y.2.to_bits()
                    || x.3.to_bits() != y.3.to_bits()
                {
                    return Err(format!("segments diverge: {x:?} vs {y:?}"));
                }
            }
            Ok(())
        });
    }

    /// Push the profile far past `BLOCK_CAP` so splits actually happen,
    /// then cross-check fits, window maxima, and a deep rollback against
    /// the flat kernel — the regime the unit tests above never reach.
    #[test]
    fn block_splits_preserve_flat_equivalence_at_scale() {
        let cap_cpu = 64.0;
        let cap_mem = 256.0;
        let mut rng = Rng::new(0xB10C);
        let mut tl = Timeline::new(cap_cpu, cap_mem);
        let mut fl = FlatTimeline::new(cap_cpu, cap_mem);
        let mark = (tl.checkpoint(), fl.checkpoint());
        for i in 0..2000 {
            let s = rng.uniform(0.0, 5000.0);
            let d = rng.uniform(0.5, 20.0);
            let cpu = cap_cpu * rng.uniform(0.02, 0.3);
            let mem = cap_mem * rng.uniform(0.02, 0.3);
            tl.place(s, d, cpu, mem);
            fl.place(s, d, cpu, mem);
            if i % 251 == 0 {
                tl.assert_invariants();
            }
        }
        assert!(
            tl.block_count() > 4,
            "2000 placements must span multiple blocks, got {}",
            tl.block_count()
        );
        for _ in 0..500 {
            let est = rng.uniform(-10.0, 5500.0);
            let d = rng.uniform(0.5, 50.0);
            let cpu = cap_cpu * rng.uniform(0.05, 0.95);
            let mem = cap_mem * rng.uniform(0.05, 0.95);
            let got = tl.earliest_fit(est, d, cpu, mem);
            let want = fl.earliest_fit(est, d, cpu, mem);
            assert_eq!(
                got.map(f64::to_bits),
                want.map(f64::to_bits),
                "fit diverges at est {est} d {d}: {got:?} vs flat {want:?}"
            );
            let t1 = est + rng.uniform(0.0, 100.0);
            let (ac, am) = tl.max_usage_in(est, t1);
            let (bc, bm) = fl.max_usage_in(est, t1);
            assert_eq!(ac.to_bits(), bc.to_bits(), "max cpu diverges in [{est}, {t1})");
            assert_eq!(am.to_bits(), bm.to_bits(), "max mem diverges in [{est}, {t1})");
        }
        // Deep rollback across hundreds of splits must land both kernels
        // on the same (empty) profile.
        tl.rollback(mark.0);
        fl.rollback(mark.1);
        tl.assert_invariants();
        assert_eq!(tl.segments().count(), 0);
        assert_eq!(fl.segments().count(), 0);
        assert_eq!(tl.block_count(), 0);
    }

    #[test]
    fn max_usage_in_windows() {
        let mut tl = Timeline::new(100.0, 100.0);
        tl.place(0.0, 10.0, 4.0, 8.0);
        tl.place(5.0, 10.0, 6.0, 1.0);
        assert_eq!(tl.max_usage_in(0.0, 5.0), (4.0, 8.0));
        assert_eq!(tl.max_usage_in(0.0, 6.0), (10.0, 9.0));
        assert_eq!(tl.max_usage_in(10.0, 15.0), (6.0, 1.0));
        assert_eq!(tl.max_usage_in(15.0, 20.0), (0.0, 0.0));
        assert_eq!(tl.max_usage_in(5.0, 5.0), (0.0, 0.0));
        // window straddling only the tail of the first task
        assert_eq!(tl.max_usage_in(9.0, 10.0), (10.0, 9.0));
    }

    #[test]
    fn area_in_integrates_the_occupied_rectangles() {
        let mut tl = Timeline::new(100.0, 100.0);
        tl.place(0.0, 10.0, 4.0, 8.0);
        tl.place(5.0, 10.0, 6.0, 1.0);
        // Full horizon: 4*10 + 6*10 cpu-seconds, 8*10 + 1*10 mem.
        let (ac, am) = tl.area_in(0.0, 20.0);
        assert!((ac - 100.0).abs() < 1e-9, "cpu area {ac}");
        assert!((am - 90.0).abs() < 1e-9, "mem area {am}");
        // Clipped window [2, 7): 4*5 from the first + 6*2 from the second.
        let (ac, am) = tl.area_in(2.0, 7.0);
        assert!((ac - 32.0).abs() < 1e-9, "clipped cpu area {ac}");
        assert!((am - 42.0).abs() < 1e-9, "clipped mem area {am}");
        // Empty and inverted windows.
        assert_eq!(tl.area_in(3.0, 3.0), (0.0, 0.0));
        assert_eq!(tl.area_in(7.0, 3.0), (0.0, 0.0));
        // Past every placement.
        assert_eq!(tl.area_in(50.0, 60.0), (0.0, 0.0));
    }

    /// `area_in` against a brute-force per-rectangle overlap integral,
    /// over random profiles (tolerance-based: segment sums and rectangle
    /// sums associate differently).
    #[test]
    fn property_area_matches_rectangle_overlap() {
        propcheck::check(30, |rng| {
            let cap_cpu = 64.0;
            let cap_mem = 256.0;
            let mut tl = Timeline::new(cap_cpu, cap_mem);
            let mut rects: Vec<Reservation> = Vec::new();
            for _ in 0..rng.below(40) {
                let r = (
                    rng.uniform(0.0, 300.0),
                    rng.uniform(0.5, 40.0),
                    rng.uniform(0.5, 20.0),
                    rng.uniform(0.5, 60.0),
                );
                tl.place(r.0, r.1, r.2, r.3);
                rects.push(r);
            }
            for _ in 0..20 {
                let t0 = rng.uniform(-20.0, 350.0);
                let t1 = t0 + rng.uniform(0.0, 120.0);
                let (ac, am) = tl.area_in(t0, t1);
                let mut bc = 0.0;
                let mut bm = 0.0;
                for &(s, d, cpu, mem) in &rects {
                    let overlap = (s + d).min(t1) - s.max(t0);
                    if overlap > 0.0 {
                        bc += cpu * overlap;
                        bm += mem * overlap;
                    }
                }
                if (ac - bc).abs() > 1e-6 * (1.0 + bc.abs())
                    || (am - bm).abs() > 1e-6 * (1.0 + bm.abs())
                {
                    return Err(format!(
                        "area in [{t0}, {t1}) = ({ac}, {am}), brute force ({bc}, {bm})"
                    ));
                }
            }
            Ok(())
        });
    }
}
