//! The shared capacity-timeline kernel: an event-sweep **capacity
//! profile** over (vcpus, memory) usage that every scheduling primitive
//! in the repo packs against.
//!
//! Every plan the optimizer evaluates — thousands of annealing probes per
//! round, each CP branch-and-bound node, every executor dispatch, every
//! `Schedule::validate` — bottoms out in [`Timeline::earliest_fit`] /
//! [`Timeline::place`]. The historical kernel kept a flat rectangle list
//! and rescanned *all* placements at every event point: O(n²) per
//! feasibility query and O(n³) per serial-SGS pass. This module replaces
//! it with a sorted step function of change-points:
//!
//! | operation      | rectangle list (old)   | capacity profile (new)      |
//! |----------------|------------------------|-----------------------------|
//! | `earliest_fit` | O(n²) (n candidates × O(n) scans) | O(log n + k) one sweep over the k segments crossed |
//! | `place`        | O(1) push (cost deferred to queries) | O(log n) locate + O(k) segment update, plus an O(n) contiguous memmove per newly inserted change-point |
//! | backtrack      | O(1) `pop`/`truncate`  | O(k) exact [`Timeline::rollback`] to a [`Mark`] |
//! | full validate  | O(n²)                  | O(n log n) typical build + O(n) segment scan |
//!
//! (`k` = number of constant-usage segments a placement window crosses —
//! small in practice. The sorted vector trades a worst-case O(n)
//! memmove per insert — so O(n²) for a full n-placement pass — for
//! cache-friendly queries; that memmove is a contiguous `memcpy`-class
//! operation, orders of magnitude cheaper per element than the old
//! kernel's per-query rescans, and the `scaling_timeline` bench measures
//! the end-to-end effect rather than relying on the asymptotics.)
//!
//! ## Checkpoint / rollback
//!
//! The ad-hoc `pop()`-per-DFS-node and `truncate(len)` prefix-reuse
//! protocols of the historical kernel are replaced by explicit epoch
//! marks: [`Timeline::checkpoint`] returns a [`Mark`], and
//! [`Timeline::rollback`] restores the timeline to that mark **exactly**
//! (bit-for-bit, via an undo journal of overwritten segment values — not
//! by re-subtracting floats, which would accumulate rounding drift over
//! the millions of place/undo cycles an annealing run performs).
//! Rollback is LIFO: marks must be released in reverse order of creation,
//! which is the natural discipline of both the CP solver's DFS and the
//! incremental evaluators' shared-prefix reuse.
//!
//! ## Infeasible demands
//!
//! [`Timeline::earliest_fit`] returns `None` when the demand can never
//! run on this cluster (it exceeds total capacity on its own). The
//! historical kernel silently returned a start anyway — an over-capacity
//! rectangle that corrupted every later query. Callers surface `None`
//! through their `anyhow::Result` paths (see `sgs::serial_sgs`).
//!
//! ## Equivalence contract
//!
//! The kernel produces **bit-identical schedules** to the historical
//! one: `earliest_fit` returns either `est` or the exact stored end of a
//! placed rectangle, and feasibility uses the same `1e-6` capacity
//! tolerance. One caveat bounds the claim: the historical kernel probed
//! usage at `point + 1e-9` (a rectangle overlapping a window by less
//! than 1e-9 was ignored), while this kernel uses exact half-open
//! segments. The two can therefore disagree only when two *distinct*
//! change-points lie within 1e-9 of each other — coincident times in
//! this codebase are computed by identical float expressions and are
//! bitwise equal, and all durations are O(seconds), so the regime does
//! not arise; it would take adversarial sub-nanosecond rectangles to
//! split them. The historical kernel is retained verbatim in
//! [`reference`] as the executable specification; property tests (here
//! and in `sgs`) and the `scaling_timeline` bench run the two side by
//! side on random seeded/occupied problems to keep the equivalence
//! honest empirically.

use super::rcpsp::Reservation;

/// Capacity slack mirrored from the historical kernel: usage may
/// overshoot capacity by at most this before a window is infeasible.
const CAP_EPS: f64 = 1e-6;

/// An epoch mark returned by [`Timeline::checkpoint`]: the number of
/// placements journaled so far. [`Timeline::rollback`] restores the
/// timeline to the state it had when the mark was taken.
pub type Mark = usize;

/// One journaled placement: which segment range it raised, which
/// change-points it inserted, and where its overwritten usage values
/// start on the save stack. Undo replays these exactly (LIFO).
#[derive(Debug, Clone, Copy)]
struct JournalEntry {
    /// First segment index whose usage this placement raised.
    lo: usize,
    /// One past the last raised segment index.
    hi: usize,
    /// Whether the placement inserted the change-point at `lo`.
    ins_lo: bool,
    /// Whether the placement inserted the change-point at `hi`.
    ins_hi: bool,
    /// Offset into [`Timeline::saved`] of this placement's overwritten
    /// `(cpu, mem)` values (one pair per raised segment).
    saved_off: usize,
}

/// Resource timeline of placed rectangular tasks, stored as a capacity
/// profile: sorted change-points with the absolute (cpu, mem) usage of
/// the constant segment starting at each point. See the module docs for
/// the representation, complexity, and rollback contract.
#[derive(Debug, Clone)]
pub struct Timeline {
    cap_cpu: f64,
    cap_mem: f64,
    /// Sorted distinct change-points (placement starts and ends).
    points: Vec<f64>,
    /// Usage on `[points[i], points[i+1])`; the final segment extends to
    /// infinity and always carries zero usage (its start is the latest
    /// placement end).
    seg_cpu: Vec<f64>,
    seg_mem: Vec<f64>,
    /// Undo journal, one entry per `place` call (including no-ops).
    journal: Vec<JournalEntry>,
    /// Stack of overwritten segment usage values, LIFO with `journal`.
    saved: Vec<(f64, f64)>,
}

impl Timeline {
    /// Empty timeline with the given capacity.
    pub fn new(cap_cpu: f64, cap_mem: f64) -> Self {
        Timeline {
            cap_cpu,
            cap_mem,
            points: Vec::new(),
            seg_cpu: Vec::new(),
            seg_mem: Vec::new(),
            journal: Vec::new(),
            saved: Vec::new(),
        }
    }

    /// Timeline pre-seeded with occupancy reservations (continuous
    /// multi-tenant admission, committed work during a replan, outage
    /// blockers). The seed rectangles are ordinary journaled placements:
    /// a [`checkpoint`](Timeline::checkpoint) taken right after
    /// construction protects them from any later rollback.
    pub fn seeded(cap_cpu: f64, cap_mem: f64, reservations: &[Reservation]) -> Self {
        let mut tl = Timeline::new(cap_cpu, cap_mem);
        for &(s, d, cpu, mem) in reservations {
            tl.place(s, d, cpu, mem);
        }
        tl
    }

    /// Cluster vCPU capacity this timeline packs against.
    pub fn cap_cpu(&self) -> f64 {
        self.cap_cpu
    }

    /// Cluster memory capacity (GiB) this timeline packs against.
    pub fn cap_mem(&self) -> f64 {
        self.cap_mem
    }

    /// Index of change-point `t`, inserting it (with the usage of the
    /// segment it splits) when absent. Returns `(index, inserted)`.
    fn ensure_point(&mut self, t: f64) -> (usize, bool) {
        match self.points.binary_search_by(|p| p.total_cmp(&t)) {
            Ok(i) => (i, false),
            Err(i) => {
                let (c, m) = if i == 0 {
                    (0.0, 0.0)
                } else {
                    (self.seg_cpu[i - 1], self.seg_mem[i - 1])
                };
                self.points.insert(i, t);
                self.seg_cpu.insert(i, c);
                self.seg_mem.insert(i, m);
                (i, true)
            }
        }
    }

    /// Reserve a (cpu, mem) rectangle over `[s, s+d)`. Non-positive
    /// durations are journaled as no-ops so mark arithmetic stays 1:1
    /// with `place` calls.
    pub fn place(&mut self, s: f64, d: f64, cpu: f64, mem: f64) {
        let e = s + d;
        // NaN-safe "not strictly after": NaN windows are no-ops too.
        if e.partial_cmp(&s) != Some(std::cmp::Ordering::Greater) {
            self.journal.push(JournalEntry {
                lo: 0,
                hi: 0,
                ins_lo: false,
                ins_hi: false,
                saved_off: self.saved.len(),
            });
            return;
        }
        let (lo, ins_lo) = self.ensure_point(s);
        // `e > s`, so inserting `e` cannot shift index `lo`.
        let (hi, ins_hi) = self.ensure_point(e);
        let saved_off = self.saved.len();
        for i in lo..hi {
            self.saved.push((self.seg_cpu[i], self.seg_mem[i]));
            self.seg_cpu[i] += cpu;
            self.seg_mem[i] += mem;
        }
        self.journal.push(JournalEntry {
            lo,
            hi,
            ins_lo,
            ins_hi,
            saved_off,
        });
    }

    /// Undo the most recent journaled placement exactly (restores the
    /// overwritten usage bytes; removes the change-points it inserted).
    fn unplace(&mut self) {
        let e = self
            .journal
            .pop()
            .expect("rollback below the empty timeline");
        for (k, i) in (e.lo..e.hi).enumerate() {
            let (c, m) = self.saved[e.saved_off + k];
            self.seg_cpu[i] = c;
            self.seg_mem[i] = m;
        }
        self.saved.truncate(e.saved_off);
        // Remove the higher index first so the lower one stays valid.
        if e.ins_hi {
            self.points.remove(e.hi);
            self.seg_cpu.remove(e.hi);
            self.seg_mem.remove(e.hi);
        }
        if e.ins_lo {
            self.points.remove(e.lo);
            self.seg_cpu.remove(e.lo);
            self.seg_mem.remove(e.lo);
        }
    }

    /// Take an epoch mark capturing the current set of placements.
    pub fn checkpoint(&self) -> Mark {
        self.journal.len()
    }

    /// Restore the timeline to the state captured by `mark`, undoing
    /// every placement made since — bit-exact (see the module docs).
    /// Marks are LIFO: rolling back past a mark invalidates every mark
    /// taken after it.
    ///
    /// # Panics
    ///
    /// Panics if `mark` lies in the future (greater than the current
    /// placement count).
    pub fn rollback(&mut self, mark: Mark) {
        assert!(
            mark <= self.journal.len(),
            "rollback to future mark {mark} (placed: {})",
            self.journal.len()
        );
        while self.journal.len() > mark {
            self.unplace();
        }
    }

    /// Number of placements currently journaled (reservation seeds
    /// included).
    pub fn len(&self) -> usize {
        self.journal.len()
    }

    /// Whether nothing is placed.
    pub fn is_empty(&self) -> bool {
        self.journal.is_empty()
    }

    /// Earliest `s >= est` such that `(cpu, mem)` more fits throughout
    /// `[s, s+d)`, or `None` when the demand alone exceeds the cluster
    /// capacity (no start can ever fit — the caller must surface this
    /// instead of placing an over-capacity rectangle).
    ///
    /// One forward sweep over the profile: start the candidate window at
    /// `est`; whenever a segment inside the window lacks free capacity,
    /// restart the window at that segment's end and keep scanning. The
    /// result is always `est` itself or the exact end of a placed
    /// rectangle (the left-shift argument: any feasible start that is
    /// neither can be shifted left to one without losing feasibility),
    /// which is what keeps schedules bit-identical to the historical
    /// candidate-scan kernel.
    pub fn earliest_fit(&self, est: f64, d: f64, cpu: f64, mem: f64) -> Option<f64> {
        if cpu > self.cap_cpu + CAP_EPS || mem > self.cap_mem + CAP_EPS {
            return None;
        }
        let n = self.points.len();
        let mut t = est;
        // First segment whose interior can reach t: the one containing t
        // (last point <= t), or segment 0 when t precedes every point.
        let first_after = self.points.partition_point(|p| p.total_cmp(&t).is_le());
        let mut idx = first_after.saturating_sub(1);
        while idx < n {
            if self.points[idx] >= t + d {
                // Every remaining segment starts at or after the window
                // end: [t, t+d) is clear.
                return Some(t);
            }
            let end = if idx + 1 < n {
                self.points[idx + 1]
            } else {
                f64::INFINITY
            };
            if end > t
                && (self.seg_cpu[idx] + cpu > self.cap_cpu + CAP_EPS
                    || self.seg_mem[idx] + mem > self.cap_mem + CAP_EPS)
            {
                // Window hits an over-full segment: restart just past it.
                // The final segment always has zero usage (it begins at
                // the latest placement end) and the demand fits capacity,
                // so a violation here is unreachable — guarded anyway.
                if idx + 1 >= n {
                    return None;
                }
                t = end;
            }
            idx += 1;
        }
        Some(t)
    }

    /// Usage `(cpu, mem)` of the segment containing instant `t`.
    pub fn usage_at(&self, t: f64) -> (f64, f64) {
        let j = self.points.partition_point(|p| p.total_cmp(&t).is_le());
        if j == 0 {
            (0.0, 0.0)
        } else {
            (self.seg_cpu[j - 1], self.seg_mem[j - 1])
        }
    }

    /// Maximum usage `(cpu, mem)` over any instant in `[t0, t1)` — the
    /// conservative per-bucket pre-load of the time-indexed MILP
    /// baseline. `(0, 0)` for an empty window or a window past every
    /// placement.
    pub fn max_usage_in(&self, t0: f64, t1: f64) -> (f64, f64) {
        let mut mc = 0.0f64;
        let mut mm = 0.0f64;
        if t1.partial_cmp(&t0) != Some(std::cmp::Ordering::Greater) {
            return (mc, mm);
        }
        let first_after = self.points.partition_point(|p| p.total_cmp(&t0).is_le());
        for i in first_after.saturating_sub(1)..self.points.len() {
            if self.points[i] >= t1 {
                break;
            }
            let end = if i + 1 < self.points.len() {
                self.points[i + 1]
            } else {
                f64::INFINITY
            };
            if end > t0 {
                mc = mc.max(self.seg_cpu[i]);
                mm = mm.max(self.seg_mem[i]);
            }
        }
        (mc, mm)
    }

    /// Every maximal constant-usage segment as `(start, end, cpu, mem)`,
    /// in time order; the final segment's end is `f64::INFINITY`. Used by
    /// `Schedule::validate`'s Eq.-4 sweep and by the property tests.
    pub fn segments(&self) -> impl Iterator<Item = (f64, f64, f64, f64)> + '_ {
        let n = self.points.len();
        (0..n).map(move |i| {
            let end = if i + 1 < n {
                self.points[i + 1]
            } else {
                f64::INFINITY
            };
            (self.points[i], end, self.seg_cpu[i], self.seg_mem[i])
        })
    }
}

pub mod reference {
    //! The historical rectangle-list kernel, retained **verbatim** as the
    //! executable specification of [`Timeline`](super::Timeline): a flat
    //! list of placed rectangles, O(n²) feasibility queries, O(n³)
    //! placement scans. Property tests (`timeline`, `sgs`) and the
    //! `scaling_timeline` bench run it side by side with the production
    //! kernel to pin bit-identical schedules and measure the speedup.
    //! Never use this from production paths.

    use crate::solver::rcpsp::Problem;
    use crate::solver::schedule::Schedule;
    use crate::solver::sgs::selection_order;

    /// Flat rectangle-list timeline (the historical implementation).
    pub struct RefTimeline {
        /// (start, end, cpu, mem) of each placed task.
        placed: Vec<(f64, f64, f64, f64)>,
        cap_cpu: f64,
        cap_mem: f64,
    }

    impl RefTimeline {
        /// Empty timeline with the given capacity.
        pub fn new(cap_cpu: f64, cap_mem: f64) -> Self {
            RefTimeline {
                placed: Vec::new(),
                cap_cpu,
                cap_mem,
            }
        }

        /// Can a (cpu, mem) demand run throughout [s, s+d)?
        fn fits(&self, s: f64, d: f64, cpu: f64, mem: f64) -> bool {
            // Capacity must hold at every event point in the window;
            // events are the window start and starts of overlapping
            // placed tasks.
            let e = s + d;
            let mut points = vec![s];
            for &(ps, pe, _, _) in &self.placed {
                if ps > s && ps < e && pe > s {
                    points.push(ps);
                }
            }
            for &point in &points {
                let mut used_cpu = cpu;
                let mut used_mem = mem;
                for &(ps, pe, pc, pm) in &self.placed {
                    if ps <= point + 1e-9 && point + 1e-9 < pe {
                        used_cpu += pc;
                        used_mem += pm;
                    }
                }
                if used_cpu > self.cap_cpu + 1e-6 || used_mem > self.cap_mem + 1e-6 {
                    return false;
                }
            }
            true
        }

        /// Earliest s >= est such that the demand fits throughout
        /// [s, s+d). Keeps the historical fallback: for a demand that
        /// exceeds cluster capacity alone, the returned start is
        /// meaningless (the production kernel returns `None` there).
        pub fn earliest_fit(&self, est: f64, d: f64, cpu: f64, mem: f64) -> f64 {
            if self.fits(est, d, cpu, mem) {
                return est;
            }
            // Candidate starts: ends of placed tasks after est, sorted.
            let mut candidates: Vec<f64> = self
                .placed
                .iter()
                .map(|&(_, e, _, _)| e)
                .filter(|&e| e > est)
                .collect();
            candidates.sort_by(|a, b| a.total_cmp(b));
            for s in candidates {
                if self.fits(s, d, cpu, mem) {
                    return s;
                }
            }
            // Fallback: after everything ends (always feasible for a
            // demand that fits capacity alone).
            self.placed
                .iter()
                .map(|&(_, e, _, _)| e)
                .fold(est, f64::max)
        }

        /// Reserve a (cpu, mem) rectangle over [s, s+d).
        pub fn place(&mut self, s: f64, d: f64, cpu: f64, mem: f64) {
            self.placed.push((s, s + d, cpu, mem));
        }

        /// Remove the most recently placed rectangle.
        pub fn pop(&mut self) {
            self.placed.pop();
        }

        /// Keep only the first `len` placements.
        pub fn truncate(&mut self, len: usize) {
            self.placed.truncate(len);
        }

        /// Number of placed rectangles.
        pub fn len(&self) -> usize {
            self.placed.len()
        }

        /// Whether nothing is placed.
        pub fn is_empty(&self) -> bool {
            self.placed.is_empty()
        }

        /// Exact usage at instant `t` under the historical membership
        /// test (`ps <= t + 1e-9 < pe`).
        pub fn usage_at(&self, t: f64) -> (f64, f64) {
            let mut cpu = 0.0;
            let mut mem = 0.0;
            for &(ps, pe, pc, pm) in &self.placed {
                if ps <= t + 1e-9 && t + 1e-9 < pe {
                    cpu += pc;
                    mem += pm;
                }
            }
            (cpu, mem)
        }
    }

    /// The historical serial SGS, verbatim, over [`RefTimeline`] —
    /// seeded with the problem's occupancy reservations like the
    /// production `sgs::serial_sgs`. The assignment must draw from
    /// `Problem::feasible` (the historical kernel has no infeasibility
    /// reporting).
    pub fn serial_sgs_ref(p: &Problem, assignment: &[usize], prio: &[f64]) -> Schedule {
        let n = p.len();
        let order = selection_order(p, prio);
        let mut start = vec![0.0f64; n];
        let mut timeline = RefTimeline::new(p.capacity.vcpus, p.capacity.memory_gb);
        for &(s, d, cpu, mem) in &p.preplaced {
            timeline.place(s, d, cpu, mem);
        }
        for &t in &order {
            let est = p
                .preds(t)
                .iter()
                .map(|&q| start[q] + p.duration(q, assignment[q]))
                .fold(p.release[t], f64::max);
            let d = p.duration(t, assignment[t]);
            let (cpu, mem) = p.demand(assignment[t]);
            let s = timeline.earliest_fit(est, d, cpu, mem);
            timeline.place(s, d, cpu, mem);
            start[t] = s;
        }
        Schedule {
            assignment: assignment.to_vec(),
            start,
            optimal: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::reference::RefTimeline;
    use super::*;
    use crate::util::{propcheck, Rng};

    #[test]
    fn earliest_fit_respects_capacity() {
        let mut tl = Timeline::new(10.0, 100.0);
        tl.place(0.0, 10.0, 8.0, 50.0);
        // demand 4 cpus cannot run concurrently with the 8-cpu task
        assert_eq!(tl.earliest_fit(0.0, 5.0, 4.0, 10.0), Some(10.0));
        // but 2 cpus can
        assert_eq!(tl.earliest_fit(0.0, 5.0, 2.0, 10.0), Some(0.0));
    }

    #[test]
    fn finds_gap_between_tasks() {
        let mut tl = Timeline::new(10.0, 100.0);
        tl.place(0.0, 5.0, 10.0, 10.0);
        tl.place(8.0, 5.0, 10.0, 10.0);
        // a 3-second task fits exactly in the [5, 8) gap
        assert_eq!(tl.earliest_fit(0.0, 3.0, 10.0, 10.0), Some(5.0));
        // a 4-second task does not; next fit is after the second task
        assert_eq!(tl.earliest_fit(0.0, 4.0, 10.0, 10.0), Some(13.0));
    }

    #[test]
    fn memory_constrains_like_cpu() {
        let mut tl = Timeline::new(100.0, 10.0);
        tl.place(0.0, 10.0, 1.0, 8.0);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 1.0, 4.0), Some(10.0));
        assert_eq!(tl.earliest_fit(0.0, 5.0, 1.0, 2.0), Some(0.0));
    }

    #[test]
    fn over_capacity_demand_is_rejected_not_placed() {
        let tl = Timeline::new(10.0, 100.0);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 10.5, 10.0), None);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 5.0, 200.0), None);
        // Exactly at capacity (within the historical 1e-6 slack) fits.
        assert_eq!(tl.earliest_fit(0.0, 5.0, 10.0, 100.0), Some(0.0));
    }

    #[test]
    fn checkpoint_rollback_restores_exactly() {
        let mut tl = Timeline::new(10.0, 100.0);
        tl.place(0.0, 10.0, 4.0, 10.0);
        let before: Vec<_> = tl.segments().collect();
        let mark = tl.checkpoint();
        tl.place(2.0, 5.0, 6.0, 20.0);
        tl.place(7.0, 9.0, 3.0, 5.0);
        assert_eq!(tl.len(), 3);
        tl.rollback(mark);
        assert_eq!(tl.len(), 1);
        let after: Vec<_> = tl.segments().collect();
        assert_eq!(before.len(), after.len());
        for (b, a) in before.iter().zip(after.iter()) {
            assert_eq!(b.0.to_bits(), a.0.to_bits());
            assert_eq!(b.2.to_bits(), a.2.to_bits());
            assert_eq!(b.3.to_bits(), a.3.to_bits());
        }
    }

    #[test]
    fn nested_marks_unwind_in_lifo_order() {
        let mut tl = Timeline::new(16.0, 64.0);
        let m0 = tl.checkpoint();
        tl.place(0.0, 4.0, 8.0, 16.0);
        let m1 = tl.checkpoint();
        tl.place(1.0, 4.0, 8.0, 16.0);
        // [1, 4) is saturated: the earliest 2-wide window for another
        // 8-cpu task opens when the second placement ends at t = 4.
        assert_eq!(tl.earliest_fit(0.0, 2.0, 8.0, 1.0), Some(4.0));
        tl.rollback(m1);
        assert_eq!(tl.earliest_fit(0.0, 2.0, 8.0, 1.0), Some(0.0));
        tl.rollback(m0);
        assert!(tl.is_empty());
        assert_eq!(tl.segments().count(), 0);
    }

    #[test]
    fn zero_duration_placements_are_journaled_noops() {
        let mut tl = Timeline::new(8.0, 8.0);
        let mark = tl.checkpoint();
        tl.place(3.0, 0.0, 8.0, 8.0);
        assert_eq!(tl.len(), 1);
        assert_eq!(tl.usage_at(3.0), (0.0, 0.0));
        tl.rollback(mark);
        assert!(tl.is_empty());
    }

    #[test]
    #[should_panic(expected = "future mark")]
    fn rollback_to_future_mark_panics() {
        let mut tl = Timeline::new(1.0, 1.0);
        tl.rollback(3);
    }

    #[test]
    fn zero_duration_fit_matches_reference_semantics() {
        // A zero-length window occupies nothing, but both kernels treat
        // it as a point probe: inside a saturated segment it defers to
        // the segment end, in free space it returns est. Pinned here so
        // the edge cannot drift silently between the two kernels.
        let mut tl = Timeline::new(10.0, 100.0);
        let mut rf = RefTimeline::new(10.0, 100.0);
        tl.place(5.0, 10.0, 8.0, 10.0);
        rf.place(5.0, 10.0, 8.0, 10.0);
        for (est, cpu) in [(0.0, 4.0), (7.0, 4.0), (7.0, 1.0), (20.0, 9.0)] {
            let got = tl.earliest_fit(est, 0.0, cpu, 1.0);
            let want = rf.earliest_fit(est, 0.0, cpu, 1.0);
            assert_eq!(
                got.map(f64::to_bits),
                Some(want.to_bits()),
                "zero-duration fit at est {est} cpu {cpu}: {got:?} vs ref {want}"
            );
        }
        // In particular: a point probe in free space is est itself...
        assert_eq!(tl.earliest_fit(0.0, 0.0, 4.0, 1.0), Some(0.0));
        // ...and inside the saturated window it defers to the boundary.
        assert_eq!(tl.earliest_fit(7.0, 0.0, 4.0, 1.0), Some(15.0));
    }

    #[test]
    fn demand_exactly_at_residual_capacity_fits_at_est() {
        // Eq. 4 is an inclusive bound (<= R_m within the 1e-6 slack):
        // a demand that tops usage to exactly capacity must start at
        // est, one that exceeds the residual by more than the slack
        // must wait for the release.
        let mut tl = Timeline::new(16.0, 64.0);
        tl.place(0.0, 10.0, 10.0, 40.0);
        // Exactly the residual (16 - 10 cpu, 64 - 40 mem).
        assert_eq!(tl.earliest_fit(0.0, 5.0, 6.0, 24.0), Some(0.0));
        // Within the historical 1e-6 capacity slack: still fits.
        assert_eq!(tl.earliest_fit(0.0, 5.0, 6.0 + 5e-7, 24.0), Some(0.0));
        // Past the slack on either resource: deferred to the release.
        assert_eq!(tl.earliest_fit(0.0, 5.0, 6.0 + 2e-6, 24.0), Some(10.0));
        assert_eq!(tl.earliest_fit(0.0, 5.0, 6.0, 24.0 + 2e-6), Some(10.0));
        // Demand exactly at full cluster capacity on an empty stretch.
        assert_eq!(tl.earliest_fit(10.0, 5.0, 16.0, 64.0), Some(10.0));
    }

    #[test]
    fn earliest_fit_none_is_stable_across_checkpoint_rollback() {
        // `None` means the demand alone exceeds the cluster — no
        // place/checkpoint/rollback interleaving may change that verdict,
        // and in-capacity answers must come back bit-identical after a
        // rollback round-trip.
        let mut tl = Timeline::new(8.0, 32.0);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 8.5, 1.0), None);
        let before = tl.earliest_fit(0.0, 5.0, 4.0, 16.0);

        let m0 = tl.checkpoint();
        tl.place(0.0, 20.0, 8.0, 32.0);
        let m1 = tl.checkpoint();
        tl.place(20.0, 20.0, 8.0, 32.0);
        // Over-capacity demand: still None with the cluster fully packed.
        assert_eq!(tl.earliest_fit(0.0, 5.0, 8.5, 1.0), None);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 1.0, 32.5), None);
        // In-capacity demand: deferred past the packed prefix.
        assert_eq!(tl.earliest_fit(0.0, 5.0, 4.0, 16.0), Some(40.0));

        tl.rollback(m1);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 8.5, 1.0), None);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 4.0, 16.0), Some(20.0));
        tl.rollback(m0);
        assert_eq!(tl.earliest_fit(0.0, 5.0, 8.5, 1.0), None);
        let after = tl.earliest_fit(0.0, 5.0, 4.0, 16.0);
        assert_eq!(
            before.map(f64::to_bits),
            after.map(f64::to_bits),
            "rollback round-trip changed an in-capacity answer"
        );
    }

    /// Drive the production and reference kernels through an identical
    /// random op sequence, cross-checking occupancy (against a
    /// brute-force per-event-point recomputation) and every
    /// `earliest_fit` answer, with reservations, floored queries, and
    /// checkpoint/rollback interleavings.
    #[test]
    fn property_fuzz_against_reference_and_brute_force() {
        propcheck::check(40, |rng| {
            let cap_cpu = rng.uniform(8.0, 64.0);
            let cap_mem = rng.uniform(32.0, 256.0);
            // Random occupancy seed (possibly negative starts, like a
            // ledger snapshot shifted into round-local time).
            let n_res = rng.below(4);
            let reservations: Vec<Reservation> = (0..n_res)
                .map(|_| {
                    (
                        rng.uniform(-50.0, 100.0),
                        rng.uniform(1.0, 80.0),
                        cap_cpu * rng.uniform(0.1, 0.9),
                        cap_mem * rng.uniform(0.1, 0.9),
                    )
                })
                .collect();
            let mut tl = Timeline::seeded(cap_cpu, cap_mem, &reservations);
            let mut rf = RefTimeline::new(cap_cpu, cap_mem);
            for &(s, d, cpu, mem) in &reservations {
                rf.place(s, d, cpu, mem);
            }
            // Rectangles mirrored into both kernels, for brute-force
            // usage recomputation and LIFO undo.
            let mut rects: Vec<Reservation> = reservations.clone();
            let mut marks: Vec<(Mark, usize)> = Vec::new();

            for step in 0..60 {
                match rng.below(10) {
                    // place
                    0..=4 => {
                        let s = rng.uniform(0.0, 200.0);
                        let d = rng.uniform(0.5, 60.0);
                        let cpu = cap_cpu * rng.uniform(0.05, 0.8);
                        let mem = cap_mem * rng.uniform(0.05, 0.8);
                        tl.place(s, d, cpu, mem);
                        rf.place(s, d, cpu, mem);
                        rects.push((s, d, cpu, mem));
                    }
                    // checkpoint
                    5 => marks.push((tl.checkpoint(), rects.len())),
                    // rollback to the most recent mark
                    6 => {
                        if let Some((mark, kept)) = marks.pop() {
                            tl.rollback(mark);
                            rf.truncate(mark);
                            rects.truncate(kept);
                        }
                    }
                    // earliest_fit cross-check (random admission floor)
                    _ => {
                        let est = rng.uniform(-10.0, 250.0);
                        let d = rng.uniform(0.5, 40.0);
                        let cpu = cap_cpu * rng.uniform(0.05, 0.95);
                        let mem = cap_mem * rng.uniform(0.05, 0.95);
                        let got = tl.earliest_fit(est, d, cpu, mem);
                        let want = rf.earliest_fit(est, d, cpu, mem);
                        match got {
                            None => {
                                return Err(format!(
                                    "step {step}: fit None for in-capacity demand"
                                ))
                            }
                            Some(got) => {
                                if got.to_bits() != want.to_bits() {
                                    return Err(format!(
                                        "step {step}: earliest_fit {got} != ref {want}"
                                    ));
                                }
                            }
                        }
                    }
                }

                // Brute-force occupancy cross-check at every event point
                // (and just before/after, to catch off-by-one-segment
                // errors), against a from-scratch recomputation.
                let mut probes: Vec<f64> = Vec::new();
                for &(s, d, _, _) in &rects {
                    probes.push(s);
                    probes.push(s + d);
                    probes.push(s + d * 0.5);
                }
                probes.push(-1e3);
                probes.push(1e4);
                for &t in &probes {
                    let (c, m) = tl.usage_at(t);
                    let mut bc = 0.0;
                    let mut bm = 0.0;
                    for &(s, d, cpu, mem) in &rects {
                        // Exact half-open membership, matching the
                        // profile's [start, end) segments.
                        if s <= t && t < s + d {
                            bc += cpu;
                            bm += mem;
                        }
                    }
                    if (c - bc).abs() > 1e-9 * (1.0 + bc.abs())
                        || (m - bm).abs() > 1e-9 * (1.0 + bm.abs())
                    {
                        return Err(format!(
                            "step {step}: usage at {t} = ({c}, {m}), brute force ({bc}, {bm})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// After an arbitrary place/rollback history, the profile must be
    /// byte-identical to one freshly built from the surviving rectangles
    /// — the no-rounding-drift guarantee of the undo journal.
    #[test]
    fn property_rollback_leaves_no_float_drift() {
        propcheck::check(30, |rng| {
            let cap = 32.0;
            let mut tl = Timeline::new(cap, cap * 4.0);
            let mut rects: Vec<Reservation> = Vec::new();
            for _ in 0..40 {
                if rng.chance(0.35) && !tl.is_empty() {
                    // rollback a random suffix
                    let keep = rng.below(tl.len() + 1);
                    tl.rollback(keep);
                    rects.truncate(keep);
                } else {
                    let r = (
                        rng.uniform(0.0, 100.0),
                        rng.uniform(0.1, 30.0),
                        // adversarial fractional demands (0.1 + 0.3-style
                        // sums that do not round-trip under subtraction)
                        rng.uniform(0.1, 0.7),
                        rng.uniform(0.1, 0.7),
                    );
                    tl.place(r.0, r.1, r.2, r.3);
                    rects.push(r);
                }
            }
            let fresh = Timeline::seeded(tl.cap_cpu(), tl.cap_mem(), &rects);
            let a: Vec<_> = tl.segments().collect();
            let b: Vec<_> = fresh.segments().collect();
            if a.len() != b.len() {
                return Err(format!("segment counts differ: {} vs {}", a.len(), b.len()));
            }
            for (x, y) in a.iter().zip(b.iter()) {
                if x.0.to_bits() != y.0.to_bits()
                    || x.2.to_bits() != y.2.to_bits()
                    || x.3.to_bits() != y.3.to_bits()
                {
                    return Err(format!("segments diverge: {x:?} vs {y:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn max_usage_in_windows() {
        let mut tl = Timeline::new(100.0, 100.0);
        tl.place(0.0, 10.0, 4.0, 8.0);
        tl.place(5.0, 10.0, 6.0, 1.0);
        assert_eq!(tl.max_usage_in(0.0, 5.0), (4.0, 8.0));
        assert_eq!(tl.max_usage_in(0.0, 6.0), (10.0, 9.0));
        assert_eq!(tl.max_usage_in(10.0, 15.0), (6.0, 1.0));
        assert_eq!(tl.max_usage_in(15.0, 20.0), (0.0, 0.0));
        assert_eq!(tl.max_usage_in(5.0, 5.0), (0.0, 0.0));
        // window straddling only the tail of the first task
        assert_eq!(tl.max_usage_in(9.0, 10.0), (10.0, 9.0));
    }
}
