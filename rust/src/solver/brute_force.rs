//! Brute-force co-optimization (the paper's *BF co-optimize*): exhaustive
//! search over configuration vectors with an exact inner schedule solve.
//! Used by the §3 motivational study (Table 2, Fig. 3) and the search
//! space / solve-time scalability measurement (Fig. 4).

use std::time::{Duration, Instant};

use super::cp::{CpSolver, Limits};
use super::objective::Objective;
use super::rcpsp::Problem;
use super::schedule::Schedule;

/// Result of an exhaustive co-optimization.
#[derive(Debug, Clone)]
pub struct BruteForceResult {
    /// Best schedule found.
    pub schedule: Schedule,
    /// Makespan of the best schedule.
    pub makespan: f64,
    /// Cost of the best schedule.
    pub cost: f64,
    /// Eq. 1 energy of the best schedule.
    pub energy: f64,
    /// Configuration vectors evaluated.
    pub evaluated: u64,
    /// Total enumeration wall-clock time.
    pub wall_time: Duration,
    /// Whether the full space was enumerated within the time budget.
    pub complete: bool,
}

/// Size of the search space: |configs|^tasks (saturating; reported in
/// Fig. 4's left panel).
pub fn search_space_size(num_tasks: usize, num_configs: usize) -> f64 {
    (num_configs as f64).powi(num_tasks as i32)
}

/// Exhaustively enumerate configuration vectors (odometer order), solve
/// each schedule exactly, keep the best Eq. 1 energy.
pub fn brute_force(
    p: &Problem,
    objective: &Objective,
    inner_limits: Limits,
    max_time: Duration,
) -> BruteForceResult {
    let t0 = Instant::now();
    let solver = CpSolver::new(inner_limits);
    let n = p.len();
    let choices = &p.feasible;

    let mut counter = vec![0usize; n];
    let mut best: Option<(f64, Schedule, f64, f64)> = None;
    let mut evaluated = 0u64;
    let mut complete = true;

    'outer: loop {
        let assignment: Vec<usize> = counter.iter().map(|&i| choices[i]).collect();
        let (sched, _) = solver
            .solve(p, &assignment)
            .expect("enumerated assignments draw from Problem::feasible");
        let makespan = sched.makespan(p);
        let cost = sched.cost(p);
        let energy = objective.energy(makespan, cost);
        evaluated += 1;
        if best.as_ref().map_or(true, |(be, ..)| energy < *be) {
            best = Some((energy, sched, makespan, cost));
        }

        if t0.elapsed() > max_time {
            complete = false;
            break;
        }

        // Odometer increment.
        let mut i = 0;
        loop {
            if i == n {
                break 'outer;
            }
            counter[i] += 1;
            if counter[i] < choices.len() {
                break;
            }
            counter[i] = 0;
            i += 1;
        }
    }

    let (energy, schedule, makespan, cost) = best.expect("at least one evaluation");
    BruteForceResult {
        schedule,
        makespan,
        cost,
        energy,
        evaluated,
        wall_time: t0.elapsed(),
        complete,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, Config, ConfigSpace, CostModel};
    use crate::dag::workloads::fig1_dag;
    use crate::predictor::OraclePredictor;
    use crate::solver::anneal::{anneal, AnnealParams};
    use crate::solver::objective::Goal;
    use crate::util::Rng;
    use crate::Predictor;

    /// Small space so exhaustive search is fast: m5.4xlarge only,
    /// ladder {1, 4, 8, 16}, balanced spark.
    fn small_problem() -> Problem {
        let dags = vec![fig1_dag()];
        let mut space = ConfigSpace::with_ladder(&[1, 4, 8, 16]);
        space.configs.retain(|c| c.instance == 0 && c.spark == 1);
        assert_eq!(space.len(), 4);
        let profiles: Vec<_> = dags[0].tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &dags,
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    fn default_objective(p: &Problem, goal: Goal) -> Objective {
        // Baseline: everything on 4 nodes.
        let c = p
            .space
            .configs
            .iter()
            .position(|c| {
                *c == Config {
                    instance: 0,
                    nodes: 4,
                    spark: 1,
                }
            })
            .unwrap();
        let solver = CpSolver::new(Limits::default());
        let (s, _) = solver.solve(p, &vec![c; p.len()]).unwrap();
        Objective::new(goal, s.makespan(p), s.cost(p))
    }

    #[test]
    fn enumerates_entire_space() {
        let p = small_problem();
        let obj = default_objective(&p, Goal::Runtime);
        let r = brute_force(&p, &obj, Limits::default(), Duration::from_secs(120));
        assert!(r.complete);
        assert_eq!(r.evaluated, 4u64.pow(4));
        r.schedule.validate(&p).unwrap();
    }

    #[test]
    fn search_space_size_grows_exponentially() {
        assert_eq!(search_space_size(4, 4), 256.0);
        assert!(search_space_size(10, 96) > 1e19);
        // Fig. 4: "only four jobs in a DAG could result in tens of
        // millions of values" (their space includes schedule orderings)
        assert!(search_space_size(4, 4) < search_space_size(5, 4));
    }

    #[test]
    fn brute_force_at_least_as_good_as_anneal() {
        let p = small_problem();
        let obj = default_objective(&p, Goal::Balanced);
        let bf = brute_force(&p, &obj, Limits::default(), Duration::from_secs(120));
        assert!(bf.complete);
        let mut rng = Rng::new(2);
        let init = vec![p.feasible[0]; p.len()];
        let sa = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
        assert!(
            bf.energy <= sa.energy + 1e-9,
            "BF {} should lower-bound SA {}",
            bf.energy,
            sa.energy
        );
    }

    #[test]
    fn incomplete_under_tiny_budget_still_returns_valid() {
        let p = small_problem();
        let obj = default_objective(&p, Goal::Balanced);
        let r = brute_force(&p, &obj, Limits::inner_loop(), Duration::from_millis(1));
        r.schedule.validate(&p).unwrap();
        assert!(r.evaluated >= 1);
    }
}
