//! Serial schedule-generation scheme (SGS) — the workhorse primitive
//! under both the CP solver's upper bounds and the heuristic baselines.
//!
//! Given a configuration assignment and a priority rule, the serial SGS
//! repeatedly takes the highest-priority *eligible* task (all
//! predecessors placed) and schedules it at the earliest
//! resource-feasible time. For RCPSP, some priority list always generates
//! an optimal active schedule, which is why the CP solver's
//! branch-and-bound searches over SGS insertion orders.

use super::rcpsp::Problem;
use super::schedule::Schedule;
use crate::util::Rng;

/// Priority rules (classic RCPSP dispatch heuristics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// Longest path through the task first (critical-path priority).
    CriticalPath,
    /// Longest processing time first.
    LongestFirst,
    /// Shortest processing time first.
    ShortestFirst,
    /// Most total successors (transitive) first.
    MostSuccessors,
    /// Largest resource demand x duration ("hardest to pack", Graphene's
    /// troublesome-task intuition).
    HardestToPack,
}

/// Every static rule, in the order `multistart_sgs` tries them.
pub const ALL_RULES: &[Rule] = &[
    Rule::CriticalPath,
    Rule::LongestFirst,
    Rule::ShortestFirst,
    Rule::MostSuccessors,
    Rule::HardestToPack,
];

/// Priority value per task (higher = schedule earlier).
pub fn priorities(p: &Problem, assignment: &[usize], rule: Rule) -> Vec<f64> {
    let durations: Vec<f64> = (0..p.len())
        .map(|t| p.duration(t, assignment[t]))
        .collect();
    match rule {
        Rule::CriticalPath => {
            // bottom level: longest path from task start to sink
            let order = p.topo_order();
            let mut bottom = vec![0.0f64; p.len()];
            for &u in order.iter().rev() {
                bottom[u] = durations[u]
                    + p.succs(u)
                        .iter()
                        .map(|&v| bottom[v])
                        .fold(0.0f64, f64::max);
            }
            bottom
        }
        Rule::LongestFirst => durations,
        Rule::ShortestFirst => durations.iter().map(|d| -d).collect(),
        Rule::MostSuccessors => {
            let order = p.topo_order();
            let mut count = vec![0.0f64; p.len()];
            for &u in order.iter().rev() {
                count[u] = p.succs(u).len() as f64
                    + p.succs(u).iter().map(|&v| count[v]).sum::<f64>();
            }
            count
        }
        Rule::HardestToPack => (0..p.len())
            .map(|t| {
                let (cpu, mem) = p.demand(assignment[t]);
                (cpu / p.capacity.vcpus + mem / p.capacity.memory_gb) * durations[t]
            })
            .collect(),
    }
}

/// Resource timeline of placed rectangular tasks.
pub struct Timeline {
    /// (start, end, cpu, mem) of each placed task.
    placed: Vec<(f64, f64, f64, f64)>,
    cap_cpu: f64,
    cap_mem: f64,
}

impl Timeline {
    /// Empty timeline with the given capacity.
    pub fn new(cap_cpu: f64, cap_mem: f64) -> Self {
        Timeline {
            placed: Vec::new(),
            cap_cpu,
            cap_mem,
        }
    }

    /// Can a (cpu, mem) demand run throughout [s, s+d)?
    fn fits(&self, s: f64, d: f64, cpu: f64, mem: f64) -> bool {
        // Capacity must hold at every event point in the window; events
        // are the window start and starts of overlapping placed tasks.
        let e = s + d;
        let mut points = vec![s];
        for &(ps, pe, _, _) in &self.placed {
            if ps > s && ps < e && pe > s {
                points.push(ps);
            }
        }
        for &point in &points {
            let mut used_cpu = cpu;
            let mut used_mem = mem;
            for &(ps, pe, pc, pm) in &self.placed {
                if ps <= point + 1e-9 && point + 1e-9 < pe {
                    used_cpu += pc;
                    used_mem += pm;
                }
            }
            if used_cpu > self.cap_cpu + 1e-6 || used_mem > self.cap_mem + 1e-6 {
                return false;
            }
        }
        true
    }

    /// Earliest s >= est such that the demand fits throughout [s, s+d).
    pub fn earliest_fit(&self, est: f64, d: f64, cpu: f64, mem: f64) -> f64 {
        if self.fits(est, d, cpu, mem) {
            return est;
        }
        // Candidate starts: ends of placed tasks after est, sorted.
        let mut candidates: Vec<f64> = self
            .placed
            .iter()
            .map(|&(_, e, _, _)| e)
            .filter(|&e| e > est)
            .collect();
        candidates.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for s in candidates {
            if self.fits(s, d, cpu, mem) {
                return s;
            }
        }
        // Fallback: after everything ends (always feasible for a demand
        // that fits capacity alone).
        self.placed
            .iter()
            .map(|&(_, e, _, _)| e)
            .fold(est, f64::max)
    }

    /// Reserve a (cpu, mem) rectangle over [s, s+d).
    pub fn place(&mut self, s: f64, d: f64, cpu: f64, mem: f64) {
        self.placed.push((s, s + d, cpu, mem));
    }

    /// Remove the most recently placed task (backtracking support for the
    /// CP solver's DFS).
    pub fn pop(&mut self) {
        self.placed.pop();
    }

    /// Keep only the first `len` placements (prefix-reuse support for the
    /// incremental evaluator: placements are pushed in SGS order, so
    /// truncating to `len` restores the timeline state after the first
    /// `len` insertions).
    pub fn truncate(&mut self, len: usize) {
        self.placed.truncate(len);
    }

    /// Number of placed rectangles.
    pub fn len(&self) -> usize {
        self.placed.len()
    }

    /// Whether nothing is placed.
    pub fn is_empty(&self) -> bool {
        self.placed.is_empty()
    }
}

/// The task *selection order* of a serial SGS run under a static priority
/// vector: repeatedly pick the highest-priority eligible task (ties break
/// on task index). Eligibility depends only on precedence — not on
/// durations or placements — so the order is a pure function of
/// (precedence, prio). This is the invariant the incremental evaluator
/// exploits: changing a task's configuration never changes the order.
pub fn selection_order(p: &Problem, prio: &[f64]) -> Vec<usize> {
    let n = p.len();
    let mut done = vec![false; n];
    let mut n_unplaced_preds: Vec<usize> = (0..n).map(|t| p.preds(t).len()).collect();
    let mut order = Vec::with_capacity(n);
    while order.len() < n {
        // Highest-priority eligible task.
        let mut best: Option<usize> = None;
        for t in 0..n {
            if !done[t] && n_unplaced_preds[t] == 0 {
                match best {
                    None => best = Some(t),
                    Some(b) if prio[t] > prio[b] => best = Some(t),
                    _ => {}
                }
            }
        }
        let t = best.expect("acyclic problem always has an eligible task");
        done[t] = true;
        order.push(t);
        for &v in p.succs(t) {
            n_unplaced_preds[v] -= 1;
        }
    }
    order
}

/// Serial SGS with a static priority vector. Ties break on task index so
/// results are deterministic. The timeline is seeded with the problem's
/// occupancy reservations (`Problem::preplaced`), so a seeded problem is
/// packed into the residual capacity; with no seed this is the classic
/// serial SGS.
pub fn serial_sgs(p: &Problem, assignment: &[usize], prio: &[f64]) -> Schedule {
    let n = p.len();
    let order = selection_order(p, prio);
    let mut start = vec![0.0f64; n];
    let mut timeline = Timeline::new(p.capacity.vcpus, p.capacity.memory_gb);
    for &(s, d, cpu, mem) in &p.preplaced {
        timeline.place(s, d, cpu, mem);
    }

    for &t in &order {
        let est = p.preds(t)
            .iter()
            .map(|&q| start[q] + p.duration(q, assignment[q]))
            .fold(p.release[t], f64::max);
        let d = p.duration(t, assignment[t]);
        let (cpu, mem) = p.demand(assignment[t]);
        let s = timeline.earliest_fit(est, d, cpu, mem);
        timeline.place(s, d, cpu, mem);
        start[t] = s;
    }

    Schedule {
        assignment: assignment.to_vec(),
        start,
        optimal: false,
    }
}

/// Incremental schedule evaluator for the SA inner loop: a serial SGS
/// with a *frozen* selection order that, for each new configuration
/// assignment, re-places only the suffix starting at the first task whose
/// configuration changed (the affected cone of the perturbation, closed
/// under the placement order).
///
/// Soundness: with a static priority vector the SGS selection order is
/// duration-independent (see [`selection_order`]), and the placement of
/// position `i` depends only on the placements of positions `0..i` and
/// the durations/demands of those tasks. A proposal that perturbs task
/// set `S` therefore leaves every position before the first occurrence of
/// `S` in the order bit-identical — those placements are reused from the
/// retained [`Timeline`] prefix.
///
/// `evaluate` is exactly equivalent to `serial_sgs(p, assignment, prio0)`
/// with the frozen priorities (asserted by a property test), at
/// O(suffix) instead of O(n) timeline work per proposal — the SA hot
/// path perturbs 1-3 tasks, so the expected suffix is short.
pub struct IncrementalSgs {
    /// Frozen selection order (critical-path priorities of the initial
    /// assignment).
    order: Vec<usize>,
    /// Start time per task from the most recent evaluation.
    start: Vec<f64>,
    /// The most recently evaluated assignment (usize::MAX = never).
    last: Vec<usize>,
    /// Occupancy reservations of the problem, retained through every
    /// truncate (continuous admission packs proposals into the gaps).
    base_len: usize,
    timeline: Timeline,
}

impl IncrementalSgs {
    /// Freeze the selection order for `initial` and seed the timeline
    /// with the problem's occupancy reservations.
    pub fn new(p: &Problem, initial: &[usize]) -> IncrementalSgs {
        let prio = priorities(p, initial, Rule::CriticalPath);
        let mut timeline = Timeline::new(p.capacity.vcpus, p.capacity.memory_gb);
        for &(s, d, cpu, mem) in &p.preplaced {
            timeline.place(s, d, cpu, mem);
        }
        IncrementalSgs {
            order: selection_order(p, &prio),
            start: vec![0.0; p.len()],
            last: vec![usize::MAX; p.len()],
            base_len: p.preplaced.len(),
            timeline,
        }
    }

    /// Schedule `assignment`, reusing the placement prefix shared with
    /// the previously evaluated assignment. Returns the makespan.
    pub fn evaluate(&mut self, p: &Problem, assignment: &[usize]) -> f64 {
        let n = p.len();
        assert_eq!(assignment.len(), n);
        let first_changed = self
            .order
            .iter()
            .position(|&t| assignment[t] != self.last[t])
            .unwrap_or(n);
        self.timeline.truncate(self.base_len + first_changed);
        for i in first_changed..n {
            let t = self.order[i];
            let est = p
                .preds(t)
                .iter()
                .map(|&q| self.start[q] + p.duration(q, assignment[q]))
                .fold(p.release[t], f64::max);
            let d = p.duration(t, assignment[t]);
            let (cpu, mem) = p.demand(assignment[t]);
            let s = self.timeline.earliest_fit(est, d, cpu, mem);
            self.timeline.place(s, d, cpu, mem);
            self.start[t] = s;
        }
        self.last.copy_from_slice(assignment);
        (0..n)
            .map(|t| self.start[t] + p.duration(t, assignment[t]))
            .fold(0.0, f64::max)
    }

    /// Materialize the schedule of the most recent `evaluate` call.
    /// `assignment` must be the one passed to that call.
    pub fn schedule(&self, assignment: &[usize]) -> Schedule {
        debug_assert_eq!(assignment, &self.last[..]);
        Schedule {
            assignment: assignment.to_vec(),
            start: self.start.clone(),
            optimal: false,
        }
    }
}

/// Suffix-cone evaluator for mid-flight re-planning (`sim::replan`): a
/// serial SGS restricted to the *active* cone of a problem — the tasks
/// that have not started yet when a replan triggers — packed around a
/// timeline pre-seeded with the rectangles of committed work (running or
/// finished tasks, capacity-outage blockers).
///
/// Same prefix-reuse contract as [`IncrementalSgs`]: the selection order
/// over the cone is frozen (critical-path priorities of the incumbent
/// assignment, filtered to the cone — precedence-consistency is
/// preserved by filtering), and a proposal that changes configurations of
/// cone set `S` re-places only the order suffix from the first member of
/// `S`, truncating the [`Timeline`] back to the shared prefix. The
/// pre-seeded base rectangles are never truncated away.
///
/// Precedence against committed predecessors uses their *realized* end
/// times (`fixed_end`), and every cone task is floored at the replan
/// instant — a replanned task cannot start in the past.
pub struct SuffixSgs {
    /// Frozen selection order restricted to the active cone.
    order: Vec<usize>,
    /// Replan instant: earliest allowed start for any cone task.
    floor: f64,
    /// Realized end per committed task (NaN/unused for cone tasks).
    fixed_end: Vec<f64>,
    /// Cone membership per task.
    active: Vec<bool>,
    /// Pre-seeded rectangles retained through every truncate.
    base_len: usize,
    start: Vec<f64>,
    last: Vec<usize>,
    timeline: Timeline,
}

impl SuffixSgs {
    /// `incumbent` fixes the frozen priorities; `active_tasks` is the
    /// cone (must be closed under successors — unstarted tasks always
    /// are); `fixed_end[t]` is the realized end of every committed task;
    /// `preplaced` are (start, duration, cpu, mem) rectangles of
    /// committed work the cone must pack around. The problem's own
    /// occupancy reservations (`Problem::preplaced`, continuous
    /// admission) are seeded in addition to `preplaced`, so a replan
    /// inside a continuously admitted round keeps packing around the
    /// other rounds' in-flight work.
    pub fn new(
        p: &Problem,
        incumbent: &[usize],
        active_tasks: &[usize],
        floor: f64,
        fixed_end: &[f64],
        preplaced: &[(f64, f64, f64, f64)],
    ) -> SuffixSgs {
        let prio = priorities(p, incumbent, Rule::CriticalPath);
        let mut active = vec![false; p.len()];
        for &t in active_tasks {
            active[t] = true;
        }
        let order: Vec<usize> = selection_order(p, &prio)
            .into_iter()
            .filter(|&t| active[t])
            .collect();
        let mut timeline = Timeline::new(p.capacity.vcpus, p.capacity.memory_gb);
        for &(s, d, cpu, mem) in &p.preplaced {
            timeline.place(s, d, cpu, mem);
        }
        for &(s, d, cpu, mem) in preplaced {
            timeline.place(s, d, cpu, mem);
        }
        SuffixSgs {
            order,
            floor,
            fixed_end: fixed_end.to_vec(),
            active,
            base_len: p.preplaced.len() + preplaced.len(),
            start: vec![0.0; p.len()],
            last: vec![usize::MAX; p.len()],
            timeline,
        }
    }

    /// Schedule the cone under `assignment` (full-length vector; entries
    /// outside the cone are ignored), reusing the placement prefix shared
    /// with the previous evaluation. Returns the max realized-projected
    /// end over the cone (at least `floor`).
    pub fn evaluate(&mut self, p: &Problem, assignment: &[usize]) -> f64 {
        assert_eq!(assignment.len(), p.len());
        let first_changed = self
            .order
            .iter()
            .position(|&t| assignment[t] != self.last[t])
            .unwrap_or(self.order.len());
        self.timeline.truncate(self.base_len + first_changed);
        for i in first_changed..self.order.len() {
            let t = self.order[i];
            let est = p
                .preds(t)
                .iter()
                .map(|&q| {
                    if self.active[q] {
                        self.start[q] + p.duration(q, assignment[q])
                    } else {
                        self.fixed_end[q]
                    }
                })
                .fold(p.release[t].max(self.floor), f64::max);
            let d = p.duration(t, assignment[t]);
            let (cpu, mem) = p.demand(assignment[t]);
            let s = self.timeline.earliest_fit(est, d, cpu, mem);
            self.timeline.place(s, d, cpu, mem);
            self.start[t] = s;
        }
        for &t in &self.order {
            self.last[t] = assignment[t];
        }
        self.order
            .iter()
            .map(|&t| self.start[t] + p.duration(t, assignment[t]))
            .fold(self.floor, f64::max)
    }

    /// Planned start of a cone task from the most recent `evaluate`.
    pub fn start_of(&self, t: usize) -> f64 {
        self.start[t]
    }
}

/// Best schedule over all static rules plus `extra_random` noisy
/// restarts — the CP solver's initial upper bound and the anytime
/// fallback at scale.
pub fn multistart_sgs(
    p: &Problem,
    assignment: &[usize],
    extra_random: usize,
    rng: &mut Rng,
) -> Schedule {
    let mut best: Option<(f64, Schedule)> = None;
    let mut consider = |s: Schedule, p: &Problem| {
        let m = s.makespan(p);
        if best.as_ref().map_or(true, |(bm, _)| m < *bm) {
            best = Some((m, s));
        }
    };
    for &rule in ALL_RULES {
        let prio = priorities(p, assignment, rule);
        consider(serial_sgs(p, assignment, &prio), p);
    }
    // Noisy critical-path restarts.
    let base = priorities(p, assignment, Rule::CriticalPath);
    let scale = base.iter().cloned().fold(0.0f64, f64::max).max(1.0);
    for _ in 0..extra_random {
        let noisy: Vec<f64> = base
            .iter()
            .map(|&b| b + rng.uniform(0.0, 0.3 * scale))
            .collect();
        consider(serial_sgs(p, assignment, &noisy), p);
    }
    best.expect("at least one rule ran").1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::generator::{arbitrary_dag, fig10_batch};
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::util::propcheck;
    use crate::Predictor;

    fn problem_from(dags: Vec<crate::Dag>) -> Problem {
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags
            .iter()
            .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
            .collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let releases = vec![0.0; dags.len()];
        Problem::new(
            &dags,
            &releases,
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    #[test]
    fn sgs_schedules_are_valid_for_all_rules() -> anyhow::Result<()> {
        use anyhow::Context;
        let p = problem_from(vec![dag1(), dag2()]);
        let assignment = vec![p.feasible[0]; p.len()];
        for &rule in ALL_RULES {
            let prio = priorities(&p, &assignment, rule);
            let s = serial_sgs(&p, &assignment, &prio);
            s.validate(&p).with_context(|| format!("rule {rule:?}"))?;
        }
        Ok(())
    }

    #[test]
    fn selection_order_is_duration_independent() {
        // The invariant IncrementalSgs rests on: perturbing configs (and
        // hence durations/demands) never changes the selection order.
        let p = problem_from(vec![dag1(), dag2()]);
        let a0 = vec![p.feasible[0]; p.len()];
        let prio = priorities(&p, &a0, Rule::CriticalPath);
        let order = selection_order(&p, &prio);
        // Precedence-consistent and a permutation.
        let mut pos = vec![0usize; p.len()];
        for (i, &t) in order.iter().enumerate() {
            pos[t] = i;
        }
        for &(a, b) in &p.precedence {
            assert!(pos[a] < pos[b], "order violates precedence {a}->{b}");
        }
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..p.len()).collect::<Vec<_>>());
    }

    #[test]
    fn property_incremental_matches_full_sgs() {
        // IncrementalSgs::evaluate must be bit-identical to a full
        // serial_sgs pass under the frozen priorities, for arbitrary
        // perturbation sequences.
        propcheck::check(20, |rng| {
            let dag = arbitrary_dag(rng, 12);
            let p = problem_from(vec![dag]);
            let initial: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let prio0 = priorities(&p, &initial, Rule::CriticalPath);
            let mut inc = IncrementalSgs::new(&p, &initial);
            let mut current = initial;
            for step in 0..12 {
                let makespan = inc.evaluate(&p, &current);
                let full = serial_sgs(&p, &current, &prio0);
                if (makespan - full.makespan(&p)).abs() > 1e-12 {
                    return Err(format!(
                        "step {step}: incremental {makespan} != full {}",
                        full.makespan(&p)
                    ));
                }
                let sched = inc.schedule(&current);
                if sched.start != full.start {
                    return Err(format!("step {step}: start vectors diverge"));
                }
                sched.validate(&p).map_err(|e| e.to_string())?;
                // Perturb 1-2 tasks like the SA proposal kernel does.
                for _ in 0..rng.range(1, 2) {
                    let t = rng.below(p.len());
                    current[t] = p.feasible[rng.below(p.feasible.len())];
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_suffix_sgs_matches_full_sgs_on_trivial_cone() {
        // With every task active, no pre-placed work and floor 0, the
        // suffix evaluator degenerates to a plain frozen-priority serial
        // SGS — pin the equivalence for arbitrary perturbation sequences.
        propcheck::check(15, |rng| {
            let dag = arbitrary_dag(rng, 10);
            let p = problem_from(vec![dag]);
            let initial: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let prio0 = priorities(&p, &initial, Rule::CriticalPath);
            let all: Vec<usize> = (0..p.len()).collect();
            let fixed_end = vec![f64::NAN; p.len()];
            let mut sfx = SuffixSgs::new(&p, &initial, &all, 0.0, &fixed_end, &[]);
            let mut current = initial;
            for step in 0..8 {
                let makespan = sfx.evaluate(&p, &current);
                let full = serial_sgs(&p, &current, &prio0);
                if (makespan - full.makespan(&p)).abs() > 1e-12 {
                    return Err(format!(
                        "step {step}: suffix {makespan} != full {}",
                        full.makespan(&p)
                    ));
                }
                for (t, &s) in full.start.iter().enumerate() {
                    if (sfx.start_of(t) - s).abs() > 1e-12 {
                        return Err(format!("step {step}: task {t} start diverges"));
                    }
                }
                let t = rng.below(p.len());
                current[t] = p.feasible[rng.below(p.feasible.len())];
            }
            Ok(())
        });
    }

    #[test]
    fn property_suffix_sgs_respects_floor_committed_work_and_precedence() {
        propcheck::check(15, |rng| {
            let dag = arbitrary_dag(rng, 12);
            let p = problem_from(vec![dag]);
            let assignment: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let prio = priorities(&p, &assignment, Rule::CriticalPath);
            let full = serial_sgs(&p, &assignment, &prio);
            // Commit everything started before a random instant.
            let makespan = full.makespan(&p);
            let floor = rng.uniform(0.0, makespan);
            let committed: Vec<bool> = (0..p.len())
                .map(|t| full.start[t] < floor - 1e-9)
                .collect();
            let active: Vec<usize> =
                (0..p.len()).filter(|&t| !committed[t]).collect();
            if active.is_empty() {
                return Ok(());
            }
            let fixed_end: Vec<f64> = (0..p.len())
                .map(|t| full.start[t] + p.duration(t, assignment[t]))
                .collect();
            let preplaced: Vec<(f64, f64, f64, f64)> = (0..p.len())
                .filter(|&t| committed[t])
                .map(|t| {
                    let (cpu, mem) = p.demand(assignment[t]);
                    (full.start[t], p.duration(t, assignment[t]), cpu, mem)
                })
                .collect();
            let mut sfx =
                SuffixSgs::new(&p, &assignment, &active, floor, &fixed_end, &preplaced);
            // Re-plan the cone under a perturbed assignment.
            let mut cone_assignment = assignment.clone();
            for &t in &active {
                if rng.chance(0.5) {
                    cone_assignment[t] = p.feasible[rng.below(p.feasible.len())];
                }
            }
            sfx.evaluate(&p, &cone_assignment);
            // Cone starts respect the floor and realized precedence.
            for &t in &active {
                if sfx.start_of(t) + 1e-9 < floor {
                    return Err(format!(
                        "cone task {t} starts {} before floor {floor}",
                        sfx.start_of(t)
                    ));
                }
                for &q in p.preds(t) {
                    let q_end = if committed[q] {
                        fixed_end[q]
                    } else {
                        sfx.start_of(q) + p.duration(q, cone_assignment[q])
                    };
                    if sfx.start_of(t) + 1e-6 < q_end {
                        return Err(format!(
                            "cone task {t} starts before predecessor {q} ends"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sgs_beats_sequential() {
        let p = problem_from(vec![dag2()]);
        // pick a small config so several tasks fit side by side
        let small = *p
            .feasible
            .iter()
            .min_by(|&&a, &&b| p.demand(a).0.partial_cmp(&p.demand(b).0).unwrap())
            .unwrap();
        let assignment = vec![small; p.len()];
        let prio = priorities(&p, &assignment, Rule::CriticalPath);
        let s = serial_sgs(&p, &assignment, &prio);
        let sequential: f64 = (0..p.len()).map(|t| p.duration(t, assignment[t])).sum();
        assert!(
            s.makespan(&p) < sequential * 0.8,
            "SGS should exploit DAG2 parallelism: {} vs {}",
            s.makespan(&p),
            sequential
        );
    }

    #[test]
    fn makespan_at_least_critical_path() {
        let p = problem_from(vec![dag1()]);
        let assignment = vec![p.feasible[0]; p.len()];
        let prio = priorities(&p, &assignment, Rule::CriticalPath);
        let s = serial_sgs(&p, &assignment, &prio);
        assert!(s.makespan(&p) + 1e-6 >= p.critical_path_lb(&assignment));
    }

    #[test]
    fn multistart_never_worse_than_single_rule() {
        let mut rng = Rng::new(3);
        let p = problem_from(vec![dag1(), dag2()]);
        let assignment = vec![p.feasible[1]; p.len()];
        let multi = multistart_sgs(&p, &assignment, 10, &mut rng);
        for &rule in ALL_RULES {
            let prio = priorities(&p, &assignment, rule);
            let single = serial_sgs(&p, &assignment, &prio);
            assert!(multi.makespan(&p) <= single.makespan(&p) + 1e-6);
        }
    }

    #[test]
    fn property_sgs_valid_on_random_dags() {
        propcheck::check(40, |rng| {
            let dag = arbitrary_dag(rng, 15);
            let p = problem_from(vec![dag]);
            let assignment: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let rule = *rng.choice(ALL_RULES);
            let prio = priorities(&p, &assignment, rule);
            let s = serial_sgs(&p, &assignment, &prio);
            s.validate(&p).map_err(|e| e.to_string())?;
            if s.makespan(&p) + 1e-6 < p.lower_bound(&assignment) {
                return Err(format!(
                    "makespan {} below lower bound {}",
                    s.makespan(&p),
                    p.lower_bound(&assignment)
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn property_fig10_batches_schedule_cleanly() {
        propcheck::check(10, |rng| {
            let dags = fig10_batch(rng, 3);
            let p = problem_from(dags);
            let assignment = vec![p.feasible[0]; p.len()];
            let prio = priorities(&p, &assignment, Rule::MostSuccessors);
            let s = serial_sgs(&p, &assignment, &prio);
            s.validate(&p).map_err(|e| e.to_string())
        });
    }

    #[test]
    fn occupancy_seed_pushes_schedule_into_residual_capacity() {
        // A full-capacity blocker over [0, 100) plus an admission floor:
        // every placement must land at or after the blocker clears.
        let p = problem_from(vec![dag1()]);
        let full = (0.0, 100.0, p.capacity.vcpus, p.capacity.memory_gb);
        let seeded = problem_from(vec![dag1()]).with_occupancy(vec![full], 40.0);
        let assignment = vec![p.feasible[0]; p.len()];
        let prio = priorities(&seeded, &assignment, Rule::CriticalPath);
        let s = serial_sgs(&seeded, &assignment, &prio);
        for t in 0..seeded.len() {
            assert!(
                s.start[t] + 1e-9 >= 100.0,
                "task {t} starts {} inside the reserved window",
                s.start[t]
            );
        }
        s.validate(&seeded).unwrap();
        // The same plan shifted by the blocker: unseeded makespan + 100.
        let unseeded = serial_sgs(&p, &assignment, &prio);
        assert!((s.makespan(&seeded) - (unseeded.makespan(&p) + 100.0)).abs() < 1e-6);
    }

    #[test]
    fn occupancy_floor_alone_delays_first_start() {
        let seeded = problem_from(vec![dag1()]).with_occupancy(Vec::new(), 50.0);
        let assignment = vec![seeded.feasible[0]; seeded.len()];
        let prio = priorities(&seeded, &assignment, Rule::CriticalPath);
        let s = serial_sgs(&seeded, &assignment, &prio);
        for t in 0..seeded.len() {
            assert!(s.start[t] + 1e-9 >= 50.0);
        }
        s.validate(&seeded).unwrap();
    }

    #[test]
    fn property_incremental_matches_full_sgs_on_seeded_problems() {
        // The prefix-reuse contract must hold with a non-empty occupancy
        // seed: IncrementalSgs over a seeded problem stays bit-identical
        // to the full seeded serial SGS across perturbation sequences.
        propcheck::check(10, |rng| {
            let dag = arbitrary_dag(rng, 10);
            let p = problem_from(vec![dag]);
            let cpu = p.capacity.vcpus * rng.uniform(0.3, 0.9);
            let mem = p.capacity.memory_gb * rng.uniform(0.3, 0.9);
            let seed = vec![
                (0.0, rng.uniform(10.0, 200.0), cpu, mem),
                (rng.uniform(50.0, 300.0), rng.uniform(10.0, 200.0), cpu * 0.5, mem * 0.5),
            ];
            let p = p.with_occupancy(seed, rng.uniform(0.0, 100.0));
            let initial: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let prio0 = priorities(&p, &initial, Rule::CriticalPath);
            let mut inc = IncrementalSgs::new(&p, &initial);
            let mut current = initial;
            for step in 0..8 {
                let makespan = inc.evaluate(&p, &current);
                let full = serial_sgs(&p, &current, &prio0);
                if (makespan - full.makespan(&p)).abs() > 1e-12 {
                    return Err(format!(
                        "step {step}: seeded incremental {makespan} != full {}",
                        full.makespan(&p)
                    ));
                }
                if inc.schedule(&current).start != full.start {
                    return Err(format!("step {step}: seeded start vectors diverge"));
                }
                let t = rng.below(p.len());
                current[t] = p.feasible[rng.below(p.feasible.len())];
            }
            Ok(())
        });
    }

    #[test]
    fn timeline_earliest_fit_respects_capacity() {
        let mut tl = Timeline::new(10.0, 100.0);
        tl.place(0.0, 10.0, 8.0, 50.0);
        // demand 4 cpus cannot run concurrently with the 8-cpu task
        let s = tl.earliest_fit(0.0, 5.0, 4.0, 10.0);
        assert_eq!(s, 10.0);
        // but 2 cpus can
        let s = tl.earliest_fit(0.0, 5.0, 2.0, 10.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn timeline_finds_gap_between_tasks() {
        let mut tl = Timeline::new(10.0, 100.0);
        tl.place(0.0, 5.0, 10.0, 10.0);
        tl.place(8.0, 5.0, 10.0, 10.0);
        // a 3-second task fits exactly in the [5, 8) gap
        let s = tl.earliest_fit(0.0, 3.0, 10.0, 10.0);
        assert_eq!(s, 5.0);
        // a 4-second task does not; next fit is after the second task
        let s = tl.earliest_fit(0.0, 4.0, 10.0, 10.0);
        assert_eq!(s, 13.0);
    }
}
