//! Serial schedule-generation scheme (SGS) — the workhorse primitive
//! under both the CP solver's upper bounds and the heuristic baselines.
//!
//! Given a configuration assignment and a priority rule, the serial SGS
//! repeatedly takes the highest-priority *eligible* task (all
//! predecessors placed) and schedules it at the earliest
//! resource-feasible time. For RCPSP, some priority list always generates
//! an optimal active schedule, which is why the CP solver's
//! branch-and-bound searches over SGS insertion orders.
//!
//! All placement queries go through the shared block-indexed
//! [`Timeline`] kernel (`solver::timeline`); the incremental evaluators
//! reuse shared placement prefixes via its checkpoint/rollback protocol.
//! A full pass is O(n log n + Σk) — heap-based task selection plus the
//! kernel's aggregate-skipping sweeps — which is what lets the
//! `scaling_timeline` bench push serial SGS to 10⁵-task DAGs.

use anyhow::{anyhow, Result};

use super::rcpsp::Problem;
use super::schedule::Schedule;
use super::timeline::Mark;
pub use super::timeline::Timeline;
use crate::util::Rng;

/// Priority rules (classic RCPSP dispatch heuristics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// Longest path through the task first (critical-path priority).
    CriticalPath,
    /// Longest processing time first.
    LongestFirst,
    /// Shortest processing time first.
    ShortestFirst,
    /// Most total successors (transitive) first.
    MostSuccessors,
    /// Largest resource demand x duration ("hardest to pack", Graphene's
    /// troublesome-task intuition).
    HardestToPack,
    /// DAGPS-style troublesome-*subgraph* priority: maximal connected
    /// groups of troublesome tasks (long × resource-skewed × deep, see
    /// [`troublesome_scores`]) are boosted above everything else, ranked
    /// by their peak score, so whole heavy chains are packed first while
    /// the remaining tasks fill in by criticality. Unlike the per-task
    /// [`Rule::HardestToPack`], the boost is subgraph-aware: a
    /// troublesome task drags its troublesome ancestors/descendants to
    /// the front with it.
    Troublesome,
}

/// Every static rule, in the order `multistart_sgs` tries them.
/// [`Rule::Troublesome`] is deliberately *not* part of the multistart
/// portfolio: it is the DAGPS baseline's rule and the opt-in seeding
/// rule, and keeping it out preserves the CP solver's pinned initial
/// upper bounds.
pub const ALL_RULES: &[Rule] = &[
    Rule::CriticalPath,
    Rule::LongestFirst,
    Rule::ShortestFirst,
    Rule::MostSuccessors,
    Rule::HardestToPack,
];

/// Priority value per task (higher = schedule earlier).
pub fn priorities(p: &Problem, assignment: &[usize], rule: Rule) -> Vec<f64> {
    let durations: Vec<f64> = (0..p.len())
        .map(|t| p.duration(t, assignment[t]))
        .collect();
    match rule {
        Rule::CriticalPath => {
            // bottom level: longest path from task start to sink
            let order = p.topo_order();
            let mut bottom = vec![0.0f64; p.len()];
            for &u in order.iter().rev() {
                bottom[u] = durations[u]
                    + p.succs(u)
                        .iter()
                        .map(|&v| bottom[v])
                        .fold(0.0f64, f64::max);
            }
            bottom
        }
        Rule::LongestFirst => durations,
        Rule::ShortestFirst => durations.iter().map(|d| -d).collect(),
        Rule::MostSuccessors => {
            let order = p.topo_order();
            let mut count = vec![0.0f64; p.len()];
            for &u in order.iter().rev() {
                count[u] = p.succs(u).len() as f64
                    + p.succs(u).iter().map(|&v| count[v]).sum::<f64>();
            }
            count
        }
        Rule::HardestToPack => (0..p.len())
            .map(|t| {
                let (cpu, mem) = p.demand(assignment[t]);
                (cpu / p.capacity.vcpus + mem / p.capacity.memory_gb) * durations[t]
            })
            .collect(),
        Rule::Troublesome => {
            let comps = troublesome_components(p, &troublesome_scores(p, assignment));
            let mut prio = priorities(p, assignment, Rule::CriticalPath);
            // Boost strictly dominates every base priority, and each
            // component's boost dominates the next-ranked component's, so
            // subgraphs are packed whole, in rank order, before any
            // non-troublesome task.
            let boost = 2.0 * prio.iter().cloned().fold(0.0f64, f64::max).max(1.0);
            let k = comps.len();
            for (rank, comp) in comps.iter().enumerate() {
                for &t in comp {
                    prio[t] += boost * (k - rank) as f64;
                }
            }
            prio
        }
    }
}

/// DAGPS/Graphene-style per-task troublesome score: normalized duration
/// × resource skew × normalized depth.
///
/// - duration is the task's duration under `assignment`, normalized by
///   the longest task duration (degenerate — non-finite or non-positive
///   — durations are treated as zero);
/// - skew is `max(cpu_frac, mem_frac) / mean(cpu_frac, mem_frac)` of the
///   assigned configuration's demand against cluster capacity, in
///   `[1, 2]` — a balanced demand scores 1, a single-resource hog
///   approaches 2;
/// - depth is the task's bottom level (longest downstream path including
///   itself), normalized by the deepest bottom level.
///
/// The score is a pure per-task function of durations, demands and DAG
/// structure, so it is deterministic and stable under task-index
/// permutation. An all-degenerate problem scores all zeros.
pub fn troublesome_scores(p: &Problem, assignment: &[usize]) -> Vec<f64> {
    let n = p.len();
    let durations: Vec<f64> = (0..n)
        .map(|t| {
            let d = p.duration(t, assignment[t]);
            if d.is_finite() && d > 0.0 {
                d
            } else {
                0.0
            }
        })
        .collect();
    let order = p.topo_order();
    let mut bottom = vec![0.0f64; n];
    for &u in order.iter().rev() {
        bottom[u] = durations[u]
            + p.succs(u)
                .iter()
                .map(|&v| bottom[v])
                .fold(0.0f64, f64::max);
    }
    let max_d = durations.iter().cloned().fold(0.0f64, f64::max);
    let max_b = bottom.iter().cloned().fold(0.0f64, f64::max);
    if max_d <= 0.0 || max_b <= 0.0 {
        return vec![0.0; n];
    }
    (0..n)
        .map(|t| {
            let (cpu, mem) = p.demand(assignment[t]);
            let cpu_frac = cpu / p.capacity.vcpus;
            let mem_frac = mem / p.capacity.memory_gb;
            let mean = 0.5 * (cpu_frac + mem_frac);
            let skew = if mean > 0.0 {
                cpu_frac.max(mem_frac) / mean
            } else {
                1.0
            };
            (durations[t] / max_d) * skew * (bottom[t] / max_b)
        })
        .collect()
}

/// Maximal troublesome subgraphs for [`Rule::Troublesome`]: a task is
/// troublesome when its score is at least half the peak score, and each
/// subgraph is a maximal precedence-connected group of troublesome tasks
/// (a troublesome task plus its troublesome ancestors/descendants,
/// transitively). Components are returned ranked by their peak member
/// score (descending; ties break on lowest member index), each with its
/// members sorted by task index. Returns no components when every score
/// is zero.
pub fn troublesome_components(p: &Problem, scores: &[f64]) -> Vec<Vec<usize>> {
    let n = p.len();
    let max_s = scores.iter().cloned().fold(0.0f64, f64::max);
    if max_s <= 0.0 {
        return Vec::new();
    }
    let threshold = 0.5 * max_s;
    let marked: Vec<bool> = scores.iter().map(|&s| s >= threshold).collect();
    // Seeding from the highest-score unclaimed task makes the component
    // order the rank order: a component's first seed carries its peak.
    let mut seeds: Vec<usize> = (0..n).filter(|&t| marked[t]).collect();
    seeds.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    let mut claimed = vec![false; n];
    let mut comps = Vec::new();
    for &seed in &seeds {
        if claimed[seed] {
            continue;
        }
        claimed[seed] = true;
        let mut members = vec![seed];
        let mut head = 0;
        while head < members.len() {
            let u = members[head];
            head += 1;
            for &v in p.preds(u).iter().chain(p.succs(u).iter()) {
                if marked[v] && !claimed[v] {
                    claimed[v] = true;
                    members.push(v);
                }
            }
        }
        members.sort_unstable();
        comps.push(members);
    }
    comps
}

/// The task *selection order* of a serial SGS run under a static priority
/// vector: repeatedly pick the highest-priority eligible task (ties break
/// on task index). Eligibility depends only on precedence — not on
/// durations or placements — so the order is a pure function of
/// (precedence, prio). This is the invariant the incremental evaluator
/// exploits: changing a task's configuration never changes the order.
///
/// Implemented as Kahn's algorithm over a max-heap — O((n + E) log n)
/// instead of the historical O(n²) full rescan per pick, which was the
/// hidden quadratic blocker for 10⁴–10⁵-task DAGs once the timeline
/// kernel itself went sub-quadratic. Integer-valued priorities within a
/// bounded range (count-like rules) take a heap-free counting-bucket
/// Kahn instead ([`selection_order_buckets`]) — O(n + E) when the rule's
/// priorities are non-increasing along precedence. The heap/bucket paths
/// reproduce the scan's semantics exactly: IEEE `>` ties the two zeros,
/// so keys collapse `-0.0` onto `0.0` before ordering by `total_cmp`,
/// and equal keys pop lowest-index-first. NaN priorities (which IEEE `>`
/// cannot order — the scan's behaviour there is "first eligible wins and
/// sticks") fall back to the verbatim historical scan, kept as the
/// executable reference and pinned equivalent by a property test.
pub fn selection_order(p: &Problem, prio: &[f64]) -> Vec<usize> {
    if prio.iter().any(|v| v.is_nan()) {
        return selection_order_scan(p, prio);
    }
    if let Some(order) = selection_order_buckets(p, prio) {
        return order;
    }
    let n = p.len();
    let mut n_unplaced_preds: Vec<usize> = (0..n).map(|t| p.preds(t).len()).collect();
    let mut heap: std::collections::BinaryHeap<Eligible> =
        std::collections::BinaryHeap::with_capacity(n);
    for t in 0..n {
        if n_unplaced_preds[t] == 0 {
            heap.push(Eligible::new(prio[t], t));
        }
    }
    let mut order = Vec::with_capacity(n);
    while let Some(c) = heap.pop() {
        let t = c.task;
        order.push(t);
        for &v in p.succs(t) {
            n_unplaced_preds[v] -= 1;
            if n_unplaced_preds[v] == 0 {
                heap.push(Eligible::new(prio[v], v));
            }
        }
    }
    debug_assert_eq!(order.len(), n, "acyclic problem always drains the heap");
    order
}

/// Max-heap entry of `selection_order`: highest canonical priority wins,
/// ties pop the lowest task index.
struct Eligible {
    /// Priority with `-0.0` collapsed onto `0.0` (IEEE `>` ties them;
    /// `total_cmp` would not), so the heap order matches the scan's.
    key: f64,
    task: usize,
}

impl Eligible {
    fn new(prio: f64, task: usize) -> Eligible {
        Eligible {
            key: prio + 0.0,
            task,
        }
    }
}

impl Ord for Eligible {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key
            .total_cmp(&other.key)
            .then_with(|| other.task.cmp(&self.task))
    }
}

impl PartialOrd for Eligible {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for Eligible {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Eligible {}

/// Per-bucket tie cap of the counting-bucket fast path: the pop's
/// lowest-index scan is O(bucket occupancy), so capping the static
/// occupancy keeps every pop O(1) amortized; denser tie patterns fall
/// back to the heap.
const BUCKET_TIE_CAP: u32 = 32;

/// Heap-free counting-bucket Kahn for *integer-valued* priorities — the
/// common case for count-like rules (e.g. successor counts). Tasks live
/// in buckets indexed by `prio - min`; the cursor walks down from the
/// highest occupied bucket, and a newly eligible successor may raise it
/// back up. Pops take the lowest task index within the bucket, which is
/// exactly the heap's (and scan's) tie-break on the canonical key
/// (`-0.0` collapses onto `0.0` via `+ 0.0` before keying).
///
/// Returns None — routing to the heap — unless every priority is a
/// finite integer-valued float, the value range is at most `4 * n`
/// (bucket storage stays O(n)), and no bucket holds more than
/// [`BUCKET_TIE_CAP`] tasks. Within those gates a full pass is
/// O(n + E + R) plus the total upward cursor movement, which is zero
/// when priorities are non-increasing along precedence (true for
/// successor counts: a task's count strictly exceeds each successor's).
fn selection_order_buckets(p: &Problem, prio: &[f64]) -> Option<Vec<usize>> {
    let n = p.len();
    if n == 0 {
        return Some(Vec::new());
    }
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    for &v in prio {
        let v = v + 0.0;
        if !v.is_finite() || v.fract() != 0.0 {
            return None;
        }
        min = min.min(v);
        max = max.max(v);
    }
    // Integer-valued floats more than one ULP apart subtract exactly, and
    // closer ones are equal, so the keys below are exact within the gate.
    if max - min > (4 * n.max(64)) as f64 {
        return None;
    }
    let range = (max - min) as usize;
    let key: Vec<usize> = prio.iter().map(|&v| ((v + 0.0) - min) as usize).collect();
    let mut count = vec![0u32; range + 1];
    for &k in &key {
        count[k] += 1;
        if count[k] > BUCKET_TIE_CAP {
            return None;
        }
    }

    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); range + 1];
    let mut n_unplaced_preds: Vec<usize> = (0..n).map(|t| p.preds(t).len()).collect();
    let mut cursor = 0usize;
    for t in 0..n {
        if n_unplaced_preds[t] == 0 {
            buckets[key[t]].push(t as u32);
            cursor = cursor.max(key[t]);
        }
    }
    let mut order = Vec::with_capacity(n);
    for _ in 0..n {
        // Invariant: every occupied bucket is at or below the cursor (it
        // only descends past empties and is raised on every push), and an
        // acyclic problem always has an eligible task, so the walk
        // terminates before underflowing.
        while buckets[cursor].is_empty() {
            debug_assert!(cursor > 0, "acyclic problem always has an eligible task");
            cursor -= 1;
        }
        let bucket = &mut buckets[cursor];
        let mut at = 0;
        for (i, &c) in bucket.iter().enumerate() {
            if c < bucket[at] {
                at = i;
            }
        }
        let t = bucket.swap_remove(at) as usize;
        order.push(t);
        for &v in p.succs(t) {
            n_unplaced_preds[v] -= 1;
            if n_unplaced_preds[v] == 0 {
                buckets[key[v]].push(v as u32);
                cursor = cursor.max(key[v]);
            }
        }
    }
    Some(order)
}

/// The historical O(n²) selection scan, verbatim: the executable
/// reference for the heap path (a property test pins them identical on
/// random DAGs with adversarial tie patterns) and the fallback for NaN
/// priorities, whose `>`-incomparability the scan resolves positionally.
fn selection_order_scan(p: &Problem, prio: &[f64]) -> Vec<usize> {
    let n = p.len();
    let mut done = vec![false; n];
    let mut n_unplaced_preds: Vec<usize> = (0..n).map(|t| p.preds(t).len()).collect();
    let mut order = Vec::with_capacity(n);
    while order.len() < n {
        // Highest-priority eligible task.
        let mut best: Option<usize> = None;
        for t in 0..n {
            if !done[t] && n_unplaced_preds[t] == 0 {
                match best {
                    None => best = Some(t),
                    Some(b) if prio[t] > prio[b] => best = Some(t),
                    _ => {}
                }
            }
        }
        let t = best.expect("acyclic problem always has an eligible task");
        done[t] = true;
        order.push(t);
        for &v in p.succs(t) {
            n_unplaced_preds[v] -= 1;
        }
    }
    order
}

/// The error a scheduling primitive reports when a task's demand alone
/// exceeds the cluster capacity (the historical kernel silently placed an
/// over-capacity rectangle here).
fn over_capacity(p: &Problem, t: usize, cpu: f64, mem: f64) -> anyhow::Error {
    anyhow!(
        "task {t} ({}) demands ({cpu:.1} vcpus, {mem:.1} GiB) exceeding cluster \
         capacity ({:.1} vcpus, {:.1} GiB); assignments must draw from Problem::feasible",
        p.tasks[t].name,
        p.capacity.vcpus,
        p.capacity.memory_gb
    )
}

/// Serial SGS with a static priority vector. Ties break on task index so
/// results are deterministic. The timeline is seeded with the problem's
/// occupancy reservations (`Problem::preplaced`), so a seeded problem is
/// packed into the residual capacity; with no seed this is the classic
/// serial SGS. Errors if any task's demand alone exceeds the cluster
/// capacity (an assignment outside `Problem::feasible`).
pub fn serial_sgs(p: &Problem, assignment: &[usize], prio: &[f64]) -> Result<Schedule> {
    let n = p.len();
    let order = selection_order(p, prio);
    let mut start = vec![0.0f64; n];
    let mut timeline = Timeline::seeded(p.capacity.vcpus, p.capacity.memory_gb, &p.preplaced);

    for &t in &order {
        let est = p.preds(t)
            .iter()
            .map(|&q| start[q] + p.duration(q, assignment[q]))
            .fold(p.release[t], f64::max);
        let d = p.duration(t, assignment[t]);
        let (cpu, mem) = p.demand(assignment[t]);
        let s = timeline
            .earliest_fit(est, d, cpu, mem)
            .ok_or_else(|| over_capacity(p, t, cpu, mem))?;
        timeline.place(s, d, cpu, mem);
        start[t] = s;
    }

    Ok(Schedule {
        assignment: assignment.to_vec(),
        start,
        optimal: false,
    })
}

/// Incremental schedule evaluator for the SA inner loop: a serial SGS
/// with a *frozen* selection order that, for each new configuration
/// assignment, re-places only the suffix starting at the first task whose
/// configuration changed (the affected cone of the perturbation, closed
/// under the placement order).
///
/// Soundness: with a static priority vector the SGS selection order is
/// duration-independent (see [`selection_order`]), and the placement of
/// position `i` depends only on the placements of positions `0..i` and
/// the durations/demands of those tasks. A proposal that perturbs task
/// set `S` therefore leaves every position before the first occurrence of
/// `S` in the order bit-identical — those placements are reused by
/// rolling the [`Timeline`] back to the shared prefix's epoch mark
/// (rollback is bit-exact; see `solver::timeline`).
///
/// `evaluate` is exactly equivalent to `serial_sgs(p, assignment, prio0)`
/// with the frozen priorities (asserted by a property test), at
/// O(suffix) instead of O(n) timeline work per proposal — the SA hot
/// path perturbs 1-3 tasks, so the expected suffix is short.
pub struct IncrementalSgs {
    /// Frozen selection order (critical-path priorities of the initial
    /// assignment).
    order: Vec<usize>,
    /// Start time per task from the most recent evaluation.
    start: Vec<f64>,
    /// The most recently evaluated assignment (usize::MAX = never).
    last: Vec<usize>,
    /// Epoch mark of the occupancy seed (`Problem::preplaced`), retained
    /// through every rollback (continuous admission packs proposals into
    /// the gaps). Each SGS placement advances the mark by exactly one,
    /// so `base_mark + i` is the epoch after the first `i` placements.
    base_mark: Mark,
    timeline: Timeline,
}

impl IncrementalSgs {
    /// Freeze the selection order for `initial` and seed the timeline
    /// with the problem's occupancy reservations.
    pub fn new(p: &Problem, initial: &[usize]) -> IncrementalSgs {
        let prio = priorities(p, initial, Rule::CriticalPath);
        let timeline = Timeline::seeded(p.capacity.vcpus, p.capacity.memory_gb, &p.preplaced);
        IncrementalSgs {
            order: selection_order(p, &prio),
            start: vec![0.0; p.len()],
            last: vec![usize::MAX; p.len()],
            base_mark: timeline.checkpoint(),
            timeline,
        }
    }

    /// Schedule `assignment`, reusing the placement prefix shared with
    /// the previously evaluated assignment. Returns the makespan.
    ///
    /// # Panics
    ///
    /// Panics if a task's demand alone exceeds the cluster capacity —
    /// the SA proposal kernel only draws from `Problem::feasible`, which
    /// rules that out; use [`serial_sgs`] for error-reporting paths.
    pub fn evaluate(&mut self, p: &Problem, assignment: &[usize]) -> f64 {
        let n = p.len();
        assert_eq!(assignment.len(), n);
        let first_changed = self
            .order
            .iter()
            .position(|&t| assignment[t] != self.last[t])
            .unwrap_or(n);
        self.timeline.rollback(self.base_mark + first_changed);
        for i in first_changed..n {
            let t = self.order[i];
            let est = p
                .preds(t)
                .iter()
                .map(|&q| self.start[q] + p.duration(q, assignment[q]))
                .fold(p.release[t], f64::max);
            let d = p.duration(t, assignment[t]);
            let (cpu, mem) = p.demand(assignment[t]);
            let s = self
                .timeline
                .earliest_fit(est, d, cpu, mem)
                .expect("SA proposals draw from Problem::feasible, whose demands fit the cluster");
            self.timeline.place(s, d, cpu, mem);
            self.start[t] = s;
        }
        self.last.copy_from_slice(assignment);
        (0..n)
            .map(|t| self.start[t] + p.duration(t, assignment[t]))
            .fold(0.0, f64::max)
    }

    /// Materialize the schedule of the most recent `evaluate` call.
    /// `assignment` must be the one passed to that call.
    pub fn schedule(&self, assignment: &[usize]) -> Schedule {
        debug_assert_eq!(assignment, &self.last[..]);
        Schedule {
            assignment: assignment.to_vec(),
            start: self.start.clone(),
            optimal: false,
        }
    }
}

/// Suffix-cone evaluator for mid-flight re-planning (`sim::replan`): a
/// serial SGS restricted to the *active* cone of a problem — the tasks
/// that have not started yet when a replan triggers — packed around a
/// timeline pre-seeded with the rectangles of committed work (running or
/// finished tasks, capacity-outage blockers).
///
/// Same prefix-reuse contract as [`IncrementalSgs`]: the selection order
/// over the cone is frozen (critical-path priorities of the incumbent
/// assignment, filtered to the cone — precedence-consistency is
/// preserved by filtering), and a proposal that changes configurations of
/// cone set `S` re-places only the order suffix from the first member of
/// `S`, rolling the [`Timeline`] back to the shared prefix's epoch mark.
/// The pre-seeded base rectangles are behind the base mark and are never
/// rolled away.
///
/// Precedence against committed predecessors uses their *realized* end
/// times (`fixed_end`), and every cone task is floored at the replan
/// instant — a replanned task cannot start in the past.
pub struct SuffixSgs {
    /// Frozen selection order restricted to the active cone.
    order: Vec<usize>,
    /// Replan instant: earliest allowed start for any cone task.
    floor: f64,
    /// Realized end per committed task (NaN/unused for cone tasks).
    fixed_end: Vec<f64>,
    /// Cone membership per task.
    active: Vec<bool>,
    /// Epoch mark of the pre-seeded rectangles, retained through every
    /// rollback.
    base_mark: Mark,
    start: Vec<f64>,
    last: Vec<usize>,
    timeline: Timeline,
}

impl SuffixSgs {
    /// `incumbent` fixes the frozen priorities; `active_tasks` is the
    /// cone (must be closed under successors — unstarted tasks always
    /// are); `fixed_end[t]` is the realized end of every committed task;
    /// `preplaced` are (start, duration, cpu, mem) rectangles of
    /// committed work the cone must pack around. The problem's own
    /// occupancy reservations (`Problem::preplaced`, continuous
    /// admission) are seeded in addition to `preplaced`, so a replan
    /// inside a continuously admitted round keeps packing around the
    /// other rounds' in-flight work.
    pub fn new(
        p: &Problem,
        incumbent: &[usize],
        active_tasks: &[usize],
        floor: f64,
        fixed_end: &[f64],
        preplaced: &[(f64, f64, f64, f64)],
    ) -> SuffixSgs {
        Self::with_rule(
            p,
            incumbent,
            active_tasks,
            floor,
            fixed_end,
            preplaced,
            Rule::CriticalPath,
        )
    }

    /// [`SuffixSgs::new`] with an explicit frozen priority rule. The
    /// replanner's troublesome-cone mode passes [`Rule::Troublesome`]
    /// here so at-risk heavy subgraphs grab residual capacity before
    /// filler tasks; `new` keeps the historical critical-path rule.
    #[allow(clippy::too_many_arguments)]
    pub fn with_rule(
        p: &Problem,
        incumbent: &[usize],
        active_tasks: &[usize],
        floor: f64,
        fixed_end: &[f64],
        preplaced: &[(f64, f64, f64, f64)],
        rule: Rule,
    ) -> SuffixSgs {
        let prio = priorities(p, incumbent, rule);
        let mut active = vec![false; p.len()];
        for &t in active_tasks {
            active[t] = true;
        }
        let order: Vec<usize> = selection_order(p, &prio)
            .into_iter()
            .filter(|&t| active[t])
            .collect();
        let mut timeline =
            Timeline::seeded(p.capacity.vcpus, p.capacity.memory_gb, &p.preplaced);
        for &(s, d, cpu, mem) in preplaced {
            timeline.place(s, d, cpu, mem);
        }
        SuffixSgs {
            order,
            floor,
            fixed_end: fixed_end.to_vec(),
            active,
            base_mark: timeline.checkpoint(),
            start: vec![0.0; p.len()],
            last: vec![usize::MAX; p.len()],
            timeline,
        }
    }

    /// Schedule the cone under `assignment` (full-length vector; entries
    /// outside the cone are ignored), reusing the placement prefix shared
    /// with the previous evaluation. Returns the max realized-projected
    /// end over the cone (at least `floor`).
    ///
    /// # Panics
    ///
    /// Panics if a cone task's demand alone exceeds the cluster capacity
    /// (replan proposals draw from `Problem::feasible`).
    pub fn evaluate(&mut self, p: &Problem, assignment: &[usize]) -> f64 {
        assert_eq!(assignment.len(), p.len());
        let first_changed = self
            .order
            .iter()
            .position(|&t| assignment[t] != self.last[t])
            .unwrap_or(self.order.len());
        self.timeline.rollback(self.base_mark + first_changed);
        for i in first_changed..self.order.len() {
            let t = self.order[i];
            let est = p
                .preds(t)
                .iter()
                .map(|&q| {
                    if self.active[q] {
                        self.start[q] + p.duration(q, assignment[q])
                    } else {
                        self.fixed_end[q]
                    }
                })
                .fold(p.release[t].max(self.floor), f64::max);
            let d = p.duration(t, assignment[t]);
            let (cpu, mem) = p.demand(assignment[t]);
            let s = self
                .timeline
                .earliest_fit(est, d, cpu, mem)
                .expect("replan proposals draw from Problem::feasible, whose demands fit the cluster");
            self.timeline.place(s, d, cpu, mem);
            self.start[t] = s;
        }
        for &t in &self.order {
            self.last[t] = assignment[t];
        }
        self.order
            .iter()
            .map(|&t| self.start[t] + p.duration(t, assignment[t]))
            .fold(self.floor, f64::max)
    }

    /// Planned start of a cone task from the most recent `evaluate`.
    pub fn start_of(&self, t: usize) -> f64 {
        self.start[t]
    }
}

/// Best schedule over all static rules plus `extra_random` noisy
/// restarts — the CP solver's initial upper bound and the anytime
/// fallback at scale. Errors if any task's demand alone exceeds the
/// cluster capacity (see [`serial_sgs`]).
pub fn multistart_sgs(
    p: &Problem,
    assignment: &[usize],
    extra_random: usize,
    rng: &mut Rng,
) -> Result<Schedule> {
    let mut best: Option<(f64, Schedule)> = None;
    let mut consider = |s: Schedule, p: &Problem| {
        let m = s.makespan(p);
        if best.as_ref().map_or(true, |(bm, _)| m < *bm) {
            best = Some((m, s));
        }
    };
    for &rule in ALL_RULES {
        let prio = priorities(p, assignment, rule);
        consider(serial_sgs(p, assignment, &prio)?, p);
    }
    // Noisy critical-path restarts.
    let base = priorities(p, assignment, Rule::CriticalPath);
    let scale = base.iter().cloned().fold(0.0f64, f64::max).max(1.0);
    for _ in 0..extra_random {
        let noisy: Vec<f64> = base
            .iter()
            .map(|&b| b + rng.uniform(0.0, 0.3 * scale))
            .collect();
        consider(serial_sgs(p, assignment, &noisy)?, p);
    }
    Ok(best.expect("at least one rule ran").1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::generator::{arbitrary_dag, fig10_batch};
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::solver::timeline::reference;
    use crate::util::propcheck;
    use crate::Predictor;

    fn problem_from(dags: Vec<crate::Dag>) -> Problem {
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags
            .iter()
            .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
            .collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let releases = vec![0.0; dags.len()];
        Problem::new(
            &dags,
            &releases,
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    #[test]
    fn sgs_schedules_are_valid_for_all_rules() -> anyhow::Result<()> {
        use anyhow::Context;
        let p = problem_from(vec![dag1(), dag2()]);
        let assignment = vec![p.feasible[0]; p.len()];
        for &rule in ALL_RULES {
            let prio = priorities(&p, &assignment, rule);
            let s = serial_sgs(&p, &assignment, &prio)?;
            s.validate(&p).with_context(|| format!("rule {rule:?}"))?;
        }
        Ok(())
    }

    #[test]
    fn over_capacity_assignment_is_an_error_not_a_schedule() {
        // An assignment outside Problem::feasible must surface as an
        // anyhow error instead of a silently over-packed schedule (the
        // historical kernel's fold-fallback bug).
        let p = problem_from(vec![dag1()]);
        let infeasible = (0..p.space.len()).find(|c| !p.feasible.contains(c));
        let Some(c) = infeasible else { return };
        let assignment = vec![c; p.len()];
        let prio = priorities(&p, &assignment, Rule::CriticalPath);
        let err = serial_sgs(&p, &assignment, &prio).unwrap_err();
        assert!(
            err.to_string().contains("exceeding cluster capacity"),
            "unexpected error: {err:#}"
        );
        let mut rng = Rng::new(1);
        assert!(multistart_sgs(&p, &assignment, 2, &mut rng).is_err());
    }

    #[test]
    fn selection_order_is_duration_independent() {
        // The invariant IncrementalSgs rests on: perturbing configs (and
        // hence durations/demands) never changes the selection order.
        let p = problem_from(vec![dag1(), dag2()]);
        let a0 = vec![p.feasible[0]; p.len()];
        let prio = priorities(&p, &a0, Rule::CriticalPath);
        let order = selection_order(&p, &prio);
        // Precedence-consistent and a permutation.
        let mut pos = vec![0usize; p.len()];
        for (i, &t) in order.iter().enumerate() {
            pos[t] = i;
        }
        for &(a, b) in &p.precedence {
            assert!(pos[a] < pos[b], "order violates precedence {a}->{b}");
        }
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..p.len()).collect::<Vec<_>>());
    }

    #[test]
    fn property_serial_sgs_matches_reference_kernel() {
        // The headline equivalence pin of the kernel swap: on random
        // problems — unseeded, occupancy-seeded, and floored — the
        // block-indexed serial SGS is bit-identical to the historical
        // rectangle-list serial SGS.
        propcheck::check(30, |rng| {
            let dag = arbitrary_dag(rng, 14);
            let p = problem_from(vec![dag]);
            let p = if rng.chance(0.6) {
                let cpu = p.capacity.vcpus * rng.uniform(0.2, 1.0);
                let mem = p.capacity.memory_gb * rng.uniform(0.2, 1.0);
                let mut seed = vec![(0.0, rng.uniform(10.0, 300.0), cpu, mem)];
                if rng.chance(0.5) {
                    seed.push((
                        rng.uniform(20.0, 400.0),
                        rng.uniform(10.0, 200.0),
                        cpu * 0.5,
                        mem * 0.5,
                    ));
                }
                let floor = if rng.chance(0.5) {
                    rng.uniform(0.0, 150.0)
                } else {
                    0.0
                };
                p.with_occupancy(seed, floor)
            } else {
                p
            };
            let assignment: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let rule = *rng.choice(ALL_RULES);
            let prio = priorities(&p, &assignment, rule);
            let new = serial_sgs(&p, &assignment, &prio).map_err(|e| e.to_string())?;
            let old = reference::serial_sgs_ref(&p, &assignment, &prio);
            for t in 0..p.len() {
                if new.start[t].to_bits() != old.start[t].to_bits() {
                    return Err(format!(
                        "task {t} start diverges: new {} vs reference {}",
                        new.start[t], old.start[t]
                    ));
                }
            }
            new.validate(&p).map_err(|e| e.to_string())
        });
    }

    #[test]
    fn property_incremental_matches_full_sgs() {
        // IncrementalSgs::evaluate must be bit-identical to a full
        // serial_sgs pass under the frozen priorities, for arbitrary
        // perturbation sequences.
        propcheck::check(20, |rng| {
            let dag = arbitrary_dag(rng, 12);
            let p = problem_from(vec![dag]);
            let initial: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let prio0 = priorities(&p, &initial, Rule::CriticalPath);
            let mut inc = IncrementalSgs::new(&p, &initial);
            let mut current = initial;
            for step in 0..12 {
                let makespan = inc.evaluate(&p, &current);
                let full = serial_sgs(&p, &current, &prio0).map_err(|e| e.to_string())?;
                if (makespan - full.makespan(&p)).abs() > 1e-12 {
                    return Err(format!(
                        "step {step}: incremental {makespan} != full {}",
                        full.makespan(&p)
                    ));
                }
                let sched = inc.schedule(&current);
                if sched.start != full.start {
                    return Err(format!("step {step}: start vectors diverge"));
                }
                sched.validate(&p).map_err(|e| e.to_string())?;
                // Perturb 1-2 tasks like the SA proposal kernel does.
                for _ in 0..rng.range(1, 2) {
                    let t = rng.below(p.len());
                    current[t] = p.feasible[rng.below(p.feasible.len())];
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_suffix_sgs_matches_full_sgs_on_trivial_cone() {
        // With every task active, no pre-placed work and floor 0, the
        // suffix evaluator degenerates to a plain frozen-priority serial
        // SGS — pin the equivalence for arbitrary perturbation sequences.
        propcheck::check(15, |rng| {
            let dag = arbitrary_dag(rng, 10);
            let p = problem_from(vec![dag]);
            let initial: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let prio0 = priorities(&p, &initial, Rule::CriticalPath);
            let all: Vec<usize> = (0..p.len()).collect();
            let fixed_end = vec![f64::NAN; p.len()];
            let mut sfx = SuffixSgs::new(&p, &initial, &all, 0.0, &fixed_end, &[]);
            let mut current = initial;
            for step in 0..8 {
                let makespan = sfx.evaluate(&p, &current);
                let full = serial_sgs(&p, &current, &prio0).map_err(|e| e.to_string())?;
                if (makespan - full.makespan(&p)).abs() > 1e-12 {
                    return Err(format!(
                        "step {step}: suffix {makespan} != full {}",
                        full.makespan(&p)
                    ));
                }
                for (t, &s) in full.start.iter().enumerate() {
                    if (sfx.start_of(t) - s).abs() > 1e-12 {
                        return Err(format!("step {step}: task {t} start diverges"));
                    }
                }
                let t = rng.below(p.len());
                current[t] = p.feasible[rng.below(p.feasible.len())];
            }
            Ok(())
        });
    }

    #[test]
    fn property_suffix_sgs_respects_floor_committed_work_and_precedence() {
        propcheck::check(15, |rng| {
            let dag = arbitrary_dag(rng, 12);
            let p = problem_from(vec![dag]);
            let assignment: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let prio = priorities(&p, &assignment, Rule::CriticalPath);
            let full = serial_sgs(&p, &assignment, &prio).map_err(|e| e.to_string())?;
            // Commit everything started before a random instant.
            let makespan = full.makespan(&p);
            let floor = rng.uniform(0.0, makespan);
            let committed: Vec<bool> = (0..p.len())
                .map(|t| full.start[t] < floor - 1e-9)
                .collect();
            let active: Vec<usize> =
                (0..p.len()).filter(|&t| !committed[t]).collect();
            if active.is_empty() {
                return Ok(());
            }
            let fixed_end: Vec<f64> = (0..p.len())
                .map(|t| full.start[t] + p.duration(t, assignment[t]))
                .collect();
            let preplaced: Vec<(f64, f64, f64, f64)> = (0..p.len())
                .filter(|&t| committed[t])
                .map(|t| {
                    let (cpu, mem) = p.demand(assignment[t]);
                    (full.start[t], p.duration(t, assignment[t]), cpu, mem)
                })
                .collect();
            let mut sfx =
                SuffixSgs::new(&p, &assignment, &active, floor, &fixed_end, &preplaced);
            // Re-plan the cone under a perturbed assignment.
            let mut cone_assignment = assignment.clone();
            for &t in &active {
                if rng.chance(0.5) {
                    cone_assignment[t] = p.feasible[rng.below(p.feasible.len())];
                }
            }
            sfx.evaluate(&p, &cone_assignment);
            // Cone starts respect the floor and realized precedence.
            for &t in &active {
                if sfx.start_of(t) + 1e-9 < floor {
                    return Err(format!(
                        "cone task {t} starts {} before floor {floor}",
                        sfx.start_of(t)
                    ));
                }
                for &q in p.preds(t) {
                    let q_end = if committed[q] {
                        fixed_end[q]
                    } else {
                        sfx.start_of(q) + p.duration(q, cone_assignment[q])
                    };
                    if sfx.start_of(t) + 1e-6 < q_end {
                        return Err(format!(
                            "cone task {t} starts before predecessor {q} ends"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sgs_beats_sequential() {
        let p = problem_from(vec![dag2()]);
        // pick a small config so several tasks fit side by side
        let small = *p
            .feasible
            .iter()
            .min_by(|&&a, &&b| p.demand(a).0.total_cmp(&p.demand(b).0))
            .unwrap();
        let assignment = vec![small; p.len()];
        let prio = priorities(&p, &assignment, Rule::CriticalPath);
        let s = serial_sgs(&p, &assignment, &prio).unwrap();
        let sequential: f64 = (0..p.len()).map(|t| p.duration(t, assignment[t])).sum();
        assert!(
            s.makespan(&p) < sequential * 0.8,
            "SGS should exploit DAG2 parallelism: {} vs {}",
            s.makespan(&p),
            sequential
        );
    }

    #[test]
    fn makespan_at_least_critical_path() {
        let p = problem_from(vec![dag1()]);
        let assignment = vec![p.feasible[0]; p.len()];
        let prio = priorities(&p, &assignment, Rule::CriticalPath);
        let s = serial_sgs(&p, &assignment, &prio).unwrap();
        assert!(s.makespan(&p) + 1e-6 >= p.critical_path_lb(&assignment));
    }

    #[test]
    fn multistart_never_worse_than_single_rule() {
        let mut rng = Rng::new(3);
        let p = problem_from(vec![dag1(), dag2()]);
        let assignment = vec![p.feasible[1]; p.len()];
        let multi = multistart_sgs(&p, &assignment, 10, &mut rng).unwrap();
        for &rule in ALL_RULES {
            let prio = priorities(&p, &assignment, rule);
            let single = serial_sgs(&p, &assignment, &prio).unwrap();
            assert!(multi.makespan(&p) <= single.makespan(&p) + 1e-6);
        }
    }

    /// The heap-based `selection_order` must reproduce the historical
    /// O(n²) scan pick for pick — on random DAGs with adversarial
    /// priority patterns: dense ties, mixed `-0.0`/`0.0` (which IEEE `>`
    /// ties but `total_cmp` would not), infinities, and NaN (routed to
    /// the scan fallback, so the assertion is still exercised end to
    /// end through the public entry point).
    #[test]
    fn property_selection_order_heap_matches_scan() {
        propcheck::check(60, |rng| {
            let dag = arbitrary_dag(rng, 20);
            let p = problem_from(vec![dag]);
            let prio: Vec<f64> = (0..p.len())
                .map(|_| match rng.below(6) {
                    // Dense ties from a tiny value set.
                    0 => rng.below(3) as f64,
                    1 => -0.0,
                    2 => 0.0,
                    3 => f64::INFINITY,
                    4 if rng.chance(0.3) => f64::NAN,
                    _ => rng.uniform(-10.0, 10.0),
                })
                .collect();
            let fast = selection_order(&p, &prio);
            let slow = selection_order_scan(&p, &prio);
            if fast != slow {
                return Err(format!(
                    "selection orders diverge for prio {prio:?}: {fast:?} vs scan {slow:?}"
                ));
            }
            Ok(())
        });
    }

    /// The counting-bucket fast path must be pick-for-pick identical to
    /// the scan on all-integer priorities (the patterns that actually
    /// route to it: dense ties under the cap, negatives, mixed zeros).
    #[test]
    fn property_selection_order_buckets_match_scan_on_integer_priorities() {
        propcheck::check(60, |rng| {
            let dag = arbitrary_dag(rng, 20);
            let p = problem_from(vec![dag]);
            let prio: Vec<f64> = (0..p.len())
                .map(|_| match rng.below(4) {
                    // Dense ties from a tiny value set (occupancy < cap).
                    0 => rng.below(2) as f64,
                    1 => -(rng.below(5) as f64),
                    2 => if rng.chance(0.5) { -0.0 } else { 0.0 },
                    _ => rng.below(40) as f64,
                })
                .collect();
            let bucketed = selection_order_buckets(&p, &prio)
                .ok_or_else(|| format!("integer priorities must bucket: {prio:?}"))?;
            let slow = selection_order_scan(&p, &prio);
            if bucketed != slow {
                return Err(format!(
                    "bucket order diverges for prio {prio:?}: {bucketed:?} vs scan {slow:?}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn bucket_path_rejects_what_it_cannot_order() {
        let p = problem_from(vec![dag1(), dag2()]);
        let n = p.len();
        assert!(n > 2, "need a few tasks");
        // Non-integer priorities route to the heap.
        assert!(selection_order_buckets(&p, &vec![0.5; n]).is_none());
        // Infinities are not bucketable.
        let mut inf = vec![1.0; n];
        inf[0] = f64::INFINITY;
        assert!(selection_order_buckets(&p, &inf).is_none());
        // A range wider than 4n overflows the bucket array budget.
        let mut wide = vec![0.0; n];
        wide[0] = (8 * n.max(64)) as f64;
        assert!(selection_order_buckets(&p, &wide).is_none());
        // Integer ties denser than the cap fall back to the heap — and
        // the public entry point still matches the scan there.
        let big = problem_from(vec![dag1(), dag2(), dag1(), dag2(), dag1()]);
        assert!(big.len() as u32 > BUCKET_TIE_CAP);
        let flat = vec![3.0; big.len()];
        assert!(selection_order_buckets(&big, &flat).is_none());
        assert_eq!(selection_order(&big, &flat), selection_order_scan(&big, &flat));
        // Successor counts are the motivating integer rule: bucketable,
        // and identical through the public entry point.
        let assignment = vec![p.feasible[0]; n];
        let counts = priorities(&p, &assignment, Rule::MostSuccessors);
        if let Some(b) = selection_order_buckets(&p, &counts) {
            assert_eq!(b, selection_order_scan(&p, &counts));
            assert_eq!(b, selection_order(&p, &counts));
        }
    }

    #[test]
    fn property_sgs_valid_on_random_dags() {
        propcheck::check(40, |rng| {
            let dag = arbitrary_dag(rng, 15);
            let p = problem_from(vec![dag]);
            let assignment: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let rule = *rng.choice(ALL_RULES);
            let prio = priorities(&p, &assignment, rule);
            let s = serial_sgs(&p, &assignment, &prio).map_err(|e| e.to_string())?;
            s.validate(&p).map_err(|e| e.to_string())?;
            if s.makespan(&p) + 1e-6 < p.lower_bound(&assignment) {
                return Err(format!(
                    "makespan {} below lower bound {}",
                    s.makespan(&p),
                    p.lower_bound(&assignment)
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn property_fig10_batches_schedule_cleanly() {
        propcheck::check(10, |rng| {
            let dags = fig10_batch(rng, 3);
            let p = problem_from(dags);
            let assignment = vec![p.feasible[0]; p.len()];
            let prio = priorities(&p, &assignment, Rule::MostSuccessors);
            let s = serial_sgs(&p, &assignment, &prio).map_err(|e| e.to_string())?;
            s.validate(&p).map_err(|e| e.to_string())
        });
    }

    #[test]
    fn occupancy_seed_pushes_schedule_into_residual_capacity() {
        // A full-capacity blocker over [0, 100) plus an admission floor:
        // every placement must land at or after the blocker clears.
        let p = problem_from(vec![dag1()]);
        let full = (0.0, 100.0, p.capacity.vcpus, p.capacity.memory_gb);
        let seeded = problem_from(vec![dag1()]).with_occupancy(vec![full], 40.0);
        let assignment = vec![p.feasible[0]; p.len()];
        let prio = priorities(&seeded, &assignment, Rule::CriticalPath);
        let s = serial_sgs(&seeded, &assignment, &prio).unwrap();
        for t in 0..seeded.len() {
            assert!(
                s.start[t] + 1e-9 >= 100.0,
                "task {t} starts {} inside the reserved window",
                s.start[t]
            );
        }
        s.validate(&seeded).unwrap();
        // The same plan shifted by the blocker: unseeded makespan + 100.
        let unseeded = serial_sgs(&p, &assignment, &prio).unwrap();
        assert!((s.makespan(&seeded) - (unseeded.makespan(&p) + 100.0)).abs() < 1e-6);
    }

    #[test]
    fn occupancy_floor_alone_delays_first_start() {
        let seeded = problem_from(vec![dag1()]).with_occupancy(Vec::new(), 50.0);
        let assignment = vec![seeded.feasible[0]; seeded.len()];
        let prio = priorities(&seeded, &assignment, Rule::CriticalPath);
        let s = serial_sgs(&seeded, &assignment, &prio).unwrap();
        for t in 0..seeded.len() {
            assert!(s.start[t] + 1e-9 >= 50.0);
        }
        s.validate(&seeded).unwrap();
    }

    #[test]
    fn property_incremental_matches_full_sgs_on_seeded_problems() {
        // The prefix-reuse contract must hold with a non-empty occupancy
        // seed: IncrementalSgs over a seeded problem stays bit-identical
        // to the full seeded serial SGS across perturbation sequences.
        propcheck::check(10, |rng| {
            let dag = arbitrary_dag(rng, 10);
            let p = problem_from(vec![dag]);
            let cpu = p.capacity.vcpus * rng.uniform(0.3, 0.9);
            let mem = p.capacity.memory_gb * rng.uniform(0.3, 0.9);
            let seed = vec![
                (0.0, rng.uniform(10.0, 200.0), cpu, mem),
                (rng.uniform(50.0, 300.0), rng.uniform(10.0, 200.0), cpu * 0.5, mem * 0.5),
            ];
            let p = p.with_occupancy(seed, rng.uniform(0.0, 100.0));
            let initial: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let prio0 = priorities(&p, &initial, Rule::CriticalPath);
            let mut inc = IncrementalSgs::new(&p, &initial);
            let mut current = initial;
            for step in 0..8 {
                let makespan = inc.evaluate(&p, &current);
                let full = serial_sgs(&p, &current, &prio0).map_err(|e| e.to_string())?;
                if (makespan - full.makespan(&p)).abs() > 1e-12 {
                    return Err(format!(
                        "step {step}: seeded incremental {makespan} != full {}",
                        full.makespan(&p)
                    ));
                }
                if inc.schedule(&current).start != full.start {
                    return Err(format!("step {step}: seeded start vectors diverge"));
                }
                let t = rng.below(p.len());
                current[t] = p.feasible[rng.below(p.feasible.len())];
            }
            Ok(())
        });
    }

    #[test]
    fn troublesome_rule_is_valid_and_outside_the_multistart_portfolio() {
        // Adding Troublesome to ALL_RULES would silently change
        // multistart_sgs (the CP solver's initial upper bound) and break
        // the golden pins — it is a baseline/seeding rule only.
        assert!(!ALL_RULES.contains(&Rule::Troublesome));
        let p = problem_from(vec![dag1(), dag2()]);
        let assignment = vec![p.feasible[0]; p.len()];
        let prio = priorities(&p, &assignment, Rule::Troublesome);
        let s = serial_sgs(&p, &assignment, &prio).unwrap();
        s.validate(&p).unwrap();
    }

    #[test]
    fn troublesome_scores_and_components_are_deterministic() {
        let p = problem_from(vec![dag1(), dag2()]);
        let assignment = vec![p.feasible[0]; p.len()];
        let s1 = troublesome_scores(&p, &assignment);
        let s2 = troublesome_scores(&p, &assignment);
        assert_eq!(s1, s2);
        let comps = troublesome_components(&p, &s1);
        assert_eq!(comps, troublesome_components(&p, &s2));

        let max = s1.iter().cloned().fold(0.0f64, f64::max);
        assert!(max > 0.0, "real workloads have nonzero scores");
        let mut seen = vec![false; p.len()];
        let mut peaks = Vec::new();
        for comp in &comps {
            assert!(!comp.is_empty());
            let mut peak = f64::NEG_INFINITY;
            for &t in comp {
                assert!(!seen[t], "components must be disjoint");
                seen[t] = true;
                assert!(s1[t] >= 0.5 * max, "members must be troublesome");
                peak = peak.max(s1[t]);
            }
            peaks.push(peak);
        }
        for w in peaks.windows(2) {
            assert!(w[0] >= w[1], "components ranked by peak score");
        }
        // Every troublesome task is claimed by exactly one component and
        // the peak scorer seeds the first one.
        let n_marked = (0..p.len()).filter(|&t| s1[t] >= 0.5 * max).count();
        assert_eq!(seen.iter().filter(|&&b| b).count(), n_marked);
        let argmax = (0..p.len()).find(|&t| s1[t] == max).unwrap();
        assert!(comps[0].contains(&argmax));
    }

    #[test]
    fn troublesome_zero_scores_mean_no_components() {
        let p = problem_from(vec![dag1()]);
        let zeros = vec![0.0; p.len()];
        assert!(troublesome_components(&p, &zeros).is_empty());
    }

    #[test]
    fn property_troublesome_rule_schedules_valid_on_random_dags() {
        propcheck::check(20, |rng| {
            let dag = arbitrary_dag(rng, 14);
            let p = problem_from(vec![dag]);
            let assignment: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let prio = priorities(&p, &assignment, Rule::Troublesome);
            let s = serial_sgs(&p, &assignment, &prio).map_err(|e| e.to_string())?;
            s.validate(&p).map_err(|e| format!("{e:#}"))
        });
    }
}
