//! Schedule representation + the feasibility invariants (Eq. 2–5) every
//! scheduler in the repo must satisfy. `validate` is used by unit tests,
//! property tests, and (in debug builds) the execution simulator.

use anyhow::{bail, Result};

use super::rcpsp::Problem;
use super::timeline::Timeline;

/// A complete solution: per-task configuration choice and start time.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Config index (into the problem's space) per task.
    pub assignment: Vec<usize>,
    /// Start time per task — ts_ij.
    pub start: Vec<f64>,
    /// Whether the producing solver proved optimality (CP-SAT contract).
    pub optimal: bool,
}

impl Schedule {
    /// End time of task `t` — te_ij = ts_ij + d_ijc (Eq. 2).
    pub fn end(&self, p: &Problem, t: usize) -> f64 {
        self.start[t] + p.duration(t, self.assignment[t])
    }

    /// Makespan — max end time (Eq. 5), relative to t = 0.
    pub fn makespan(&self, p: &Problem) -> f64 {
        (0..p.len())
            .map(|t| self.end(p, t))
            .fold(0.0, f64::max)
    }

    /// Total dollar cost (Eq. 6).
    pub fn cost(&self, p: &Problem) -> f64 {
        p.assignment_cost(&self.assignment)
    }

    /// Per-DAG completion time (max end over the DAG's tasks) — used by
    /// the multi-DAG macro benchmark (Fig. 11).
    pub fn dag_completion(&self, p: &Problem, dag: usize) -> f64 {
        (0..p.len())
            .filter(|&t| p.tasks[t].dag == dag)
            .map(|t| self.end(p, t))
            .fold(0.0, f64::max)
    }

    /// Check every constraint of the §4.2 formulation:
    ///   Eq. 3 precedence, Eq. 4 capacity at every instant, release times,
    ///   and assignment validity. Eq. 4 runs on the shared block-indexed
    ///   [`Timeline`] kernel: build the capacity profile of the
    ///   schedule's rectangles plus the occupancy reservations, then scan
    ///   its constant-usage segments — O(n log n + Σk) (block splits
    ///   replaced the flat kernel's worst-case O(n²) insert memmoves)
    ///   instead of the historical O(n²) per-event feasibility rescan.
    pub fn validate(&self, p: &Problem) -> Result<()> {
        let n = p.len();
        if self.assignment.len() != n || self.start.len() != n {
            bail!(
                "schedule arity mismatch: {} tasks, {} assignments, {} starts",
                n,
                self.assignment.len(),
                self.start.len()
            );
        }
        for t in 0..n {
            let c = self.assignment[t];
            if !p.feasible.contains(&c) {
                bail!("task {t} assigned infeasible config {c}");
            }
            if !self.start[t].is_finite() || self.start[t] < -1e-9 {
                bail!("task {t} has invalid start {}", self.start[t]);
            }
            if self.start[t] + 1e-9 < p.release[t] {
                bail!(
                    "task {t} starts at {} before release {}",
                    self.start[t],
                    p.release[t]
                );
            }
        }
        // Eq. 3: ts_j >= te_k for (k, j) in P
        for &(a, b) in &p.precedence {
            let end_a = self.end(p, a);
            if self.start[b] + 1e-6 < end_a {
                bail!(
                    "precedence violated: {} (ends {end_a:.3}) -> {} (starts {:.3})",
                    p.tasks[a].name,
                    p.tasks[b].name,
                    self.start[b]
                );
            }
        }
        // Eq. 4: capacity at every instant, via the shared block-indexed
        // kernel. Reserved capacity counts against the cluster: a
        // schedule overlapping `Problem::preplaced` is infeasible.
        let mut profile =
            Timeline::seeded(p.capacity.vcpus, p.capacity.memory_gb, &p.preplaced);
        for t in 0..n {
            let (c, m) = p.demand(self.assignment[t]);
            profile.place(self.start[t], p.duration(t, self.assignment[t]), c, m);
        }
        for (at, _, cpu, mem) in profile.segments() {
            if cpu > p.capacity.vcpus + 1e-6 {
                bail!(
                    "cpu capacity exceeded at t={at:.3}: {cpu:.1} > {:.1}",
                    p.capacity.vcpus
                );
            }
            if mem > p.capacity.memory_gb + 1e-6 {
                bail!(
                    "memory capacity exceeded at t={at:.3}: {mem:.1} > {:.1}",
                    p.capacity.memory_gb
                );
            }
        }
        Ok(())
    }

    /// Gantt-style text rendering for reports and examples.
    pub fn render(&self, p: &Problem) -> String {
        let mut rows: Vec<usize> = (0..p.len()).collect();
        rows.sort_by(|&a, &b| self.start[a].total_cmp(&self.start[b]));
        let makespan = self.makespan(p).max(1e-9);
        let width = 60usize;
        let mut out = String::new();
        for t in rows {
            let s = self.start[t];
            let e = self.end(p, t);
            let i0 = ((s / makespan) * width as f64).round() as usize;
            let i1 = (((e / makespan) * width as f64).round() as usize).max(i0 + 1);
            let mut bar = String::new();
            for i in 0..width {
                bar.push(if i >= i0 && i < i1.min(width) { '#' } else { '.' });
            }
            out.push_str(&format!(
                "{:<28} |{bar}| {:>8.0}s..{:>8.0}s  {}\n",
                p.tasks[t].name,
                s,
                e,
                p.config(self.assignment[t]).label()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::dag1;
    use crate::predictor::OraclePredictor;
    use crate::Predictor;

    fn problem() -> Problem {
        let dags = vec![dag1()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags[0].tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &dags,
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    /// A trivially valid schedule: every task sequential in topo order.
    fn sequential(p: &Problem) -> Schedule {
        let c = p.feasible[0];
        let order = p.topo_order();
        let mut start = vec![0.0; p.len()];
        let mut clock = 0.0;
        for &t in &order {
            start[t] = clock;
            clock += p.duration(t, c);
        }
        Schedule {
            assignment: vec![c; p.len()],
            start,
            optimal: false,
        }
    }

    #[test]
    fn sequential_schedule_is_valid() {
        let p = problem();
        let s = sequential(&p);
        s.validate(&p).unwrap();
        assert!(s.makespan(&p) > 0.0);
        assert!(s.cost(&p) > 0.0);
    }

    #[test]
    fn precedence_violation_detected() {
        let p = problem();
        let mut s = sequential(&p);
        // dag1 edge (0, 1): force task 1 to start at 0
        s.start[1] = 0.0;
        assert!(s.validate(&p).is_err());
    }

    #[test]
    fn capacity_violation_detected() {
        let p = problem();
        let mut s = sequential(&p);
        // Give every task the largest feasible config and run all at once.
        let biggest = *p
            .feasible
            .iter()
            .max_by(|&&a, &&b| p.demand(a).0.total_cmp(&p.demand(b).0))
            .unwrap();
        for t in 0..p.len() {
            s.assignment[t] = biggest;
            s.start[t] = 0.0;
        }
        assert!(s.validate(&p).is_err());
    }

    #[test]
    fn occupancy_overlap_detected() {
        // A schedule overlapping a full-capacity occupancy reservation is
        // infeasible, even though its own demand fits the cluster alone.
        let p = problem();
        let s = sequential(&p);
        s.validate(&p).unwrap();
        let cap = p.capacity;
        let seeded = problem().with_occupancy(vec![(0.0, 1e9, cap.vcpus, cap.memory_gb)], 0.0);
        assert!(s.validate(&seeded).is_err());
    }

    #[test]
    fn infeasible_config_detected() {
        let p = problem();
        let mut s = sequential(&p);
        // find an infeasible config index (too big for the cluster)
        let infeasible = (0..p.space.len()).find(|c| !p.feasible.contains(c));
        if let Some(c) = infeasible {
            s.assignment[0] = c;
            assert!(s.validate(&p).is_err());
        }
    }

    #[test]
    fn release_violation_detected() {
        let dags = vec![dag1()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags[0].tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let p = Problem::new(
            &dags,
            &[500.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        );
        let mut s = sequential(&p);
        // sequential() starts at release? No: it starts at 0 -> violation.
        s.start[0] = 0.0;
        assert!(s.validate(&p).is_err());
    }

    #[test]
    fn makespan_equals_last_end() {
        let p = problem();
        let s = sequential(&p);
        let total: f64 = (0..p.len())
            .map(|t| p.duration(t, s.assignment[t]))
            .sum();
        assert!((s.makespan(&p) - total).abs() < 1e-6);
    }

    #[test]
    fn render_contains_all_tasks() {
        let p = problem();
        let s = sequential(&p);
        let g = s.render(&p);
        assert_eq!(g.lines().count(), p.len());
    }
}
