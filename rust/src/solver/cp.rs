//! CP-style scheduling solver — the inner "SAT solver" of Algorithm 1.
//!
//! Stand-in for OR-Tools CP-SAT (unavailable offline), with the same
//! contract the paper relies on:
//!   * given *fixed* per-task configurations, minimize makespan;
//!   * prove optimality when the search completes (`optimal = true`);
//!   * behave as an anytime solver under a node/time budget ("the
//!     optimization can be stopped earlier", §5.4).
//!
//! Method: branch-and-bound over serial-SGS insertion orders. For a
//! regular objective like makespan, some precedence-feasible insertion
//! order generates an optimal active schedule, so complete enumeration is
//! exact. Pruning:
//!   * critical-path + energy (area) lower bounds on the completion of
//!     the residual problem (cheap, always valid);
//!   * no-good dominance: a memo of scheduled-task bitsets — if the same
//!     subset was reached before with a pointwise-dominating end-time
//!     profile, the current branch cannot improve on it (the lazy-clause
//!     analogue: learned states that need not be revisited);
//!   * capacity-envelope pruning (opt-in, [`Limits::exact`]): a node is
//!     cut when the remaining cone's aggregate (cpu·time, mem·time) area
//!     cannot fit under the capacity envelope between the cone's earliest
//!     possible start and the incumbent horizon, with the already-placed
//!     area read off the timeline kernel's [`Timeline::area_in`]
//!     aggregate. Off by default: under a *binding* node budget any extra
//!     prune reroutes the anytime traversal, and several golden-scenario
//!     suites pin those budget-bound trajectories bit-for-bit. On
//!     searches that complete, the prune is provably outcome-neutral (it
//!     only removes subtrees that cannot beat the incumbent), which the
//!     property tests assert by solving with it on and off.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::rcpsp::Problem;
use super::schedule::Schedule;
use super::sgs;
use super::timeline::Timeline;
use crate::util::Rng;

/// Search limits: the solver stops at whichever budget is hit first.
#[derive(Debug, Clone)]
pub struct Limits {
    /// Node budget of the branch-and-bound search.
    pub max_nodes: u64,
    /// Wall-clock budget of one solve.
    pub max_time: Duration,
    /// Random multistart-SGS restarts for the initial upper bound. The
    /// annealing inner loop uses a small value (the B&B refines the bound
    /// anyway and the loop is called thousands of times); one-shot solves
    /// use more. See EXPERIMENTS.md §Perf for the tuning data.
    pub sgs_restarts: usize,
    /// Enable the capacity-envelope area prune (see module docs). Off by
    /// default — and in [`Limits::inner_loop`] — because under a binding
    /// node budget any extra prune reroutes the anytime traversal, and
    /// the pinned golden-scenario suites depend on those budget-bound
    /// trajectories bit-for-bit. Only outcome-neutral when the search
    /// completes; [`Limits::exact`] turns it on for one-shot solves.
    pub envelope_prune: bool,
}

impl Default for Limits {
    fn default() -> Self {
        Limits {
            max_nodes: 200_000,
            max_time: Duration::from_secs(10),
            sgs_restarts: 8,
            envelope_prune: false,
        }
    }
}

impl Limits {
    /// Tight budget for the annealing inner loop (called thousands of
    /// times; see EXPERIMENTS.md §Perf for the tuning).
    pub fn inner_loop() -> Self {
        Limits {
            max_nodes: 64,
            max_time: Duration::from_millis(250),
            sgs_restarts: 2,
            envelope_prune: false,
        }
    }

    /// Default budgets plus the capacity-envelope prune — for one-shot
    /// solves where the search is expected to complete and the extra
    /// prune only shrinks the tree (it removes subtrees that provably
    /// cannot beat the incumbent, so the proved optimum is unchanged).
    pub fn exact() -> Self {
        Limits {
            envelope_prune: true,
            ..Limits::default()
        }
    }

    /// Budgets for the destructive UB-ladder ([`CpSolver::solve_ladder`]):
    /// default node/time budgets, envelope prune on. Inside the ladder
    /// the prune is always sound — every rung only removes subtrees that
    /// cannot beat the incumbent, and the ladder's contract is the final
    /// incumbent, not a pinned traversal.
    pub fn ladder() -> Self {
        Limits {
            envelope_prune: true,
            ..Limits::default()
        }
    }
}

/// Solve statistics for overhead reporting (Fig. 10).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    /// Branch-and-bound nodes visited.
    pub nodes: u64,
    /// Branches pruned by the lower bound.
    pub pruned_lb: u64,
    /// Branches pruned by the dominance store.
    pub pruned_dominance: u64,
    /// Nodes pruned by the capacity-envelope area bound (only non-zero
    /// when [`Limits::envelope_prune`] is on).
    pub pruned_envelope: u64,
    /// Wall-clock time of the solve.
    pub solve_time: Duration,
    /// Whether the search completed (schedule proven optimal).
    pub proved_optimal: bool,
    /// UB-ladder rungs executed (0 outside [`CpSolver::solve_ladder`]).
    pub rungs: u64,
    /// Serial-SGS decodes spent on the incumbent (multistart rules +
    /// noisy restarts) — part of the evaluation budget currency for fair
    /// engine comparisons.
    pub sgs_evals: u64,
}

/// The CP-style branch-and-bound scheduler (see module docs).
pub struct CpSolver {
    /// Search budgets of each solve.
    pub limits: Limits,
}

struct Search<'a> {
    p: &'a Problem,
    assignment: &'a [usize],
    durations: Vec<f64>,
    demands: Vec<(f64, f64)>,
    /// bottom-level (critical path to sink) per task for LB + branching.
    bottom: Vec<f64>,
    best: Schedule,
    best_makespan: f64,
    root_lb: f64,
    stats: Stats,
    limits: Limits,
    deadline: Instant,
    /// scheduled-set -> end-time profile(s) seen (dominance store).
    seen: HashMap<u128, Vec<Vec<f64>>>,
    exhausted: bool,
    /// Ladder mode: unwind the whole search as soon as one improving
    /// solution is accepted (the rung's job is a single UB tightening).
    first_solution: bool,
    /// Whether the current (rung) search accepted an improving solution.
    found: bool,
}

impl CpSolver {
    /// Solver with the given search budgets.
    pub fn new(limits: Limits) -> Self {
        CpSolver { limits }
    }

    /// Minimize makespan for a fixed configuration assignment. Errors if
    /// any task's demand alone exceeds the cluster capacity (an
    /// assignment outside `Problem::feasible`) — surfaced by the SGS
    /// incumbent before the branch-and-bound starts, so the search itself
    /// never packs an over-capacity rectangle.
    pub fn solve(&self, p: &Problem, assignment: &[usize]) -> Result<(Schedule, Stats)> {
        let t0 = Instant::now();
        assert_eq!(assignment.len(), p.len());

        // Upper bound: multistart SGS (also the anytime fallback). Its
        // success proves every task's demand fits the cluster alone, the
        // precondition the DFS below relies on.
        let mut rng = Rng::new(0xCB5A7);
        let incumbent = sgs::multistart_sgs(p, assignment, self.limits.sgs_restarts, &mut rng)?;
        let incumbent_makespan = incumbent.makespan(p);

        let durations: Vec<f64> = (0..p.len())
            .map(|t| p.duration(t, assignment[t]))
            .collect();
        let demands: Vec<(f64, f64)> = (0..p.len())
            .map(|t| p.demand(assignment[t]))
            .collect();
        let bottom = {
            let order = p.topo_order();
            let mut b = vec![0.0f64; p.len()];
            for &u in order.iter().rev() {
                b[u] = durations[u]
                    + p.succs(u).iter().map(|&v| b[v]).fold(0.0f64, f64::max);
            }
            b
        };
        let root_lb = p.lower_bound(assignment);

        let mut search = Search {
            p,
            assignment,
            durations,
            demands,
            bottom,
            best: incumbent,
            best_makespan: incumbent_makespan,
            root_lb,
            stats: Stats::default(),
            limits: self.limits.clone(),
            deadline: t0 + self.limits.max_time,
            seen: HashMap::new(),
            exhausted: false,
            first_solution: false,
            found: false,
        };
        search.stats.sgs_evals = (sgs::ALL_RULES.len() + self.limits.sgs_restarts) as u64;

        // Bitset dominance only works up to 128 tasks; beyond that the
        // anytime SGS result stands (macro-scale problems).
        if p.len() <= 128 && incumbent_makespan > root_lb + 1e-6 {
            // Seed the branch-and-bound timeline with the problem's
            // occupancy reservations (continuous admission); every DFS
            // node checkpoints before placing and rolls back after, so
            // the seed rectangles are never backtracked away.
            let mut timeline =
                Timeline::seeded(p.capacity.vcpus, p.capacity.memory_gb, &p.preplaced);
            let mut start = vec![0.0f64; p.len()];
            let mut indeg: Vec<usize> = (0..p.len()).map(|t| p.preds(t).len()).collect();
            search.exhausted = true;
            search.dfs(0u128, &mut start, &mut indeg, &mut timeline, 0, 0.0);
        } else if incumbent_makespan <= root_lb + 1e-6 {
            search.exhausted = true; // UB met LB: already optimal
        }

        let mut best = search.best;
        best.optimal = search.exhausted;
        let mut stats = search.stats;
        stats.proved_optimal = search.exhausted;
        stats.solve_time = t0.elapsed();
        Ok((best, stats))
    }

    /// Destructive UB-ladder solve (the DDD/incremental-SAT shape): seed
    /// the incumbent once via multistart SGS, then run first-solution
    /// branch-and-bound *rungs*, each re-searching from the root with the
    /// upper bound tightened to the previous rung's `best_makespan − ε`.
    /// The root [`Timeline`] seed, the precomputed per-task lower bounds
    /// (`bottom`, `root_lb`) and the bottom-level branching order are
    /// built once and reused across every rung; the node/time budgets are
    /// global across the whole ladder, and [`Stats`] accumulates per-rung
    /// (`rungs` counts them). Envelope pruning is forced on — inside the
    /// ladder it is always sound, because each rung only removes subtrees
    /// that cannot beat the current incumbent and the ladder's contract
    /// is the final incumbent, not a pinned traversal.
    ///
    /// The dominance store is cleared between rungs: a witness recorded
    /// during an aborted (first-solution) rung may cover a subtree that
    /// was never fully explored, so carrying it over could prune the very
    /// branch the next rung must descend. Within a rung the store is
    /// sound as usual.
    ///
    /// Optimality: a rung that exhausts without finding an improvement
    /// proves the incumbent optimal (no completion beats it); a rung that
    /// hits the budget leaves the incumbent anytime-valid, unproven.
    pub fn solve_ladder(&self, p: &Problem, assignment: &[usize]) -> Result<(Schedule, Stats)> {
        let t0 = Instant::now();
        assert_eq!(assignment.len(), p.len());

        let mut limits = self.limits.clone();
        limits.envelope_prune = true;

        let mut rng = Rng::new(0xCB5A7);
        let incumbent = sgs::multistart_sgs(p, assignment, limits.sgs_restarts, &mut rng)?;
        let incumbent_makespan = incumbent.makespan(p);

        let durations: Vec<f64> = (0..p.len())
            .map(|t| p.duration(t, assignment[t]))
            .collect();
        let demands: Vec<(f64, f64)> = (0..p.len())
            .map(|t| p.demand(assignment[t]))
            .collect();
        let bottom = {
            let order = p.topo_order();
            let mut b = vec![0.0f64; p.len()];
            for &u in order.iter().rev() {
                b[u] = durations[u]
                    + p.succs(u).iter().map(|&v| b[v]).fold(0.0f64, f64::max);
            }
            b
        };
        let root_lb = p.lower_bound(assignment);

        let mut search = Search {
            p,
            assignment,
            durations,
            demands,
            bottom,
            best: incumbent,
            best_makespan: incumbent_makespan,
            root_lb,
            stats: Stats::default(),
            limits: limits.clone(),
            deadline: t0 + limits.max_time,
            seen: HashMap::new(),
            exhausted: false,
            first_solution: true,
            found: false,
        };
        search.stats.sgs_evals = (sgs::ALL_RULES.len() + limits.sgs_restarts) as u64;

        let mut proved = incumbent_makespan <= root_lb + 1e-6;
        if p.len() <= 128 && !proved {
            let mut timeline =
                Timeline::seeded(p.capacity.vcpus, p.capacity.memory_gb, &p.preplaced);
            let root_mark = timeline.checkpoint();
            let mut start = vec![0.0f64; p.len()];
            let mut indeg: Vec<usize> = (0..p.len()).map(|t| p.preds(t).len()).collect();
            loop {
                search.stats.rungs += 1;
                search.found = false;
                search.exhausted = true;
                search.seen.clear();
                // Every DFS frame rolls back before returning, so the
                // timeline is already at the root; the rollback makes the
                // rung-reuse contract explicit (and is a cheap no-op).
                timeline.rollback(root_mark);
                search.dfs(0u128, &mut start, &mut indeg, &mut timeline, 0, 0.0);
                if search.best_makespan <= root_lb + 1e-6 {
                    proved = true; // UB met LB
                    break;
                }
                if !search.exhausted {
                    break; // global node/time budget ran out mid-rung
                }
                if !search.found {
                    // A complete rung found nothing below the incumbent's
                    // UB: the incumbent is the optimum.
                    proved = true;
                    break;
                }
            }
        }

        let mut best = search.best;
        best.optimal = proved;
        let mut stats = search.stats;
        stats.proved_optimal = proved;
        stats.solve_time = t0.elapsed();
        Ok((best, stats))
    }
}

impl<'a> Search<'a> {
    /// DFS over eligible-task insertions. `scheduled` is a bitset,
    /// `max_end` the latest end among placed tasks.
    #[allow(clippy::too_many_arguments)]
    fn dfs(
        &mut self,
        scheduled: u128,
        start: &mut Vec<f64>,
        indeg: &mut Vec<usize>,
        timeline: &mut Timeline,
        depth: usize,
        max_end: f64,
    ) {
        self.stats.nodes += 1;
        if self.stats.nodes >= self.limits.max_nodes
            || (self.stats.nodes % 512 == 0 && Instant::now() >= self.deadline)
        {
            self.exhausted = false;
            return;
        }
        let n = self.p.len();
        if depth == n {
            if max_end < self.best_makespan - 1e-9 {
                self.best = Schedule {
                    assignment: self.assignment.to_vec(),
                    start: start.clone(),
                    optimal: false,
                };
                self.best_makespan = max_end;
                self.found = true;
            }
            return;
        }

        // Dominance check on the scheduled set.
        if self.dominated(scheduled, start) {
            self.stats.pruned_dominance += 1;
            return;
        }

        // Eligible tasks, ordered by bottom level (critical first) —
        // branching order strongly affects pruning.
        let mut eligible: Vec<usize> = (0..n)
            .filter(|&t| scheduled & (1u128 << t) == 0 && indeg[t] == 0)
            .collect();
        eligible.sort_by(|&a, &b| self.bottom[b].total_cmp(&self.bottom[a]));

        // Capacity-envelope prune (opt-in): any completion that improves
        // the incumbent ends every remaining task strictly before the
        // horizon `best_makespan - 1e-9`, and no remaining task can start
        // before `t_low` (the min earliest-start over eligible tasks —
        // every unscheduled task is a descendant of, or is, an eligible
        // one). So the remaining cone's aggregate (demand × duration)
        // area must fit inside the capacity envelope over
        // [t_low, horizon) minus the area already occupied there, which
        // the indexed timeline reports as an O(points) aggregate via
        // `area_in`. If it cannot — on either resource — no descendant of
        // this node beats the incumbent and the subtree is cut. The
        // slack terms only weaken the prune, never its soundness.
        if self.limits.envelope_prune && !eligible.is_empty() {
            let t_low = eligible
                .iter()
                .map(|&t| {
                    self.p
                        .preds(t)
                        .iter()
                        .map(|&q| start[q] + self.durations[q])
                        .fold(self.p.release[t], f64::max)
                })
                .fold(f64::INFINITY, f64::min);
            let horizon = self.best_makespan - 1e-9;
            let (mut rem_cpu, mut rem_mem) = (0.0f64, 0.0f64);
            for t in 0..n {
                if scheduled & (1u128 << t) == 0 {
                    let (c, m) = self.demands[t];
                    rem_cpu += c * self.durations[t];
                    rem_mem += m * self.durations[t];
                }
            }
            let (occ_cpu, occ_mem) = timeline.area_in(t_low, horizon);
            let window = horizon - t_low;
            let avail_cpu = (self.p.capacity.vcpus + 1e-6) * window - occ_cpu;
            let avail_mem = (self.p.capacity.memory_gb + 1e-6) * window - occ_mem;
            if rem_cpu > avail_cpu + 1e-6 || rem_mem > avail_mem + 1e-6 {
                self.stats.pruned_envelope += 1;
                return;
            }
        }

        for t in eligible {
            let est = self
                .p
                .preds(t)
                .iter()
                .map(|&q| start[q] + self.durations[q])
                .fold(self.p.release[t], f64::max);
            let (cpu, mem) = self.demands[t];
            let s = timeline
                .earliest_fit(est, self.durations[t], cpu, mem)
                .expect("demands validated by the SGS incumbent at solve entry");
            let end = s + self.durations[t];

            // Lower bound of any completion through this insertion.
            let lb = (s + self.bottom[t]).max(max_end);
            if lb >= self.best_makespan - 1e-9 {
                self.stats.pruned_lb += 1;
                continue;
            }

            // Apply.
            let mark = timeline.checkpoint();
            timeline.place(s, self.durations[t], cpu, mem);
            start[t] = s;
            for &v in self.p.succs(t) {
                indeg[v] -= 1;
            }

            self.dfs(
                scheduled | (1u128 << t),
                start,
                indeg,
                timeline,
                depth + 1,
                max_end.max(end),
            );

            // Undo (bit-exact: the rollback restores the pre-placement
            // profile bytes instead of re-subtracting floats).
            timeline.rollback(mark);
            for &v in self.p.succs(t) {
                indeg[v] += 1;
            }

            // Ladder rung: one improving solution tightened the UB; the
            // rung is done — unwind (every frame re-checks this flag).
            if self.first_solution && self.found {
                return;
            }
            if self.best_makespan <= self.root_lb + 1e-6 {
                return; // proven optimal
            }
            if self.stats.nodes >= self.limits.max_nodes {
                self.exhausted = false;
                return;
            }
        }
    }

    /// True if a previously seen end-time profile for the same scheduled
    /// set pointwise-dominates (every task ends no later than) this one.
    fn dominated(&mut self, scheduled: u128, start: &[f64]) -> bool {
        if scheduled == 0 {
            return false;
        }
        let profile: Vec<f64> = (0..self.p.len())
            .map(|t| {
                if scheduled & (1u128 << t) != 0 {
                    start[t] + self.durations[t]
                } else {
                    0.0
                }
            })
            .collect();
        let entry = self.seen.entry(scheduled).or_default();
        for old in entry.iter() {
            if old
                .iter()
                .zip(profile.iter())
                .all(|(o, n)| *o <= *n + 1e-9)
            {
                return true;
            }
        }
        // Keep the store bounded per subset.
        if entry.len() < 4 {
            entry.push(profile);
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::generator::arbitrary_dag;
    use crate::dag::workloads::{dag1, dag2, fig1_dag};
    use crate::predictor::OraclePredictor;
    use crate::util::propcheck;
    use crate::Predictor;

    fn problem_from(dags: Vec<crate::Dag>, cap: Capacity) -> Problem {
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags
            .iter()
            .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
            .collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let releases = vec![0.0; dags.len()];
        Problem::new(&dags, &releases, cap, space, grid, CostModel::OnDemand)
    }

    #[test]
    fn solves_fig1_to_optimality() {
        let p = problem_from(vec![fig1_dag()], Capacity::micro());
        let assignment = vec![p.feasible[0]; p.len()];
        let solver = CpSolver::new(Limits::default());
        let (s, stats) = solver.solve(&p, &assignment).unwrap();
        s.validate(&p).unwrap();
        assert!(stats.proved_optimal, "4-task DAG must solve exactly");
        assert!(s.optimal);
    }

    #[test]
    fn optimal_at_least_lower_bound() {
        let p = problem_from(vec![dag1()], Capacity::micro());
        let assignment = vec![p.feasible[2]; p.len()];
        let (s, _) = CpSolver::new(Limits::default()).solve(&p, &assignment).unwrap();
        assert!(s.makespan(&p) + 1e-6 >= p.lower_bound(&assignment));
    }

    #[test]
    fn never_worse_than_sgs() {
        let p = problem_from(vec![dag1(), dag2()], Capacity::micro());
        let assignment = vec![p.feasible[1]; p.len()];
        let mut rng = Rng::new(1);
        let ub = sgs::multistart_sgs(&p, &assignment, 8, &mut rng).unwrap();
        let (s, _) = CpSolver::new(Limits::default()).solve(&p, &assignment).unwrap();
        assert!(s.makespan(&p) <= ub.makespan(&p) + 1e-6);
        s.validate(&p).unwrap();
    }

    #[test]
    fn anytime_under_tiny_budget() {
        let p = problem_from(vec![dag1(), dag2()], Capacity::micro());
        let assignment = vec![p.feasible[0]; p.len()];
        let (s, stats) = CpSolver::new(Limits {
            max_nodes: 10,
            max_time: Duration::from_millis(50),
            sgs_restarts: 1,
            envelope_prune: false,
        })
        .solve(&p, &assignment)
        .unwrap();
        // Must still return a valid schedule even with a starved budget.
        s.validate(&p).unwrap();
        assert!(stats.nodes <= 11);
    }

    #[test]
    fn cp_packs_around_occupancy_seed() {
        // Full-capacity reservation over [0, 50): both the SGS incumbent
        // and every branch-and-bound insertion must land after it.
        let cap = Capacity::micro();
        let p = problem_from(vec![fig1_dag()], cap)
            .with_occupancy(vec![(0.0, 50.0, cap.vcpus, cap.memory_gb)], 0.0);
        let assignment = vec![p.feasible[0]; p.len()];
        let (s, _) = CpSolver::new(Limits::default()).solve(&p, &assignment).unwrap();
        s.validate(&p).unwrap();
        for t in 0..p.len() {
            assert!(
                s.start[t] + 1e-9 >= 50.0,
                "task {t} scheduled at {} inside the reservation",
                s.start[t]
            );
        }
    }

    #[test]
    fn tight_capacity_forces_serialization() {
        // Capacity for exactly one task at a time -> makespan = sum.
        let p = problem_from(vec![fig1_dag()], Capacity::new(16.0, 64.0));
        let assignment = vec![p.feasible[0]; p.len()];
        let (cpu, _) = p.demand(assignment[0]);
        assert_eq!(cpu, 16.0);
        let (s, _) = CpSolver::new(Limits::default()).solve(&p, &assignment).unwrap();
        s.validate(&p).unwrap();
        let total: f64 = (0..p.len()).map(|t| p.duration(t, assignment[t])).sum();
        assert!((s.makespan(&p) - total).abs() < 1e-6);
    }

    #[test]
    fn property_cp_beats_or_ties_every_rule() {
        propcheck::check(15, |rng| {
            let dag = arbitrary_dag(rng, 8);
            let p = problem_from(vec![dag], Capacity::micro());
            let assignment: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let (s, _) = CpSolver::new(Limits::default())
                .solve(&p, &assignment)
                .map_err(|e| e.to_string())?;
            s.validate(&p).map_err(|e| e.to_string())?;
            for &rule in sgs::ALL_RULES {
                let prio = sgs::priorities(&p, &assignment, rule);
                let single = sgs::serial_sgs(&p, &assignment, &prio).map_err(|e| e.to_string())?;
                if s.makespan(&p) > single.makespan(&p) + 1e-6 {
                    return Err(format!(
                        "CP {} worse than {:?} {}",
                        s.makespan(&p),
                        rule,
                        single.makespan(&p)
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn envelope_prune_preserves_the_proved_optimum() {
        // On a search that completes, the envelope prune only removes
        // subtrees that cannot beat the incumbent, so the proved optimal
        // makespan is unchanged (the argmin schedule may differ — both
        // are optima, found along different traversals).
        let p = problem_from(vec![dag1(), dag2()], Capacity::micro());
        let assignment = vec![p.feasible[0]; p.len()];
        let (off, off_stats) = CpSolver::new(Limits::default()).solve(&p, &assignment).unwrap();
        let (on, on_stats) = CpSolver::new(Limits::exact()).solve(&p, &assignment).unwrap();
        off.validate(&p).unwrap();
        on.validate(&p).unwrap();
        assert!(off_stats.proved_optimal && on_stats.proved_optimal);
        assert!(
            (off.makespan(&p) - on.makespan(&p)).abs() <= 1e-9,
            "envelope prune changed the proved optimum: {} vs {}",
            off.makespan(&p),
            on.makespan(&p)
        );
        assert_eq!(
            off_stats.pruned_envelope, 0,
            "default limits must never envelope-prune"
        );
    }

    #[test]
    fn envelope_prune_packs_around_occupancy_seed() {
        // The area bound must account for the preplaced reservation via
        // `Timeline::area_in` on the seeded timeline — a full-capacity
        // block over [0, 50) is occupied area, not free envelope.
        let cap = Capacity::micro();
        let p = problem_from(vec![fig1_dag()], cap)
            .with_occupancy(vec![(0.0, 50.0, cap.vcpus, cap.memory_gb)], 0.0);
        let assignment = vec![p.feasible[0]; p.len()];
        let (s, stats) = CpSolver::new(Limits::exact()).solve(&p, &assignment).unwrap();
        s.validate(&p).unwrap();
        for t in 0..p.len() {
            assert!(s.start[t] + 1e-9 >= 50.0, "task {t} inside the reservation");
        }
        // Same optimum as the unpruned solve on the same seeded problem.
        let (base, _) = CpSolver::new(Limits::default()).solve(&p, &assignment).unwrap();
        assert!((s.makespan(&p) - base.makespan(&p)).abs() <= 1e-9);
        let _ = stats.pruned_envelope; // counter is telemetry, not an invariant here
    }

    #[test]
    fn property_envelope_prune_is_outcome_neutral_when_complete() {
        propcheck::check(10, |rng| {
            let dag = arbitrary_dag(rng, 6);
            let p = problem_from(vec![dag], Capacity::micro());
            let assignment: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let (off, off_stats) = CpSolver::new(Limits::default())
                .solve(&p, &assignment)
                .map_err(|e| e.to_string())?;
            let (on, on_stats) = CpSolver::new(Limits::exact())
                .solve(&p, &assignment)
                .map_err(|e| e.to_string())?;
            on.validate(&p).map_err(|e| e.to_string())?;
            if !(off_stats.proved_optimal && on_stats.proved_optimal) {
                return Err("6-task search must complete under default budgets".into());
            }
            if (off.makespan(&p) - on.makespan(&p)).abs() > 1e-9 {
                return Err(format!(
                    "envelope prune changed the optimum: {} vs {}",
                    off.makespan(&p),
                    on.makespan(&p)
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn ladder_matches_exact_on_the_figure_workload() {
        let p = problem_from(vec![dag1(), dag2()], Capacity::micro());
        let assignment = vec![p.feasible[0]; p.len()];
        let (exact, exact_stats) =
            CpSolver::new(Limits::exact()).solve(&p, &assignment).unwrap();
        let (ladder, ladder_stats) = CpSolver::new(Limits::ladder())
            .solve_ladder(&p, &assignment)
            .unwrap();
        ladder.validate(&p).unwrap();
        assert!(exact_stats.proved_optimal && ladder_stats.proved_optimal);
        assert!(ladder.optimal);
        assert!(
            (exact.makespan(&p) - ladder.makespan(&p)).abs() <= 1e-9,
            "ladder optimum {} != exact optimum {}",
            ladder.makespan(&p),
            exact.makespan(&p)
        );
        assert!(
            ladder_stats.rungs >= 1
                || ladder.makespan(&p) <= p.lower_bound(&assignment) + 1e-6,
            "rungs only stay at zero when the seed incumbent meets the root LB"
        );
        assert!(
            ladder_stats.sgs_evals >= sgs::ALL_RULES.len() as u64,
            "incumbent seeding is charged to the budget currency"
        );
    }

    #[test]
    fn property_ladder_proves_the_same_optimum_as_exact() {
        propcheck::check(10, |rng| {
            let dag = arbitrary_dag(rng, 6);
            let p = problem_from(vec![dag], Capacity::micro());
            let assignment: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let (exact, exact_stats) = CpSolver::new(Limits::exact())
                .solve(&p, &assignment)
                .map_err(|e| e.to_string())?;
            let (ladder, ladder_stats) = CpSolver::new(Limits::ladder())
                .solve_ladder(&p, &assignment)
                .map_err(|e| e.to_string())?;
            ladder.validate(&p).map_err(|e| e.to_string())?;
            if !(exact_stats.proved_optimal && ladder_stats.proved_optimal) {
                return Err("6-task searches must complete under default budgets".into());
            }
            if (exact.makespan(&p) - ladder.makespan(&p)).abs() > 1e-9 {
                return Err(format!(
                    "ladder optimum {} != exact optimum {}",
                    ladder.makespan(&p),
                    exact.makespan(&p)
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn ladder_stays_anytime_valid_under_a_starved_budget() {
        // A global node budget that dies mid-rung must still hand back a
        // feasible (SGS-seeded or partially improved) incumbent, unproven.
        let p = problem_from(vec![dag1(), dag2()], Capacity::micro());
        let assignment = vec![p.feasible[0]; p.len()];
        let (s, stats) = CpSolver::new(Limits {
            max_nodes: 10,
            max_time: Duration::from_millis(50),
            sgs_restarts: 1,
            envelope_prune: true,
        })
        .solve_ladder(&p, &assignment)
        .unwrap();
        s.validate(&p).unwrap();
        if !stats.proved_optimal {
            assert!(!s.optimal);
        }
        assert!(stats.rungs >= 1 || s.makespan(&p) <= p.lower_bound(&assignment) + 1e-6);
    }

    #[test]
    fn property_optimal_flag_implies_lb_or_complete() {
        propcheck::check(10, |rng| {
            let dag = arbitrary_dag(rng, 6);
            let p = problem_from(vec![dag], Capacity::micro());
            let assignment: Vec<usize> = (0..p.len())
                .map(|_| p.feasible[rng.below(p.feasible.len())])
                .collect();
            let (s, stats) = CpSolver::new(Limits::default())
                .solve(&p, &assignment)
                .map_err(|e| e.to_string())?;
            if stats.proved_optimal && !s.optimal {
                return Err("stats/schedule optimal flags disagree".into());
            }
            s.validate(&p).map_err(|e| e.to_string())
        });
    }
}
