//! The extended RCPSP of §4.2: scheduling with *malleable* durations and
//! demands — both are functions of the per-task configuration choice,
//! which is itself a decision variable (the key departure from classic
//! RCPSP that enables co-optimization).
//!
//! A problem can additionally carry an **occupancy seed**
//! ([`Problem::with_occupancy`]): rectangles of capacity already reserved
//! by work admitted earlier (continuous multi-tenant admission) plus an
//! admission floor. Every scheduler in the repo packs around the seed
//! through the shared block-indexed
//! [`Timeline`](super::timeline::Timeline)
//! kernel, which generalizes the replan-only pre-seeded timeline of
//! [`SuffixSgs`](super::sgs::SuffixSgs) to cross-round, cross-DAG
//! occupancy.

use super::objective::Sla;
use crate::cluster::{Capacity, Config, ConfigSpace, CostModel};
use crate::dag::Dag;
use crate::predictor::Grid;

/// One reserved rectangle on the cluster timeline:
/// `(start, duration, vcpus, memory_gb)` in the problem's (virtual) time
/// base. The scheduling primitives treat these as immovable blockers.
pub type Reservation = (f64, f64, f64, f64);

/// A task flattened into the multi-DAG optimization problem.
#[derive(Debug, Clone)]
pub struct FlatTask {
    /// Which input DAG this task came from.
    pub dag: usize,
    /// Index within that DAG.
    pub local: usize,
    /// Fully qualified scoped name (`"{dag}/{task}"`).
    pub name: String,
}

/// One co-optimization problem instance (possibly spanning several DAGs —
/// AGORA "supports optimization for one DAG as well as multiple DAGs").
#[derive(Debug, Clone)]
pub struct Problem {
    /// Flattened tasks of every input DAG, in concatenation order.
    pub tasks: Vec<FlatTask>,
    /// Precedence pairs (pred, succ) over global task indices — the set P.
    pub precedence: Vec<(usize, usize)>,
    /// Earliest allowed start per task (DAG submission time; 0 for batch).
    pub release: Vec<f64>,
    /// Cluster capacity — the R_m of Eq. 4.
    pub capacity: Capacity,
    /// Candidate configuration space shared by all tasks.
    pub space: ConfigSpace,
    /// Indices into `space` that fit the capacity (precomputed).
    pub feasible: Vec<usize>,
    /// Predicted durations `d[t][c]` — the malleable-runtime extension.
    pub grid: Grid,
    /// Pricing model used for Eq. 6 cost terms.
    pub cost_model: CostModel,
    /// Capacity already reserved by previously admitted work — rectangles
    /// every scheduler must pack around. Empty for standalone problems.
    pub preplaced: Vec<Reservation>,
    /// Earliest instant any task of this problem may start (the admission
    /// instant under continuous admission; 0 for standalone problems).
    /// [`Problem::with_occupancy`] folds it into `release`, so schedulers
    /// that respect release times respect the floor for free.
    pub floor: f64,
    /// Per-DAG service-level agreements (deadlines in this problem's
    /// time base), indexed by DAG. Defaults to [`Sla::none`] per DAG —
    /// fully inert until [`Problem::with_slas`] attaches bounded ones.
    pub slas: Vec<Sla>,
    preds: Vec<Vec<usize>>,
    succs: Vec<Vec<usize>>,
    /// Cached `space.instance_count()` — the SA proposer reads it on
    /// every move and must not rescan the space each time.
    space_instances: usize,
    /// Cached `space.has_spot()` (same hot path).
    space_has_spot: bool,
}

impl Problem {
    /// Assemble a problem from DAGs + a prediction grid whose task rows
    /// follow the DAG-concatenation order.
    ///
    /// Under [`CostModel::Market`] the grid rows of **spot**
    /// configurations are inflated by the expected interruption overhead
    /// ([`crate::cluster::expected_spot_overhead`]), so both sides of
    /// the Eq. 1 trade-off see preemption risk: the runtime goal avoids
    /// spot capacity, the cost goal pays the (inflated-duration x
    /// discounted-price) product. Every other cost model leaves the grid
    /// untouched — bit-identical to the pre-market problem.
    pub fn new(
        dags: &[Dag],
        releases: &[f64],
        capacity: Capacity,
        space: ConfigSpace,
        grid: Grid,
        cost_model: CostModel,
    ) -> Self {
        assert_eq!(dags.len(), releases.len());
        let mut tasks = Vec::new();
        let mut precedence = Vec::new();
        let mut release = Vec::new();
        let mut offset = 0usize;
        for (di, dag) in dags.iter().enumerate() {
            for (li, t) in dag.tasks.iter().enumerate() {
                tasks.push(FlatTask {
                    dag: di,
                    local: li,
                    // The canonical scoped name doubles as the event-log
                    // database key the coordinator writes realized runs
                    // back under — see `predictor::scoped_task_name`.
                    name: crate::predictor::scoped_task_name(&dag.name, &t.name),
                });
                release.push(releases[di]);
            }
            for &(a, b) in &dag.edges {
                precedence.push((offset + a, offset + b));
            }
            offset += dag.len();
        }
        assert_eq!(grid.tasks(), tasks.len(), "grid rows must match task count");

        // Market pricing: fold the expected spot-preemption re-run work
        // into the predicted durations of spot configurations.
        let mut grid = grid;
        if let CostModel::Market { interrupt_rate } = &cost_model {
            if *interrupt_rate > 0.0 {
                for row in grid.durations.iter_mut() {
                    for (c, d) in row.iter_mut().enumerate() {
                        let cfg = &space.configs[c];
                        if cfg.is_spot() {
                            *d *= crate::cluster::expected_spot_overhead(
                                crate::cluster::spot_lambda(cfg, *d, *interrupt_rate),
                            );
                        }
                    }
                }
            }
        }

        let n = tasks.len();
        let mut preds = vec![Vec::new(); n];
        let mut succs = vec![Vec::new(); n];
        for &(a, b) in &precedence {
            succs[a].push(b);
            preds[b].push(a);
        }
        let feasible = space.feasible(&capacity);
        assert!(!feasible.is_empty(), "no feasible configuration fits the cluster");
        let space_instances = space.instance_count();
        let space_has_spot = space.has_spot();

        Problem {
            tasks,
            precedence,
            release,
            capacity,
            space,
            feasible,
            grid,
            cost_model,
            preplaced: Vec::new(),
            floor: 0.0,
            slas: vec![Sla::none(); dags.len()],
            preds,
            succs,
            space_instances,
            space_has_spot,
        }
    }

    /// Seed this problem with pre-existing reservations and an admission
    /// floor (continuous multi-tenant admission): every task must start at
    /// or after `floor` (folded into the per-task release times) and every
    /// scheduler packs around the `preplaced` rectangles. With an empty
    /// seed and `floor <= 0` this is a no-op and scheduling is
    /// bit-identical to the unseeded problem.
    pub fn with_occupancy(mut self, preplaced: Vec<Reservation>, floor: f64) -> Self {
        for r in &mut self.release {
            *r = r.max(floor);
        }
        self.preplaced = preplaced;
        self.floor = floor;
        self
    }

    /// Attach per-DAG SLAs (deadlines in this problem's time base). The
    /// vector must carry one entry per input DAG.
    pub fn with_slas(mut self, slas: Vec<Sla>) -> Self {
        assert_eq!(
            slas.len(),
            self.slas.len(),
            "one SLA per DAG ({} DAGs)",
            self.slas.len()
        );
        self.slas = slas;
        self
    }

    /// Per-DAG completion lower bounds under **best-case** durations:
    /// the critical-path pass of [`Problem::critical_path_lb`] with each
    /// task at its minimum feasible duration, maxed per source DAG.
    /// Resources and co-tenants are ignored, so this is a true lower
    /// bound on any feasible schedule's per-DAG completion — the
    /// provable side of SLA admission: a DAG whose bound already exceeds
    /// its deadline cannot meet it under *any* schedule.
    pub fn dag_lower_bounds(&self) -> Vec<f64> {
        let order = self.topo_order();
        let mut finish = vec![0.0f64; self.len()];
        let mut out = vec![0.0f64; self.slas.len()];
        for &u in &order {
            let start = self.preds[u]
                .iter()
                .map(|&p| finish[p])
                .fold(self.release[u], f64::max);
            let best = self
                .feasible
                .iter()
                .map(|&c| self.duration(u, c))
                .fold(f64::INFINITY, f64::min);
            finish[u] = start + best;
            let d = self.tasks[u].dag;
            out[d] = out[d].max(finish[u]);
        }
        out
    }

    /// Per-DAG provable SLA infeasibility: `true` where a **hard**
    /// bounded deadline sits below the DAG's completion lower bound
    /// ([`Problem::dag_lower_bounds`]) — no schedule can meet it, so
    /// admission may reject outright. Soft and unbounded SLAs are never
    /// flagged.
    pub fn sla_infeasible(&self) -> Vec<bool> {
        let lbs = self.dag_lower_bounds();
        self.slas
            .iter()
            .zip(&lbs)
            .map(|(sla, &lb)| sla.hard && !sla.is_unbounded() && lb > sla.deadline)
            .collect()
    }

    /// Number of flat tasks.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Whether the problem has no tasks.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Direct predecessors of a flat task.
    pub fn preds(&self, t: usize) -> &[usize] {
        &self.preds[t]
    }

    /// Direct successors of a flat task.
    pub fn succs(&self, t: usize) -> &[usize] {
        &self.succs[t]
    }

    /// One past the largest catalog index in this problem's space —
    /// cached at construction for the SA proposal hot path.
    pub fn instance_count(&self) -> usize {
        self.space_instances
    }

    /// Whether this problem's space sells spot capacity (cached at
    /// construction; arms the SA purchase-toggle move).
    pub fn space_has_spot(&self) -> bool {
        self.space_has_spot
    }

    /// Predicted duration of task `t` under config index `c` — d_ijc.
    pub fn duration(&self, t: usize, c: usize) -> f64 {
        self.grid.get(t, c)
    }

    /// Resource demand of config index `c` — r_jtmc (constant over the
    /// task's execution window, per the paper's formulation).
    pub fn demand(&self, c: usize) -> (f64, f64) {
        let cfg = &self.space.configs[c];
        (cfg.vcpus(), cfg.memory_gb())
    }

    /// The configuration at index `c` of the space.
    pub fn config(&self, c: usize) -> &Config {
        &self.space.configs[c]
    }

    /// Dollar cost of task `t` under config `c` (Eq. 6 component) —
    /// schedule-independent, which is what lets the inner solver optimize
    /// makespan alone while the outer loop owns cost.
    pub fn cost(&self, t: usize, c: usize) -> f64 {
        self.cost_model
            .cost(&self.space.configs[c], self.duration(t, c))
    }

    /// Total cost of a config assignment (Eq. 6).
    pub fn assignment_cost(&self, assignment: &[usize]) -> f64 {
        assignment
            .iter()
            .enumerate()
            .map(|(t, &c)| self.cost(t, c))
            .sum()
    }

    /// Topological order of the flattened task set.
    pub fn topo_order(&self) -> Vec<usize> {
        let n = self.len();
        let mut indeg: Vec<usize> = (0..n).map(|i| self.preds[i].len()).collect();
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            for &v in &self.succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        assert_eq!(queue.len(), n, "problem contains a cycle");
        queue
    }

    /// Critical-path lower bound on makespan for a given assignment
    /// (ignores resources — always a valid LB).
    pub fn critical_path_lb(&self, assignment: &[usize]) -> f64 {
        let order = self.topo_order();
        let mut finish = vec![0.0f64; self.len()];
        for &u in &order {
            let start = self.preds[u]
                .iter()
                .map(|&p| finish[p])
                .fold(self.release[u], f64::max);
            finish[u] = start + self.duration(u, assignment[u]);
        }
        finish.into_iter().fold(0.0, f64::max)
    }

    /// Energy (area) lower bound: total cpu-seconds / cluster cpus, and
    /// the memory analogue — valid because no preemption can beat the
    /// aggregate-capacity constraint (Eq. 4 integrated over time).
    pub fn energy_lb(&self, assignment: &[usize]) -> f64 {
        let mut cpu_area = 0.0;
        let mut mem_area = 0.0;
        for (t, &c) in assignment.iter().enumerate() {
            let d = self.duration(t, c);
            let (cpu, mem) = self.demand(c);
            cpu_area += cpu * d;
            mem_area += mem * d;
        }
        let release_min = self.release.iter().cloned().fold(f64::INFINITY, f64::min);
        let release_min = if release_min.is_finite() { release_min } else { 0.0 };
        release_min + (cpu_area / self.capacity.vcpus).max(mem_area / self.capacity.memory_gb)
    }

    /// Combined makespan lower bound.
    pub fn lower_bound(&self, assignment: &[usize]) -> f64 {
        self.critical_path_lb(assignment)
            .max(self.energy_lb(assignment))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::Predictor;

    pub fn toy_problem() -> Problem {
        let dags = vec![dag1(), dag2()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags
            .iter()
            .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
            .collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &dags,
            &[0.0, 0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    #[test]
    fn flattening_preserves_structure() {
        let p = toy_problem();
        assert_eq!(p.len(), 16);
        assert_eq!(p.tasks[8].dag, 1);
        // dag1 has 9 edges, dag2 has 7
        assert_eq!(p.precedence.len(), 16);
        // cross-DAG edges must not exist
        for &(a, b) in &p.precedence {
            assert_eq!(p.tasks[a].dag, p.tasks[b].dag);
        }
    }

    #[test]
    fn durations_and_costs_consistent() {
        let p = toy_problem();
        let c = p.feasible[0];
        for t in 0..p.len() {
            let d = p.duration(t, c);
            assert!(d > 0.0);
            let cost = p.cost(t, c);
            let expect = p.space.configs[c].hourly_cost() * d / 3600.0;
            assert!((cost - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn cp_lower_bound_at_least_longest_task() {
        let p = toy_problem();
        let assignment = vec![p.feasible[0]; p.len()];
        let lb = p.critical_path_lb(&assignment);
        let longest = (0..p.len())
            .map(|t| p.duration(t, assignment[t]))
            .fold(0.0, f64::max);
        assert!(lb >= longest);
    }

    #[test]
    fn energy_bound_positive() {
        let p = toy_problem();
        let assignment = vec![p.feasible[0]; p.len()];
        assert!(p.energy_lb(&assignment) > 0.0);
        assert!(p.lower_bound(&assignment) >= p.energy_lb(&assignment));
    }

    #[test]
    fn problems_default_to_unbounded_slas() {
        let p = toy_problem();
        assert_eq!(p.slas.len(), 2);
        assert!(p.slas.iter().all(|s| s.is_unbounded() && !s.hard));
        assert_eq!(p.sla_infeasible(), vec![false, false]);
    }

    #[test]
    fn dag_lower_bounds_are_per_dag_and_positive() {
        let p = toy_problem();
        let lbs = p.dag_lower_bounds();
        assert_eq!(lbs.len(), 2);
        assert!(lbs.iter().all(|&lb| lb > 0.0));
        // Best-case durations: the bound cannot exceed the critical path
        // of any concrete assignment.
        let assignment = vec![p.feasible[0]; p.len()];
        let cp = p.critical_path_lb(&assignment);
        assert!(lbs.iter().all(|&lb| lb <= cp + 1e-9));
    }

    #[test]
    fn sla_infeasible_flags_only_provably_impossible_hard_deadlines() {
        let lbs = toy_problem().dag_lower_bounds();
        // A hard deadline below the lower bound is provably impossible;
        // a soft one never flags, however tight.
        let p = toy_problem().with_slas(vec![Sla::hard(lbs[0] * 0.5), Sla::soft(0.0, 1.0)]);
        assert_eq!(p.sla_infeasible(), vec![true, false]);
        // A hard deadline above the bound is not provably impossible.
        let p = toy_problem().with_slas(vec![Sla::hard(lbs[0] * 2.0), Sla::none()]);
        assert_eq!(p.sla_infeasible(), vec![false, false]);
    }

    #[test]
    fn market_cost_model_inflates_spot_rows_only() {
        let dags = vec![dag1()];
        let space = ConfigSpace::market();
        let profiles: Vec<_> = dags[0].tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor {
            profiles: profiles.clone(),
        }
        .predict(&space);
        let raw = grid.clone();
        let rate = 1.5;
        let p = Problem::new(
            &dags,
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::Market {
                interrupt_rate: rate,
            },
        );
        for t in 0..p.len() {
            for (c, cfg) in p.space.configs.iter().enumerate() {
                let d0 = raw.get(t, c);
                let d = p.duration(t, c);
                if cfg.is_spot() {
                    let want = d0
                        * crate::cluster::expected_spot_overhead(
                            crate::cluster::spot_lambda(cfg, d0, rate),
                        );
                    assert!((d - want).abs() < 1e-9, "task {t} config {c}");
                    assert!(d > d0, "spot duration must be inflated");
                } else {
                    assert_eq!(d, d0, "on-demand durations untouched");
                }
            }
        }
    }

    #[test]
    fn releases_delay_lower_bound() {
        let dags = vec![dag1()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags[0].tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let p = Problem::new(
            &dags,
            &[1000.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        );
        let assignment = vec![p.feasible[0]; p.len()];
        assert!(p.critical_path_lb(&assignment) > 1000.0);
    }
}
