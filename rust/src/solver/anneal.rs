//! Simulated-annealing outer loop — Algorithm 1 of the paper.
//!
//! SA proposes per-task configuration vectors; the CP solver (cp.rs)
//! schedules each proposal to (near-)optimal makespan; cost follows from
//! the configuration alone (Eq. 6). Acceptance is Metropolis on the Eq. 1
//! energy: improvements always accepted, regressions accepted with
//! probability exp(-dE/T) so the search escapes local minima.
//!
//! As in the paper, the energy is a sum of *percentage* improvements, so
//! a constant starting temperature (T0 = 1) works at every problem size;
//! the cooling rate is a function of n, giving O(n) iterations to a fixed
//! convergence criterion.

use std::time::{Duration, Instant};

use super::cp::{CpSolver, Limits};
use super::objective::Objective;
use super::rcpsp::Problem;
use super::schedule::Schedule;
use crate::util::Rng;

/// Annealing hyper-parameters.
#[derive(Debug, Clone)]
pub struct AnnealParams {
    /// Starting temperature; None = calibrated from a warmup sample
    /// (mean |dE| of the first proposals), which adapts the Metropolis
    /// acceptance to the actual energy scale of the instance. The paper
    /// fixes T0 = 1 on percentage energies; calibration preserves that
    /// scale-freeness while giving meaningful rejection pressure.
    pub t0: Option<f64>,
    /// Multiplicative cooling per iteration; None = derived from n.
    pub cooling: Option<f64>,
    /// Stop after this many iterations without improvement.
    pub patience: usize,
    /// Hard iteration cap.
    pub max_iters: usize,
    /// Wall-clock budget.
    pub max_time: Duration,
    /// Inner CP budget per iteration.
    pub inner_limits: Limits,
    /// Tasks perturbed per proposal.
    pub moves_per_proposal: usize,
}

impl Default for AnnealParams {
    fn default() -> Self {
        AnnealParams {
            t0: None,
            cooling: None,
            patience: 400,
            max_iters: 2_000,
            max_time: Duration::from_secs(45),
            inner_limits: Limits::inner_loop(),
            moves_per_proposal: 1,
        }
    }
}

impl AnnealParams {
    /// Cooling rate as a function of problem size (paper §4.3: "the
    /// cooling rate we define as a function of n"): larger problems cool
    /// slower so the expected accepted-move count scales linearly.
    pub fn cooling_for(&self, n: usize) -> f64 {
        self.cooling
            .unwrap_or_else(|| 1.0 - 1.0 / (20.0 * (n.max(1) as f64)))
    }

    /// Fast preset for unit tests and the overhead micro-measurements.
    pub fn fast() -> Self {
        AnnealParams {
            patience: 150,
            max_iters: 600,
            max_time: Duration::from_secs(10),
            ..Default::default()
        }
    }
}

/// Propose a neighbour of a config assignment: half the time a uniform
/// re-draw of one task's config, half the time a single-dimension tweak
/// (node-ladder step / instance step / Spark preset) — the classic SA
/// neighbourhood that makes small cost/runtime trades discoverable.
pub fn propose(
    p: &Problem,
    current: &[usize],
    moves: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    let mut proposal = current.to_vec();
    for _ in 0..moves {
        let t = rng.below(p.len());
        let cur = p.space.configs[proposal[t]];
        let candidate = if rng.chance(0.5) {
            p.feasible[rng.below(p.feasible.len())]
        } else {
            // Tweak one dimension; fall back to uniform if the tweaked
            // config is not in the feasible set.
            let mut cfg = cur;
            match rng.below(3) {
                0 => {
                    // node ladder step
                    let ladder = crate::cluster::config::NODE_LADDER;
                    let pos = ladder.iter().position(|&n| n == cfg.nodes).unwrap_or(0);
                    let next = if rng.chance(0.5) {
                        pos.saturating_sub(1)
                    } else {
                        (pos + 1).min(ladder.len() - 1)
                    };
                    cfg.nodes = ladder[next];
                }
                1 => {
                    let count = crate::cluster::catalog::M5_CATALOG.len();
                    cfg.instance = if rng.chance(0.5) {
                        cfg.instance.saturating_sub(1)
                    } else {
                        (cfg.instance + 1).min(count - 1)
                    };
                }
                _ => {
                    cfg.spark = rng.below(crate::cluster::config::SPARK_PRESETS.len());
                }
            }
            match p.space.configs.iter().position(|c| *c == cfg) {
                Some(idx) if p.feasible.contains(&idx) => idx,
                _ => p.feasible[rng.below(p.feasible.len())],
            }
        };
        proposal[t] = candidate;
    }
    proposal
}

/// Iteration telemetry (overhead analysis, Fig. 10).
#[derive(Debug, Clone, Default)]
pub struct AnnealStats {
    pub iterations: usize,
    pub accepted: usize,
    pub improved: usize,
    pub inner_nodes: u64,
    pub wall_time: Duration,
    /// Energy trace (best-so-far per iteration), for convergence plots.
    pub trace: Vec<f64>,
}

/// Result of the co-optimization.
#[derive(Debug, Clone)]
pub struct AnnealResult {
    pub schedule: Schedule,
    pub makespan: f64,
    pub cost: f64,
    pub energy: f64,
    pub stats: AnnealStats,
}

/// Algorithm 1: co-optimize configurations (SA) and schedule (CP).
pub fn anneal(
    p: &Problem,
    objective: &Objective,
    initial: &[usize],
    params: &AnnealParams,
    rng: &mut Rng,
) -> AnnealResult {
    let t_start = Instant::now();
    let solver = CpSolver::new(params.inner_limits.clone());
    let cooling = params.cooling_for(p.len());

    // Evaluate the initial configuration.
    let mut current = initial.to_vec();
    let (mut cur_sched, stats0) = solver.solve(p, &current);
    let mut cur_makespan = cur_sched.makespan(p);
    let mut cur_cost = cur_sched.cost(p);
    let mut cur_energy = objective.energy(cur_makespan, cur_cost);

    let mut best = cur_sched.clone();
    let mut best_makespan = cur_makespan;
    let mut best_cost = cur_cost;
    let mut best_energy = cur_energy;

    let mut stats = AnnealStats {
        inner_nodes: stats0.nodes,
        ..Default::default()
    };

    // Warmup calibration: sample a few proposals to learn the energy
    // scale, then set T0 so typical regressions are accepted with
    // probability ~exp(-1) at the start and the walk turns greedy as the
    // temperature cools.
    let mut temperature = match params.t0 {
        Some(t0) => t0,
        None => {
            let warmup = 12.min(params.max_iters / 4).max(3);
            let mut des = Vec::new();
            for _ in 0..warmup {
                let proposal = propose(p, &current, params.moves_per_proposal, rng);
                let (sched, cp_stats) = solver.solve(p, &proposal);
                stats.inner_nodes += cp_stats.nodes;
                let e = objective.energy(sched.makespan(p), sched.cost(p));
                if e.is_finite() {
                    des.push((e - cur_energy).abs());
                    // Greedy seed: keep strict improvements found during
                    // warmup (they are free information).
                    if e < cur_energy {
                        current = proposal;
                        cur_sched = sched;
                        cur_makespan = cur_sched.makespan(p);
                        cur_cost = cur_sched.cost(p);
                        cur_energy = e;
                        if e < best_energy {
                            best = cur_sched.clone();
                            best_makespan = cur_makespan;
                            best_cost = cur_cost;
                            best_energy = e;
                        }
                    }
                }
            }
            let mean = if des.is_empty() {
                0.01
            } else {
                des.iter().sum::<f64>() / des.len() as f64
            };
            (0.8 * mean).max(1e-4)
        }
    };
    let mut stale = 0usize;

    while stats.iterations < params.max_iters
        && stale < params.patience
        && t_start.elapsed() < params.max_time
    {
        stats.iterations += 1;

        // c <- get_new_configuration(c): perturb a few tasks.
        let proposal = propose(p, &current, params.moves_per_proposal, rng);

        // M_new, C_new <- SAT_Solver(c, d, P, R)
        let (sched, cp_stats) = solver.solve(p, &proposal);
        stats.inner_nodes += cp_stats.nodes;
        let makespan = sched.makespan(p);
        let cost = sched.cost(p);
        let energy = objective.energy(makespan, cost);

        // dE and acceptance (flip probability F).
        let de = energy - cur_energy;
        let accept = if de < 0.0 {
            true
        } else if energy.is_infinite() {
            false
        } else {
            let f = (-de / temperature.max(1e-12)).exp();
            rng.f64() < f
        };

        if accept {
            stats.accepted += 1;
            current = proposal;
            cur_sched = sched;
            cur_makespan = makespan;
            cur_cost = cost;
            cur_energy = energy;
            if cur_energy < best_energy - 1e-12 {
                stats.improved += 1;
                best = cur_sched.clone();
                best_makespan = cur_makespan;
                best_cost = cur_cost;
                best_energy = cur_energy;
                stale = 0;
            } else {
                stale += 1;
            }
        } else {
            stale += 1;
        }

        temperature *= cooling;
        stats.trace.push(best_energy);
    }

    // Final polish: one full-budget CP solve on the best configuration —
    // the inner loop runs with starved limits for speed (§Perf), so the
    // winning assignment deserves an exact(-ish) schedule before returning.
    let polish = CpSolver::new(Limits::default());
    let (polished, _) = polish.solve(p, &best.assignment);
    let pm = polished.makespan(p);
    let pc = polished.cost(p);
    let pe = objective.energy(pm, pc);
    if pe <= best_energy {
        best = polished;
        best_makespan = pm;
        best_cost = pc;
        best_energy = pe;
    }

    stats.wall_time = t_start.elapsed();
    AnnealResult {
        schedule: best,
        makespan: best_makespan,
        cost: best_cost,
        energy: best_energy,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::solver::objective::Goal;
    use crate::Predictor;

    fn problem() -> Problem {
        let dags = vec![dag1()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags[0].tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &dags,
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    fn baseline(p: &Problem) -> (Vec<usize>, f64, f64) {
        // default config: 4 x m5.4xlarge balanced for everything
        let c = p
            .space
            .configs
            .iter()
            .position(|c| c.instance == 0 && c.nodes == 4 && c.spark == 1)
            .unwrap();
        let solver = CpSolver::new(Limits::default());
        let (s, _) = solver.solve(p, &vec![c; p.len()]);
        (vec![c; p.len()], s.makespan(p), s.cost(p))
    }

    #[test]
    fn anneal_improves_over_initial() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let mut rng = Rng::new(42);
        let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
        r.schedule.validate(&p).unwrap();
        assert!(
            r.energy < 0.0,
            "co-optimization should improve the balanced objective, got {}",
            r.energy
        );
    }

    #[test]
    fn runtime_goal_reduces_makespan() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Runtime, m0, c0);
        let mut rng = Rng::new(7);
        let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
        assert!(r.makespan <= m0 * 1.001, "{} vs {}", r.makespan, m0);
    }

    #[test]
    fn cost_goal_reduces_cost() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Cost, m0, c0);
        let mut rng = Rng::new(9);
        let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
        assert!(r.cost <= c0 * 1.001, "{} vs {}", r.cost, c0);
    }

    #[test]
    fn budget_constraints_respected() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        // runtime goal but cost must not exceed baseline
        let obj = Objective::new(Goal::Runtime, m0, c0).with_budgets(f64::INFINITY, c0);
        let mut rng = Rng::new(11);
        let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
        if r.energy.is_finite() {
            assert!(r.cost <= c0 * 1.0 + 1e-9, "cost {} over budget {}", r.cost, c0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let run = |seed| {
            let mut rng = Rng::new(seed);
            let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
            (r.makespan, r.cost)
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn trace_is_monotone_nonincreasing() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let mut rng = Rng::new(3);
        let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
        for w in r.stats.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn multi_dag_problems_anneal() {
        let dags = vec![dag1(), dag2()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags
            .iter()
            .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
            .collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let p = Problem::new(
            &dags,
            &[0.0, 0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        );
        let c = p.feasible[0];
        let solver = CpSolver::new(Limits::inner_loop());
        let (s0, _) = solver.solve(&p, &vec![c; p.len()]);
        let obj = Objective::new(Goal::Balanced, s0.makespan(&p), s0.cost(&p));
        let mut rng = Rng::new(1);
        let r = anneal(&p, &obj, &vec![c; p.len()], &AnnealParams::fast(), &mut rng);
        r.schedule.validate(&p).unwrap();
        assert!(r.energy <= 0.0);
    }
}
