//! Simulated-annealing outer loop — Algorithm 1 of the paper — plus the
//! parallel portfolio driver.
//!
//! SA proposes per-task configuration vectors; the CP solver (cp.rs)
//! schedules each proposal to (near-)optimal makespan; cost follows from
//! the configuration alone (Eq. 6). Acceptance is Metropolis on the Eq. 1
//! energy: improvements always accepted, regressions accepted with
//! probability exp(-dE/T) so the search escapes local minima.
//!
//! As in the paper, the energy is a sum of *percentage* improvements, so
//! a constant starting temperature (T0 = 1) works at every problem size;
//! the cooling rate is a function of n, giving O(n) iterations to a fixed
//! convergence criterion.
//!
//! ## Portfolio mode (`portfolio_anneal`)
//!
//! K chains run simultaneously on scoped threads with diversified seeds,
//! temperature scales and `moves_per_proposal`, sharing the best plan
//! found so far through a mutex-guarded [`Exchange`] polled every
//! `exchange_interval` iterations. Odd chains evaluate proposals with the
//! O(affected-suffix) [`IncrementalSgs`] cone evaluator instead of the
//! full CP pass (explorers); even chains keep the exact inner solve
//! (exploiters). Chain 0 always runs the undiversified base parameters,
//! so the portfolio contains the single-chain search as a member.
//!
//! Determinism contract: `parallelism = 1` never constructs an exchange
//! or diversified chains; the outer RNG consumption is unchanged and the
//! evaluation cache memoizes the inner CP solve (its internal RNG is
//! fixed-seeded), so seeded runs are bit-identical to the historical
//! single-chain implementation whenever the inner solver is itself
//! deterministic — i.e. its node budget binds before the 250 ms
//! wall-clock cutoff, which is the regime of every seeded test. When the
//! wall-clock cutoff binds, re-solving a revisited assignment was
//! load-dependent even before the cache existed; the cache replays the
//! first solve, which *removes* that nondeterminism rather than adding
//! any.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::cp::{CpSolver, Limits};
use super::objective::Objective;
use super::rcpsp::Problem;
use super::schedule::Schedule;
use super::sgs::{self, IncrementalSgs};
use crate::util::Rng;

/// Annealing hyper-parameters.
#[derive(Debug, Clone)]
pub struct AnnealParams {
    /// Starting temperature; None = calibrated from a warmup sample
    /// (mean |dE| of the first proposals), which adapts the Metropolis
    /// acceptance to the actual energy scale of the instance. The paper
    /// fixes T0 = 1 on percentage energies; calibration preserves that
    /// scale-freeness while giving meaningful rejection pressure.
    pub t0: Option<f64>,
    /// Multiplier applied to the (fixed or calibrated) starting
    /// temperature — the portfolio's temperature-diversification knob.
    /// 1.0 = historical behaviour.
    pub t0_scale: f64,
    /// Multiplicative cooling per iteration; None = derived from n.
    pub cooling: Option<f64>,
    /// Stop after this many iterations without improvement.
    pub patience: usize,
    /// Hard iteration cap.
    pub max_iters: usize,
    /// Wall-clock budget.
    pub max_time: Duration,
    /// Inner CP budget per iteration.
    pub inner_limits: Limits,
    /// Tasks perturbed per proposal.
    pub moves_per_proposal: usize,
    /// Evaluate proposals with the incremental suffix-SGS instead of the
    /// full CP solve (portfolio explorer chains; the final polish still
    /// runs a full-budget CP solve).
    pub incremental: bool,
    /// Poll/publish the portfolio exchange every N iterations
    /// (0 = never; irrelevant outside portfolio mode).
    pub exchange_interval: usize,
    /// Target start-of-search acceptance ratio for statistical-cooling
    /// calibration (Aarts & Van Laarhoven): with `t0 = None` and this
    /// set, T0 is estimated from the warmup sample's mean *uphill* delta
    /// as `mean(dE+) / ln(1/chi0)`, so a chain starts accepting roughly
    /// `chi0` of its regressions at every problem size — and the warmup
    /// evaluations are charged against the chain's iteration budget.
    /// `None` preserves the historical uncharged mean-|dE| heuristic.
    pub target_acceptance: Option<f64>,
    /// Hold the temperature for an equilibrium-length inner loop
    /// (iterations per temperature step derived from the neighbourhood's
    /// task dimension, à la Van Laarhoven) instead of cooling once per
    /// move. The envelope is preserved: after L iterations at constant T
    /// the chain cools by `cooling^L`. `false` = historical per-move
    /// cooling.
    pub equilibrium: bool,
    /// Restart-on-stall: after this many iterations without improving
    /// the chain's local best, reheat to `reheat * T0` and restart from a
    /// diversified seed (incumbent perturbation on even restarts, DAGPS
    /// troublesome-task-first reseed on odd restarts). `0` = off.
    pub stall_iters: usize,
    /// Fraction of the (calibrated or fixed) starting temperature the
    /// chain reheats to on a stall restart.
    pub reheat: f64,
    /// Run the final polish (and the scheduler-only paths in the
    /// co-optimizer) through the destructive UB-ladder CP mode
    /// ([`CpSolver::solve_ladder`]) instead of a single default solve.
    pub cp_ladder: bool,
    /// Seed the search from the DAGPS troublesome-task-first reseed of
    /// the initial assignment (the most troublesome half of the tasks
    /// start on their fastest per-task-feasible configuration). In
    /// portfolio mode chain 1 starts from the seeded assignment while
    /// chain 0 keeps the unseeded base walk; at parallelism 1 the single
    /// chain starts from the seeded assignment directly. `false` =
    /// historical behaviour, bit-identical.
    pub troublesome_seed: bool,
}

impl Default for AnnealParams {
    fn default() -> Self {
        AnnealParams {
            t0: None,
            t0_scale: 1.0,
            cooling: None,
            patience: 400,
            max_iters: 2_000,
            max_time: Duration::from_secs(45),
            inner_limits: Limits::inner_loop(),
            moves_per_proposal: 1,
            incremental: false,
            exchange_interval: 16,
            target_acceptance: None,
            equilibrium: false,
            stall_iters: 0,
            reheat: 0.5,
            cp_ladder: false,
            troublesome_seed: false,
        }
    }
}

impl AnnealParams {
    /// Cooling rate as a function of problem size (paper §4.3: "the
    /// cooling rate we define as a function of n"): larger problems cool
    /// slower so the expected accepted-move count scales linearly.
    pub fn cooling_for(&self, n: usize) -> f64 {
        self.cooling
            .unwrap_or_else(|| 1.0 - 1.0 / (20.0 * (n.max(1) as f64)))
    }

    /// Fast preset for unit tests and the overhead micro-measurements.
    pub fn fast() -> Self {
        AnnealParams {
            patience: 150,
            max_iters: 600,
            max_time: Duration::from_secs(10),
            ..Default::default()
        }
    }

    /// Turn on the adaptive engine: acceptance-calibrated T0 (target
    /// start-acceptance 0.8), equilibrium-length inner loops, and
    /// restart-on-stall at a quarter of the iteration budget.
    pub fn adaptive(mut self) -> Self {
        self.t0 = None;
        self.target_acceptance = Some(0.8);
        self.equilibrium = true;
        self.stall_iters = (self.max_iters / 4).max(16);
        self
    }

    /// Equilibrium inner-loop length for an n-task neighbourhood: one
    /// sweep of the first-order neighbourhood's task dimension (Van
    /// Laarhoven's |N| proxy), clipped so a chain still visits several
    /// temperature plateaus within its budget. 1 when the equilibrium
    /// knob is off — i.e. the historical cool-every-move schedule.
    pub fn equilibrium_len(&self, n: usize) -> usize {
        if self.equilibrium {
            n.max(1).min((self.max_iters / 8).max(1))
        } else {
            1
        }
    }
}

/// Propose a neighbour of a config assignment: half the time a uniform
/// re-draw of one task's config, half the time a single-dimension tweak
/// (node-ladder step / instance step / Spark preset / — on spot-bearing
/// market spaces — purchase-option toggle) — the classic SA
/// neighbourhood that makes small cost/runtime trades discoverable.
///
/// The tweak dimensions and the instance-step bound derive from the
/// problem's *space*, not the global catalog: on the historical m5-only
/// space the proposal distribution (and thus every seeded walk) is
/// bit-identical to the pre-market implementation; the purchase-toggle
/// dimension only exists when the space actually sells spot capacity.
pub fn propose(
    p: &Problem,
    current: &[usize],
    moves: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    let mut proposal = current.to_vec();
    let instance_count = p.instance_count();
    let tweak_dims = if p.space_has_spot() { 4 } else { 3 };
    for _ in 0..moves {
        let t = rng.below(p.len());
        let cur = p.space.configs[proposal[t]];
        let candidate = if rng.chance(0.5) {
            p.feasible[rng.below(p.feasible.len())]
        } else {
            // Tweak one dimension; fall back to uniform if the tweaked
            // config is not in the feasible set.
            let ladder = crate::cluster::config::NODE_LADDER;
            let presets = crate::cluster::config::SPARK_PRESETS.len();
            let mut cfg = cur;
            match rng.below(tweak_dims) {
                0 => {
                    // node ladder step
                    let pos = ladder.iter().position(|&n| n == cfg.nodes).unwrap_or(0);
                    let next = if rng.chance(0.5) {
                        pos.saturating_sub(1)
                    } else {
                        (pos + 1).min(ladder.len() - 1)
                    };
                    cfg.nodes = ladder[next];
                }
                1 => {
                    cfg.instance = if rng.chance(0.5) {
                        cfg.instance.saturating_sub(1)
                    } else {
                        (cfg.instance + 1).min(instance_count - 1)
                    };
                }
                2 => {
                    cfg.spark = rng.below(presets);
                }
                _ => {
                    // Purchase-option toggle: same family and shape, the
                    // other market (no-op for sizes without a spot twin).
                    if let Some(alt) = crate::cluster::catalog::purchase_toggle(cfg.instance)
                    {
                        cfg.instance = alt;
                    }
                }
            }
            // Index of the tweaked config: O(1) closed form for the
            // dense instance-major layout of `ConfigSpace::enumerate`
            // (standard and market spaces), verified by an equality
            // check so sparse custom spaces fall back to the scan.
            let dense = ladder
                .iter()
                .position(|&n| n == cfg.nodes)
                .map(|lp| (cfg.instance * ladder.len() + lp) * presets + cfg.spark)
                .filter(|&i| p.space.configs.get(i) == Some(&cfg));
            let found =
                dense.or_else(|| p.space.configs.iter().position(|c| *c == cfg));
            match found {
                // `feasible` is ascending by construction (a filtered
                // index range), so membership is a binary search.
                Some(idx) if p.feasible.binary_search(&idx).is_ok() => idx,
                _ => p.feasible[rng.below(p.feasible.len())],
            }
        };
        proposal[t] = candidate;
    }
    proposal
}

/// Iteration telemetry (overhead analysis, Fig. 10).
#[derive(Debug, Clone, Default)]
pub struct AnnealStats {
    /// SA iterations executed (warmup excluded).
    pub iterations: usize,
    /// Accepted proposals (improvements + Metropolis).
    pub accepted: usize,
    /// Proposals that improved the best-so-far energy.
    pub improved: usize,
    /// Inner CP solver nodes across all evaluations.
    pub inner_nodes: u64,
    /// Wall-clock time of the whole search.
    pub wall_time: Duration,
    /// Energy trace (best-so-far per iteration), for convergence plots.
    pub trace: Vec<f64>,
    /// Schedule evaluations answered by the memo cache (no CP solve ran).
    pub cache_hits: usize,
    /// Plans adopted from the portfolio exchange.
    pub adopted: usize,
    /// Objective evaluations actually computed (memo hits excluded) —
    /// the budget currency for equal-cost comparisons between search
    /// engines. Excludes the final polish solve.
    pub evaluations: usize,
    /// Stall restarts taken (reheat + diversified reseed).
    pub restarts: usize,
    /// Acceptance-calibrated starting temperature, when the warmup
    /// calibration ran with a target acceptance ratio.
    pub calibrated_t0: Option<f64>,
}

/// Result of the co-optimization.
#[derive(Debug, Clone)]
pub struct AnnealResult {
    /// Best schedule found (polished with a full-budget CP solve).
    pub schedule: Schedule,
    /// Makespan of the best schedule.
    pub makespan: f64,
    /// Cost of the best schedule.
    pub cost: f64,
    /// Eq. 1 energy of the best schedule.
    pub energy: f64,
    /// Search telemetry.
    pub stats: AnnealStats,
}

// ---------------------------------------------------------------------------
// Schedule evaluation: memoized full CP solve or incremental suffix SGS.

struct CachedEval {
    schedule: Schedule,
    makespan: f64,
    cost: f64,
    nodes: u64,
}

/// Memo of assignment -> evaluated schedule. The CP solve is
/// deterministic per assignment (fixed internal seed) as long as its
/// node budget binds before the wall-clock cutoff, so replaying a cached
/// result is bit-identical to re-solving in that regime — and strictly
/// *more* deterministic than re-solving when the cutoff binds (a re-solve
/// was load-dependent even pre-cache). Either way the cache is invisible
/// to the seeded walk's RNG stream.
const EVAL_CACHE_CAP: usize = 8_192;

enum Evaluator {
    Full {
        solver: CpSolver,
        cache: HashMap<Vec<usize>, CachedEval>,
        /// Schedule of the most recent `eval`, handed out by
        /// `take_schedule` — so rejected proposals never pay for a
        /// schedule materialization.
        last: Option<Schedule>,
    },
    Incremental(IncrementalSgs),
}

impl Evaluator {
    fn new(p: &Problem, initial: &[usize], params: &AnnealParams) -> Evaluator {
        if params.incremental {
            Evaluator::Incremental(IncrementalSgs::new(p, initial))
        } else {
            Evaluator::Full {
                solver: CpSolver::new(params.inner_limits.clone()),
                cache: HashMap::new(),
                last: None,
            }
        }
    }

    /// Evaluate an assignment: (makespan, cost). The schedule itself is
    /// only materialized on demand via [`Evaluator::take_schedule`].
    fn eval(&mut self, p: &Problem, assignment: &[usize], stats: &mut AnnealStats) -> (f64, f64) {
        match self {
            Evaluator::Full { solver, cache, last } => {
                if let Some(hit) = cache.get(assignment) {
                    stats.inner_nodes += hit.nodes;
                    stats.cache_hits += 1;
                    // Hits store nothing: take_schedule re-reads the cache,
                    // so the (mostly rejected) hot path stays clone-free.
                    // Clearing `last` keeps a stale miss-schedule from
                    // being handed out for this assignment.
                    *last = None;
                    return (hit.makespan, hit.cost);
                }
                let (sched, cp_stats) = solver
                    .solve(p, assignment)
                    .expect("SA proposals draw from Problem::feasible, whose demands fit");
                stats.inner_nodes += cp_stats.nodes;
                stats.evaluations += 1;
                let makespan = sched.makespan(p);
                let cost = sched.cost(p);
                if cache.len() < EVAL_CACHE_CAP {
                    cache.insert(
                        assignment.to_vec(),
                        CachedEval {
                            schedule: sched.clone(),
                            makespan,
                            cost,
                            nodes: cp_stats.nodes,
                        },
                    );
                }
                *last = Some(sched);
                (makespan, cost)
            }
            Evaluator::Incremental(inc) => {
                let makespan = inc.evaluate(p, assignment);
                stats.evaluations += 1;
                (makespan, p.assignment_cost(assignment))
            }
        }
    }

    /// Materialize the schedule of the most recent `eval` call.
    /// `assignment` must be the one passed to that call.
    fn take_schedule(&mut self, assignment: &[usize]) -> Schedule {
        match self {
            Evaluator::Full { cache, last, .. } => match last.take() {
                Some(sched) => sched,
                // The most recent eval was a cache hit.
                None => cache
                    .get(assignment)
                    .map(|hit| hit.schedule.clone())
                    .expect("take_schedule immediately follows eval"),
            },
            Evaluator::Incremental(inc) => inc.schedule(assignment),
        }
    }
}

// ---------------------------------------------------------------------------
// Portfolio exchange.

struct SharedPlan {
    energy: f64,
    schedule: Schedule,
    makespan: f64,
    cost: f64,
}

/// Best-so-far plan shared between portfolio chains: a mutex-guarded
/// cell, published on improvement and polled every `exchange_interval`
/// iterations — contention is negligible because both operations touch
/// the lock O(iterations / interval) times.
#[derive(Default)]
pub struct Exchange {
    best: Mutex<Option<SharedPlan>>,
}

impl Exchange {
    /// Empty exchange (no plan published yet).
    pub fn new() -> Exchange {
        Exchange::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Option<SharedPlan>> {
        // A panicked chain must not poison the whole portfolio.
        self.best.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Publish a plan if it beats the current global best.
    fn publish(&self, energy: f64, schedule: &Schedule, makespan: f64, cost: f64) {
        if !energy.is_finite() {
            return;
        }
        let mut guard = self.lock();
        let better = guard.as_ref().map_or(true, |s| energy < s.energy - 1e-12);
        if better {
            *guard = Some(SharedPlan {
                energy,
                schedule: schedule.clone(),
                makespan,
                cost,
            });
        }
    }

    /// Fetch the global best if it strictly beats `energy`.
    fn steal(&self, energy: f64) -> Option<(f64, Schedule, f64, f64)> {
        let guard = self.lock();
        match guard.as_ref() {
            Some(s) if s.energy < energy - 1e-12 => {
                Some((s.energy, s.schedule.clone(), s.makespan, s.cost))
            }
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// The annealing chain.

/// DAGPS/Graphene-style restart seed ("schedule the hard stuff first",
/// Grandl et al.): score every task by how hard it is to pack under the
/// incumbent (resource share x duration), then hand the most troublesome
/// half their fastest *per-task-feasible* configuration while the rest
/// keep the incumbent's choice — a deterministic reseed that pulls the
/// restarted walk toward a different basin than the one it stalled in.
///
/// "Per-task-feasible" matters: a config in `p.feasible` fits the
/// cluster, but its duration model can still be degenerate for a given
/// task (NaN/inf/non-positive rows from a predictor that never saw that
/// shape). Such configs are skipped rather than adopted on raw duration.
fn dagps_seed(p: &Problem, incumbent: &[usize]) -> Vec<usize> {
    let score = sgs::priorities(p, incumbent, sgs::Rule::HardestToPack);
    let mut order: Vec<usize> = (0..p.len()).collect();
    order.sort_by(|&a, &b| score[b].total_cmp(&score[a]).then(a.cmp(&b)));
    let mut seed = incumbent.to_vec();
    for &t in order.iter().take(p.len().div_ceil(2)) {
        // Fastest per-task-feasible config; strict `<` keeps the
        // lowest config index among duration ties (feasible is ascending).
        let mut best_c = seed[t];
        let mut best_d = f64::INFINITY;
        for &c in &p.feasible {
            let d = p.duration(t, c);
            if !d.is_finite() || d <= 0.0 {
                continue;
            }
            if d < best_d {
                best_d = d;
                best_c = c;
            }
        }
        seed[t] = best_c;
    }
    seed
}

/// Algorithm 1: co-optimize configurations (SA) and schedule (CP).
pub fn anneal(
    p: &Problem,
    objective: &Objective,
    initial: &[usize],
    params: &AnnealParams,
    rng: &mut Rng,
) -> AnnealResult {
    anneal_chain(p, objective, initial, params, rng, None)
}

/// One annealing chain, optionally wired to a portfolio [`Exchange`].
/// With `exchange = None` this is exactly the historical single-chain
/// algorithm (same RNG draw sequence, same outputs for a given seed).
pub fn anneal_chain(
    p: &Problem,
    objective: &Objective,
    initial: &[usize],
    params: &AnnealParams,
    rng: &mut Rng,
    exchange: Option<&Exchange>,
) -> AnnealResult {
    let t_start = Instant::now();
    let cooling = params.cooling_for(p.len());
    let mut stats = AnnealStats::default();
    let mut evaluator = Evaluator::new(p, initial, params);

    // Evaluate the initial configuration.
    let mut current = initial.to_vec();
    let (mut cur_makespan, mut cur_cost) = evaluator.eval(p, &current, &mut stats);
    let mut cur_energy = objective.energy(cur_makespan, cur_cost);

    let mut best = evaluator.take_schedule(&current);
    let mut best_makespan = cur_makespan;
    let mut best_cost = cur_cost;
    let mut best_energy = cur_energy;

    // Warmup calibration: sample a few proposals to learn the energy
    // scale. With a `target_acceptance` the statistical-cooling estimate
    // (Aarts & Van Laarhoven) sets T0 = mean(dE+) / ln(1/chi0) so the
    // start-of-search acceptance ratio is ~chi0 at every problem size,
    // and the warmup evaluations are charged against the chain budget;
    // without one, the historical uncharged mean-|dE| heuristic stands.
    let mut temperature = match (params.t0, params.target_acceptance) {
        (Some(t0), _) => t0 * params.t0_scale,
        (None, Some(chi0)) => {
            let chi0 = chi0.clamp(0.05, 0.99);
            let warmup = 12.min(params.max_iters / 4).max(3);
            let mut uphill = Vec::new();
            for _ in 0..warmup {
                if stats.iterations >= params.max_iters {
                    break;
                }
                // Calibration samples are real objective evaluations:
                // they spend the same budget the search loop does.
                stats.iterations += 1;
                let proposal = propose(p, &current, params.moves_per_proposal, rng);
                let (makespan, cost) = evaluator.eval(p, &proposal, &mut stats);
                let e = objective.energy(makespan, cost);
                if e.is_finite() {
                    let de = e - cur_energy;
                    if de > 0.0 {
                        uphill.push(de);
                    }
                    // Greedy seed: keep strict improvements found during
                    // warmup (they are free information).
                    if e < cur_energy {
                        current = proposal;
                        cur_makespan = makespan;
                        cur_cost = cost;
                        cur_energy = e;
                        if e < best_energy {
                            best = evaluator.take_schedule(&current);
                            best_makespan = cur_makespan;
                            best_cost = cur_cost;
                            best_energy = e;
                        }
                    }
                }
                stats.trace.push(best_energy);
            }
            let mean = if uphill.is_empty() {
                // All-downhill (or infeasible) warmup: no uphill scale to
                // learn; fall back to the historical default scale.
                0.01
            } else {
                uphill.iter().sum::<f64>() / uphill.len() as f64
            };
            let t0 = (mean / (1.0 / chi0).ln()).max(1e-4) * params.t0_scale;
            stats.calibrated_t0 = Some(t0);
            t0
        }
        (None, None) => {
            let warmup = 12.min(params.max_iters / 4).max(3);
            let mut des = Vec::new();
            for _ in 0..warmup {
                let proposal = propose(p, &current, params.moves_per_proposal, rng);
                let (makespan, cost) = evaluator.eval(p, &proposal, &mut stats);
                let e = objective.energy(makespan, cost);
                if e.is_finite() {
                    des.push((e - cur_energy).abs());
                    // Greedy seed: keep strict improvements found during
                    // warmup (they are free information).
                    if e < cur_energy {
                        current = proposal;
                        cur_makespan = makespan;
                        cur_cost = cost;
                        cur_energy = e;
                        if e < best_energy {
                            best = evaluator.take_schedule(&current);
                            best_makespan = cur_makespan;
                            best_cost = cur_cost;
                            best_energy = e;
                        }
                    }
                }
            }
            let mean = if des.is_empty() {
                0.01
            } else {
                des.iter().sum::<f64>() / des.len() as f64
            };
            (0.8 * mean).max(1e-4) * params.t0_scale
        }
    };
    // Reheat target for stall restarts: the (calibrated or fixed) T0.
    let base_t0 = temperature;
    let equilibrium_len = params.equilibrium_len(p.len());
    let mut since_cool = 0usize;
    let mut stale = 0usize;

    if let Some(ex) = exchange {
        ex.publish(best_energy, &best, best_makespan, best_cost);
    }

    while stats.iterations < params.max_iters
        && stale < params.patience
        && t_start.elapsed() < params.max_time
    {
        stats.iterations += 1;

        // c <- get_new_configuration(c): perturb a few tasks.
        let proposal = propose(p, &current, params.moves_per_proposal, rng);

        // M_new, C_new <- SAT_Solver(c, d, P, R)
        let (makespan, cost) = evaluator.eval(p, &proposal, &mut stats);
        let energy = objective.energy(makespan, cost);

        // dE and acceptance (flip probability F).
        let de = energy - cur_energy;
        let accept = if de < 0.0 {
            true
        } else if energy.is_infinite() {
            false
        } else {
            let f = (-de / temperature.max(1e-12)).exp();
            rng.f64() < f
        };

        if accept {
            stats.accepted += 1;
            current = proposal;
            cur_makespan = makespan;
            cur_cost = cost;
            cur_energy = energy;
            if cur_energy < best_energy - 1e-12 {
                stats.improved += 1;
                best = evaluator.take_schedule(&current);
                best_makespan = cur_makespan;
                best_cost = cur_cost;
                best_energy = cur_energy;
                stale = 0;
                if let Some(ex) = exchange {
                    ex.publish(best_energy, &best, best_makespan, best_cost);
                }
            } else {
                stale += 1;
            }
        } else {
            stale += 1;
        }

        // Portfolio exchange: adopt the global best when it strictly
        // beats this chain's OWN best. Gating on best (not current)
        // means adoption fires at most once per global improvement — a
        // chain whose evaluator cannot reproduce the published energy
        // (explorer suffix-SGS vs. a full-CP plan) is not teleported
        // back to the same plan every poll, which would discard its
        // walk progress between polls.
        if let Some(ex) = exchange {
            if params.exchange_interval > 0
                && stats.iterations % params.exchange_interval == 0
            {
                if let Some((e, sched, makespan, cost)) = ex.steal(best_energy) {
                    stats.adopted += 1;
                    // The stolen plan's energy is genuine (published from
                    // a real schedule) — it becomes this chain's best.
                    best_makespan = makespan;
                    best_cost = cost;
                    best_energy = e;
                    stale = 0;
                    current = sched.assignment.clone();
                    best = sched;
                    // Continue the walk from the adopted assignment,
                    // re-evaluated with THIS chain's evaluator so later dE
                    // comparisons stay on the chain's own energy scale: an
                    // explorer (suffix-SGS) chain cannot reproduce a
                    // full-CP makespan and would otherwise reject every
                    // subsequent proposal until patience ran out.
                    let (own_makespan, own_cost) = evaluator.eval(p, &current, &mut stats);
                    cur_makespan = own_makespan;
                    cur_cost = own_cost;
                    cur_energy = objective.energy(own_makespan, own_cost);
                }
            }
        }

        // Cooling: one multiplicative step per move (historical), or —
        // with equilibrium inner loops — hold T for `equilibrium_len`
        // moves and then apply the same envelope in one step
        // (`cooling^L`), so the temperature trajectory is preserved while
        // the chain actually equilibrates at each plateau.
        if equilibrium_len > 1 {
            since_cool += 1;
            if since_cool >= equilibrium_len {
                temperature *= cooling.powi(equilibrium_len as i32);
                since_cool = 0;
            }
        } else {
            temperature *= cooling;
        }
        stats.trace.push(best_energy);

        // Restart-on-stall (Cruz-Chávez & Frausto-Solís): `stall_iters`
        // moves without improving the local best means the chain is
        // re-rejecting into a cold basin — reheat toward T0 and restart
        // from a diversified seed instead of burning the rest of the
        // budget. Even restarts kick the incumbent with a multi-move
        // perturbation; odd restarts take the deterministic DAGPS
        // troublesome-task-first reseed.
        if params.stall_iters > 0
            && stale >= params.stall_iters
            && stats.iterations < params.max_iters
        {
            let r = stats.restarts;
            stats.restarts += 1;
            // The reseed evaluation is a real objective evaluation:
            // charge it like any other iteration.
            stats.iterations += 1;
            let seed_assignment = if r % 2 == 0 {
                propose(p, &best.assignment, (2 * params.moves_per_proposal).max(3), rng)
            } else {
                dagps_seed(p, &best.assignment)
            };
            let (makespan, cost) = evaluator.eval(p, &seed_assignment, &mut stats);
            current = seed_assignment;
            cur_makespan = makespan;
            cur_cost = cost;
            cur_energy = objective.energy(makespan, cost);
            if cur_energy < best_energy - 1e-12 {
                stats.improved += 1;
                best = evaluator.take_schedule(&current);
                best_makespan = cur_makespan;
                best_cost = cur_cost;
                best_energy = cur_energy;
                if let Some(ex) = exchange {
                    ex.publish(best_energy, &best, best_makespan, best_cost);
                }
            }
            temperature = params.reheat.max(0.0) * base_t0;
            stale = 0;
            since_cool = 0;
            stats.trace.push(best_energy);
        }
    }

    // Final polish: one full-budget CP solve on the best configuration —
    // the inner loop runs with starved limits for speed (§Perf), so the
    // winning assignment deserves an exact(-ish) schedule before
    // returning. With the ladder knob on, the polish runs the
    // destructive UB-ladder instead of a single descent.
    let (polished, _) = if params.cp_ladder {
        CpSolver::new(Limits::ladder())
            .solve_ladder(p, &best.assignment)
            .expect("the accepted incumbent was already scheduled feasibly")
    } else {
        CpSolver::new(Limits::default())
            .solve(p, &best.assignment)
            .expect("the accepted incumbent was already scheduled feasibly")
    };
    let pm = polished.makespan(p);
    let pc = polished.cost(p);
    let pe = objective.energy(pm, pc);
    if pe <= best_energy {
        best = polished;
        best_makespan = pm;
        best_cost = pc;
        best_energy = pe;
    }

    if let Some(ex) = exchange {
        ex.publish(best_energy, &best, best_makespan, best_cost);
    }

    stats.wall_time = t_start.elapsed();
    AnnealResult {
        schedule: best,
        makespan: best_makespan,
        cost: best_cost,
        energy: best_energy,
        stats,
    }
}

// ---------------------------------------------------------------------------
// Portfolio driver.

/// Deterministic per-chain seed derivation (SplitMix64 increment).
pub fn chain_seed(seed: u64, chain: usize) -> u64 {
    seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(chain as u64))
}

/// Diversified parameters for chain `i` of a portfolio. Chain 0 is the
/// undiversified base chain; higher chains vary temperature scale and
/// moves-per-proposal, and odd chains switch to the incremental
/// suffix-SGS evaluator (fast explorers).
pub fn chain_params(base: &AnnealParams, chain: usize) -> AnnealParams {
    let mut p = base.clone();
    if chain == 0 {
        return p;
    }
    p.moves_per_proposal = 1 + (chain % 3);
    p.t0_scale = base.t0_scale * (1.0 + 0.5 * (chain % 4) as f64);
    p.incremental = chain % 2 == 1;
    p
}

/// Run `parallelism` annealing chains concurrently (scoped threads) with
/// diversified seeds/parameters and a shared best-plan exchange; return
/// the best chain result with portfolio-aggregated statistics.
///
/// `parallelism <= 1` falls back to the plain deterministic single chain
/// seeded with `seed`.
pub fn portfolio_anneal(
    p: &Problem,
    objective: &Objective,
    initial: &[usize],
    params: &AnnealParams,
    parallelism: usize,
    seed: u64,
) -> AnnealResult {
    let k = parallelism.max(1);
    // Troublesome-first seeding (off by default): derive the DAGPS reseed
    // of the initial assignment once. A single chain starts from it
    // directly; a portfolio hands it to chain 1 only, so chain 0 remains
    // the historical unseeded walk and the winner can never be worse than
    // the unseeded single chain at the same parameters.
    let seeded: Option<Vec<usize>> = params.troublesome_seed.then(|| dagps_seed(p, initial));
    let seeded_ref: Option<&[usize]> = seeded.as_deref();
    if k == 1 {
        let mut rng = Rng::new(seed);
        let start = seeded_ref.unwrap_or(initial);
        return anneal(p, objective, start, params, &mut rng);
    }

    let t_start = Instant::now();
    let exchange = Exchange::new();
    let configs: Vec<AnnealParams> = (0..k).map(|i| chain_params(params, i)).collect();

    let mut results: Vec<AnnealResult> = Vec::with_capacity(k);
    std::thread::scope(|scope| {
        let handles: Vec<_> = configs
            .iter()
            .enumerate()
            .map(|(i, cp)| {
                let ex = &exchange;
                scope.spawn(move || {
                    let start = if i == 1 {
                        seeded_ref.unwrap_or(initial)
                    } else {
                        initial
                    };
                    let mut rng = Rng::new(chain_seed(seed, i));
                    anneal_chain(p, objective, start, cp, &mut rng, Some(ex))
                })
            })
            .collect();
        for handle in handles {
            match handle.join() {
                Ok(r) => results.push(r),
                // A panicking chain is a solver bug, not a condition to
                // mask by returning the surviving chains' best: re-raise
                // with the original payload (scope joins the remaining
                // chains before unwinding).
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });

    // Aggregate telemetry across chains.
    let mut agg = AnnealStats::default();
    for r in &results {
        agg.iterations += r.stats.iterations;
        agg.accepted += r.stats.accepted;
        agg.improved += r.stats.improved;
        agg.inner_nodes += r.stats.inner_nodes;
        agg.cache_hits += r.stats.cache_hits;
        agg.adopted += r.stats.adopted;
        agg.evaluations += r.stats.evaluations;
        agg.restarts += r.stats.restarts;
    }
    agg.wall_time = t_start.elapsed();

    // Deterministic winner selection: strictly better energy wins, ties
    // go to the lowest chain index (results are in chain order).
    let mut best: Option<AnnealResult> = None;
    for r in results {
        let take = best.as_ref().map_or(true, |b| r.energy < b.energy);
        if take {
            best = Some(r);
        }
    }
    let mut best = best.expect("portfolio ran at least one chain");
    agg.trace = std::mem::take(&mut best.stats.trace);
    agg.calibrated_t0 = best.stats.calibrated_t0;
    best.stats = agg;
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Capacity, ConfigSpace, CostModel};
    use crate::dag::generator::arbitrary_dag;
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::OraclePredictor;
    use crate::solver::objective::Goal;
    use crate::util::propcheck;
    use crate::Predictor;

    fn problem() -> Problem {
        let dags = vec![dag1()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags[0].tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        Problem::new(
            &dags,
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        )
    }

    fn baseline(p: &Problem) -> (Vec<usize>, f64, f64) {
        // default config: 4 x m5.4xlarge balanced for everything
        let c = p
            .space
            .configs
            .iter()
            .position(|c| c.instance == 0 && c.nodes == 4 && c.spark == 1)
            .unwrap();
        let solver = CpSolver::new(Limits::default());
        let (s, _) = solver.solve(p, &vec![c; p.len()]).unwrap();
        (vec![c; p.len()], s.makespan(p), s.cost(p))
    }

    #[test]
    fn anneal_improves_over_initial() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let mut rng = Rng::new(42);
        let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
        r.schedule.validate(&p).unwrap();
        assert!(
            r.energy < 0.0,
            "co-optimization should improve the balanced objective, got {}",
            r.energy
        );
    }

    #[test]
    fn runtime_goal_reduces_makespan() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Runtime, m0, c0);
        let mut rng = Rng::new(7);
        let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
        assert!(r.makespan <= m0 * 1.001, "{} vs {}", r.makespan, m0);
    }

    #[test]
    fn cost_goal_reduces_cost() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Cost, m0, c0);
        let mut rng = Rng::new(9);
        let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
        assert!(r.cost <= c0 * 1.001, "{} vs {}", r.cost, c0);
    }

    #[test]
    fn budget_constraints_respected() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        // runtime goal but cost must not exceed baseline
        let obj = Objective::new(Goal::Runtime, m0, c0).with_budgets(f64::INFINITY, c0);
        let mut rng = Rng::new(11);
        let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
        if r.energy.is_finite() {
            assert!(r.cost <= c0 * 1.0 + 1e-9, "cost {} over budget {}", r.cost, c0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let run = |seed| {
            let mut rng = Rng::new(seed);
            let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
            (r.makespan, r.cost)
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn trace_is_monotone_nonincreasing() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let mut rng = Rng::new(3);
        let r = anneal(&p, &obj, &init, &AnnealParams::fast(), &mut rng);
        for w in r.stats.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn multi_dag_problems_anneal() {
        let dags = vec![dag1(), dag2()];
        let space = ConfigSpace::standard();
        let profiles: Vec<_> = dags
            .iter()
            .flat_map(|d| d.tasks.iter().map(|t| t.profile.clone()))
            .collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let p = Problem::new(
            &dags,
            &[0.0, 0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::OnDemand,
        );
        let c = p.feasible[0];
        let solver = CpSolver::new(Limits::inner_loop());
        let (s0, _) = solver.solve(&p, &vec![c; p.len()]).unwrap();
        let obj = Objective::new(Goal::Balanced, s0.makespan(&p), s0.cost(&p));
        let mut rng = Rng::new(1);
        let r = anneal(&p, &obj, &vec![c; p.len()], &AnnealParams::fast(), &mut rng);
        r.schedule.validate(&p).unwrap();
        assert!(r.energy <= 0.0);
    }

    #[test]
    fn incremental_chain_produces_valid_improving_plans() {
        use crate::solver::sgs;
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let params = AnnealParams {
            incremental: true,
            ..AnnealParams::fast()
        };
        let mut rng = Rng::new(13);
        let r = anneal(&p, &obj, &init, &params, &mut rng);
        r.schedule.validate(&p).unwrap();
        // Guaranteed bound: the chain's best is monotone from the
        // incremental evaluation of the initial assignment (a plain
        // critical-path serial SGS), and the polish can only improve it.
        let prio = sgs::priorities(&p, &init, sgs::Rule::CriticalPath);
        let init_sgs = sgs::serial_sgs(&p, &init, &prio).unwrap();
        let e_init = obj.energy(init_sgs.makespan(&p), init_sgs.cost(&p));
        assert!(
            r.energy <= e_init + 1e-9,
            "incremental chain regressed: {} vs initial {}",
            r.energy,
            e_init
        );
    }

    #[test]
    fn portfolio_is_deterministic_at_parallelism_one() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let params = AnnealParams::fast();
        let a = portfolio_anneal(&p, &obj, &init, &params, 1, 5);
        let mut rng = Rng::new(5);
        let b = anneal(&p, &obj, &init, &params, &mut rng);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.schedule.assignment, b.schedule.assignment);
        assert_eq!(a.schedule.start, b.schedule.start);
    }

    #[test]
    fn portfolio_produces_valid_plans() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let params = AnnealParams {
            max_iters: 150,
            patience: 150,
            ..AnnealParams::fast()
        };
        let r = portfolio_anneal(&p, &obj, &init, &params, 4, 17);
        r.schedule.validate(&p).unwrap();
        assert!(r.energy <= 1e-9, "portfolio regressed: {}", r.energy);
        assert!(r.stats.iterations > 0);
    }

    #[test]
    fn property_portfolio_never_worse_than_best_single_chain() {
        // With the exchange disabled, the portfolio is exactly the
        // independent union of its chains, so its result must equal the
        // best standalone chain on the same budget.
        propcheck::check(5, |rng| {
            let dag = arbitrary_dag(rng, 7);
            let space = ConfigSpace::standard();
            let profiles: Vec<_> = dag.tasks.iter().map(|t| t.profile.clone()).collect();
            let grid = OraclePredictor { profiles }.predict(&space);
            let dags = vec![dag];
            let p = Problem::new(
                &dags,
                &[0.0],
                Capacity::micro(),
                space,
                grid,
                CostModel::OnDemand,
            );
            let init = vec![p.feasible[0]; p.len()];
            let solver = CpSolver::new(Limits::inner_loop());
            let (s0, _) = solver.solve(&p, &init).unwrap();
            let obj = Objective::new(Goal::Balanced, s0.makespan(&p), s0.cost(&p));

            let seed = rng.next_u64();
            let k = 3usize;
            let params = AnnealParams {
                max_iters: 60,
                patience: 60,
                exchange_interval: 0, // isolate chains
                ..AnnealParams::fast()
            };
            let portfolio = portfolio_anneal(&p, &obj, &init, &params, k, seed);
            portfolio.schedule.validate(&p).map_err(|e| e.to_string())?;

            let mut best_single = f64::INFINITY;
            for i in 0..k {
                let cp = chain_params(&params, i);
                let mut crng = Rng::new(chain_seed(seed, i));
                let r = anneal(&p, &obj, &init, &cp, &mut crng);
                best_single = best_single.min(r.energy);
            }
            if portfolio.energy > best_single + 1e-9 {
                return Err(format!(
                    "portfolio energy {} worse than best single chain {}",
                    portfolio.energy, best_single
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn adaptive_restarts_are_seed_deterministic() {
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let params = AnnealParams {
            stall_iters: 40, // low patience-to-stall so restarts actually fire
            ..AnnealParams::fast().adaptive()
        };
        let run = |seed| {
            let mut rng = Rng::new(seed);
            anneal(&p, &obj, &init, &params, &mut rng)
        };
        let a = run(21);
        let b = run(21);
        a.schedule.validate(&p).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.cost.to_bits(), b.cost.to_bits());
        assert_eq!(a.schedule.assignment, b.schedule.assignment);
        assert_eq!(a.stats.restarts, b.stats.restarts, "restart count must replay");
        assert_eq!(a.stats.evaluations, b.stats.evaluations);
        assert_eq!(a.stats.calibrated_t0, b.stats.calibrated_t0);
        assert!(a.stats.calibrated_t0.is_some(), "adaptive preset calibrates T0");
    }

    #[test]
    fn knobs_off_is_bit_identical_to_default_params() {
        // Spelling every adaptive knob out in its off position must replay
        // the default engine exactly — the legacy-path pin for this PR.
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let spelled_out = AnnealParams {
            target_acceptance: None,
            equilibrium: false,
            stall_iters: 0,
            reheat: 0.5,
            cp_ladder: false,
            troublesome_seed: false,
            ..AnnealParams::fast()
        };
        let run = |params: &AnnealParams| {
            let mut rng = Rng::new(19);
            anneal(&p, &obj, &init, params, &mut rng)
        };
        let a = run(&AnnealParams::fast());
        let b = run(&spelled_out);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.cost.to_bits(), b.cost.to_bits());
        assert_eq!(a.schedule.assignment, b.schedule.assignment);
        assert_eq!(a.schedule.start, b.schedule.start);
        assert_eq!(a.stats.restarts, 0, "no stall knob, no restarts");
        assert_eq!(a.stats.calibrated_t0, None, "no target, no calibration");
    }

    #[test]
    fn evaluations_count_the_computed_solves_exactly() {
        // With a pinned T0 (no warmup) every iteration evaluates exactly
        // one assignment, either computed or answered by the memo — so
        // evaluations + cache_hits == iterations + 1 (the initial eval).
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let params = AnnealParams {
            t0: Some(0.05),
            ..AnnealParams::fast()
        };
        let mut rng = Rng::new(31);
        let r = anneal(&p, &obj, &init, &params, &mut rng);
        assert_eq!(
            r.stats.evaluations + r.stats.cache_hits,
            r.stats.iterations + 1,
            "budget accounting must cover every eval exactly once"
        );
        assert!(r.stats.evaluations >= 1);
    }

    #[test]
    fn stall_restart_fires_exactly_at_stall_iters() {
        // A one-config search space is a perfect plateau: every proposal
        // re-draws the same assignment, dE == 0 is accepted but never
        // improves, so `stale` grows by one per iteration and a restart
        // must fire exactly every `stall_iters` moves. Each restart also
        // charges one iteration for its reseed evaluation, so a budget of
        // `max_iters` buys exactly max_iters / (stall_iters + 1) restarts.
        let mut p = problem();
        let keep = p.feasible[0];
        p.feasible = vec![keep];
        let init = vec![keep; p.len()];
        let solver = CpSolver::new(Limits::inner_loop());
        let (s0, _) = solver.solve(&p, &init).unwrap();
        let obj = Objective::new(Goal::Balanced, s0.makespan(&p), s0.cost(&p));
        let params = AnnealParams {
            t0: Some(0.1), // pinned: no warmup iterations
            max_iters: 40,
            patience: 10_000,
            stall_iters: 7,
            ..AnnealParams::fast()
        };
        let mut rng = Rng::new(5);
        let r = anneal(&p, &obj, &init, &params, &mut rng);
        assert_eq!(r.stats.iterations, 40, "the full budget is consumed");
        assert_eq!(
            r.stats.restarts,
            40 / (7 + 1),
            "one restart per stall_iters+1 charged iterations"
        );
        // The plateau has a single reachable assignment: the memo answers
        // every re-evaluation after the first.
        assert_eq!(r.stats.evaluations, 1);
        assert_eq!(r.schedule.assignment, init);
    }

    #[test]
    fn propose_explores_the_market_including_purchase_toggles() {
        use crate::cluster::Config;
        let dags = vec![dag1()];
        let space = ConfigSpace::market();
        let profiles: Vec<_> = dags[0].tasks.iter().map(|t| t.profile.clone()).collect();
        let grid = OraclePredictor { profiles }.predict(&space);
        let p = Problem::new(
            &dags,
            &[0.0],
            Capacity::micro(),
            space,
            grid,
            CostModel::Market { interrupt_rate: 1.0 },
        );
        // Start every task on a spot config; the toggle move must reach
        // its on-demand twin, and every proposal must stay feasible.
        let spot_idx = crate::cluster::catalog::index_by_name("c5.4xlarge:spot").unwrap();
        let od_idx = crate::cluster::catalog::index_by_name("c5.4xlarge").unwrap();
        let start_cfg = Config { instance: spot_idx, nodes: 2, spark: 1 };
        let twin_cfg = Config { instance: od_idx, nodes: 2, spark: 1 };
        let start = p
            .space
            .configs
            .iter()
            .position(|c| *c == start_cfg)
            .unwrap();
        let twin = p.space.configs.iter().position(|c| *c == twin_cfg).unwrap();
        assert!(p.feasible.contains(&start) && p.feasible.contains(&twin));

        let current = vec![start; p.len()];
        let mut rng = Rng::new(77);
        let mut saw_twin = false;
        for _ in 0..500 {
            let proposal = propose(&p, &current, 1, &mut rng);
            for &c in &proposal {
                assert!(p.feasible.contains(&c), "infeasible proposal {c}");
            }
            saw_twin |= proposal.contains(&twin);
        }
        assert!(saw_twin, "purchase toggle never reached the on-demand twin");
    }

    #[test]
    fn dagps_seed_picks_the_fastest_per_task_feasible_config() {
        // The globally fastest config can be infeasible *for one task*:
        // its duration row there is degenerate (zero — the predictor has
        // no model for that shape on that config). The reseed must skip
        // it and fall back to that task's fastest valid config; the old
        // scan on duration alone would adopt the degenerate config, since
        // 0.0 is the global duration minimum.
        let mut p = problem();
        assert!(p.feasible.len() >= 2, "need a fallback config to pin");

        // Fastest config for `t` and, with `skip`, the runner-up it must
        // fall back to once the fastest is poisoned.
        let fastest = |p: &Problem, t: usize, skip: Option<usize>| {
            let mut best_c = usize::MAX;
            let mut best_d = f64::INFINITY;
            for &c in &p.feasible {
                if Some(c) == skip {
                    continue;
                }
                let d = p.duration(t, c);
                if d < best_d {
                    best_d = d;
                    best_c = c;
                }
            }
            best_c
        };
        // Pick a uniform incumbent whose config is NOT the fastest for
        // the most troublesome task: poisoning then can't perturb the
        // troublesome ordering, because HardestToPack scores only read
        // each task's own incumbent config, which stays untouched.
        let (init, t_star, c_fast, c_next) = p
            .feasible
            .iter()
            .find_map(|&c| {
                let init = vec![c; p.len()];
                let score = sgs::priorities(&p, &init, sgs::Rule::HardestToPack);
                let t_star = (0..p.len())
                    .max_by(|&a, &b| score[a].total_cmp(&score[b]).then(b.cmp(&a)))
                    .unwrap();
                let c_fast = fastest(&p, t_star, None);
                (c != c_fast).then(|| (init, t_star, c_fast, fastest(&p, t_star, Some(c_fast))))
            })
            .expect("some feasible config is slower than the fastest");
        assert_ne!(c_fast, c_next);

        p.grid.durations[t_star][c_fast] = 0.0;
        let seed = dagps_seed(&p, &init);
        assert_ne!(
            seed[t_star], c_fast,
            "a config with a degenerate duration row must not be adopted"
        );
        assert_eq!(
            seed[t_star], c_next,
            "the fastest per-task-feasible config wins instead"
        );
        // Every reseeded task lands on a valid duration row.
        for t in 0..p.len() {
            let d = p.duration(t, seed[t]);
            assert!(d.is_finite() && d > 0.0, "task {t} seeded onto duration {d}");
        }
    }

    #[test]
    fn troublesome_seed_at_parallelism_one_is_anneal_from_the_dagps_reseed() {
        // With the knob on, a single-chain portfolio is exactly `anneal`
        // started from the DAGPS reseed of the initial assignment — same
        // RNG stream, bit-identical outputs.
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let params = AnnealParams {
            troublesome_seed: true,
            ..AnnealParams::fast()
        };
        let via_portfolio = portfolio_anneal(&p, &obj, &init, &params, 1, 0xD46);
        let mut rng = Rng::new(0xD46);
        let direct = anneal(&p, &obj, &dagps_seed(&p, &init), &params, &mut rng);
        assert_eq!(via_portfolio.makespan.to_bits(), direct.makespan.to_bits());
        assert_eq!(via_portfolio.cost.to_bits(), direct.cost.to_bits());
        assert_eq!(
            via_portfolio.schedule.assignment,
            direct.schedule.assignment
        );
        assert_eq!(via_portfolio.schedule.start, direct.schedule.start);
    }

    #[test]
    fn troublesome_seeded_portfolio_never_loses_to_the_unseeded_single_chain() {
        // Chain 0 of a portfolio runs the base parameters from the
        // unseeded initial assignment with the base seed — the seeded
        // walk only ever occupies chain 1. With the exchange disabled the
        // chains are independent, so the portfolio winner is at most
        // chain 0's energy, which equals the plain unseeded single-chain
        // result: seeding can add a better basin but never costs one.
        let p = problem();
        let (init, m0, c0) = baseline(&p);
        let obj = Objective::new(Goal::Balanced, m0, c0);
        let params = AnnealParams {
            exchange_interval: 0,
            troublesome_seed: true,
            ..AnnealParams::fast()
        };
        let seeded = portfolio_anneal(&p, &obj, &init, &params, 2, 0xBEE);
        let mut rng = Rng::new(0xBEE);
        let unseeded = anneal(&p, &obj, &init, &params, &mut rng);
        assert!(
            seeded.energy <= unseeded.energy + 1e-12,
            "seeded portfolio {} must not degrade the unseeded chain {}",
            seeded.energy,
            unseeded.energy
        );
    }

    #[test]
    fn problem_and_exchange_are_shareable_across_threads() {
        fn assert_sync_send<T: Sync + Send>() {}
        assert_sync_send::<Problem>();
        assert_sync_send::<Objective>();
        assert_sync_send::<AnnealParams>();
        assert_sync_send::<Exchange>();
    }
}
