//! The AGORA co-optimizer facade (§4): wires Predictor → extended RCPSP →
//! simulated annealing ⊗ CP solve, and exposes the ablation modes of the
//! §5.2 performance breakdown (predictor-only, scheduler-only,
//! separately-optimized).

use std::time::Duration;

use super::anneal::{portfolio_anneal, AnnealParams, AnnealResult};
use super::cp::{CpSolver, Limits};
use super::objective::{Goal, Objective};
use super::rcpsp::Problem;
use super::schedule::Schedule;
use crate::cluster::{Capacity, Config, ConfigSpace, CostModel};
use crate::dag::Dag;
use crate::predictor::{EventLog, Grid, LearnedPredictor, Predictor};

/// Which parts of AGORA are active — the §5.2 ablation axes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Full AGORA: co-optimized configurations + schedule (Algorithm 1).
    CoOptimize,
    /// Predictor only: pick each task's best config in isolation, then
    /// schedule with the default policy order.
    PredictorOnly,
    /// Scheduler only: keep the user's default configs, optimize the
    /// schedule exactly.
    SchedulerOnly,
    /// Both, but run independently (Ernest-style selection, then
    /// scheduling) — "AGORA-separate" in Fig. 8.
    Separate,
}

impl Mode {
    /// Stable name used by reports and the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            Mode::CoOptimize => "agora",
            Mode::PredictorOnly => "predictor-only",
            Mode::SchedulerOnly => "scheduler-only",
            Mode::Separate => "agora-separate",
        }
    }
}

/// A complete optimization outcome.
#[derive(Debug, Clone)]
pub struct Plan {
    /// The chosen configuration assignment + start times.
    pub schedule: Schedule,
    /// Predicted makespan of the schedule.
    pub makespan: f64,
    /// Predicted dollar cost of the schedule.
    pub cost: f64,
    /// Optimizer wall-clock overhead (the Fig. 10 x-axis).
    pub overhead: Duration,
    /// Annealing telemetry when Mode::CoOptimize ran.
    pub anneal: Option<AnnealResult>,
}

/// Co-optimizer configuration.
#[derive(Debug, Clone)]
pub struct AgoraOptions {
    /// Runtime/cost trade-off of Eq. 1.
    pub goal: Goal,
    /// Which parts of AGORA are active (ablations).
    pub mode: Mode,
    /// Annealing hyper-parameters.
    pub params: AnnealParams,
    /// Hard Eq. 7 budget (infinity = unconstrained).
    pub makespan_budget: f64,
    /// Hard Eq. 8 budget (infinity = unconstrained).
    pub cost_budget: f64,
    /// Seed of the optimizer's RNG stream.
    pub seed: u64,
    /// Simultaneous annealing chains for Mode::CoOptimize. 1 = the
    /// historical deterministic single chain (bit-identical per seed);
    /// K > 1 = a diversified portfolio with best-plan exchange (see
    /// `solver::anneal::portfolio_anneal`).
    pub parallelism: usize,
}

impl Default for AgoraOptions {
    fn default() -> Self {
        AgoraOptions {
            goal: Goal::Balanced,
            mode: Mode::CoOptimize,
            params: AnnealParams::default(),
            makespan_budget: f64::INFINITY,
            cost_budget: f64::INFINITY,
            seed: 0xA60BA,
            parallelism: 1,
        }
    }
}

/// The user-facing co-optimizer.
pub struct Agora {
    /// The configured options.
    pub options: AgoraOptions,
}

impl Agora {
    /// Co-optimizer with the given options.
    pub fn new(options: AgoraOptions) -> Self {
        Agora { options }
    }

    /// Default user configuration: the "carefully chosen by Spark
    /// experts" baseline of §5 — 8 x m5.4xlarge, balanced preset. Experts
    /// tune each job for good standalone runtime (the paper's Table 2
    /// shows Ernest picking 10-16 nodes per job), without a view of DAG
    /// overlap — exactly the gap co-optimization exploits.
    pub fn default_config(space: &ConfigSpace) -> usize {
        space
            .configs
            .iter()
            .position(|c| {
                *c == Config {
                    instance: 0,
                    nodes: 8,
                    spark: 1,
                }
            })
            .unwrap_or(0)
    }

    /// Assemble a problem from DAGs + event logs using the learned
    /// predictor (host path; the PJRT path builds the same Grid through
    /// `runtime::PjrtPredictor` and is numerically interchangeable).
    pub fn build_problem(
        dags: &[Dag],
        releases: &[f64],
        logs: &[EventLog],
        capacity: Capacity,
        space: ConfigSpace,
        cost_model: CostModel,
    ) -> Problem {
        let predictor = LearnedPredictor::fit(logs);
        let grid = predictor.predict(&space);
        Problem::new(dags, releases, capacity, space, grid, cost_model)
    }

    /// Assemble a problem from an externally produced grid (oracle tests,
    /// PJRT predictor, trace replay).
    pub fn build_problem_with_grid(
        dags: &[Dag],
        releases: &[f64],
        grid: Grid,
        capacity: Capacity,
        space: ConfigSpace,
        cost_model: CostModel,
    ) -> Problem {
        Problem::new(dags, releases, capacity, space, grid, cost_model)
    }

    /// Optimize a problem. The baseline for Eq. 1 improvements is the
    /// default-config schedule under the default (Airflow-like) order.
    ///
    /// ```
    /// use agora::cluster::{Capacity, ConfigSpace, CostModel};
    /// use agora::dag::workloads::dag1;
    /// use agora::predictor::{bootstrap_history, default_profiling_configs};
    /// use agora::solver::{Agora, AgoraOptions, AnnealParams};
    /// use agora::util::Rng;
    ///
    /// let dags = vec![dag1()];
    /// let mut rng = Rng::new(7);
    /// let logs: Vec<_> = dags[0]
    ///     .tasks
    ///     .iter()
    ///     .map(|t| {
    ///         bootstrap_history(&t.name, &t.profile, &default_profiling_configs(), &mut rng)
    ///     })
    ///     .collect();
    /// let p = Agora::build_problem(
    ///     &dags,
    ///     &[0.0],
    ///     &logs,
    ///     Capacity::micro(),
    ///     ConfigSpace::standard(),
    ///     CostModel::OnDemand,
    /// );
    /// let plan = Agora::new(AgoraOptions {
    ///     params: AnnealParams::fast(),
    ///     ..Default::default()
    /// })
    /// .optimize(&p);
    /// assert!(plan.makespan > 0.0 && plan.cost > 0.0);
    /// plan.schedule.validate(&p).unwrap();
    /// ```
    pub fn optimize(&self, p: &Problem) -> Plan {
        let t0 = std::time::Instant::now();
        // Baseline configuration, clamped into the problem's feasible set
        // (non-empty by `Problem::new`): on a cluster too small for the
        // default 8-node shape the baseline degrades to a feasible config
        // instead of tripping the over-capacity error below.
        let default_cfg = Self::default_config(&p.space);
        let default_cfg = if p.feasible.contains(&default_cfg) {
            default_cfg
        } else {
            p.feasible[0]
        };
        let default_assignment = vec![default_cfg; p.len()];

        // Baseline (M, C) of Eq. 1.
        let solver = CpSolver::new(self.options.params.inner_limits.clone());
        let (base_sched, _) = solver
            .solve(p, &default_assignment)
            .expect("the default configuration must fit the cluster capacity");
        let base_makespan = base_sched.makespan(p);
        let base_cost = base_sched.cost(p);
        let mut objective = Objective::new(self.options.goal, base_makespan, base_cost)
            .with_budgets(self.options.makespan_budget, self.options.cost_budget);
        if self.options.goal == Goal::DeadlineCost {
            // Deadline-aware cost minimization: hard SLA deadlines become
            // Eq. 7 makespan budgets, soft ones a penalty schedule folded
            // into the cost term. With only unbounded SLAs attached this
            // is a no-op and the search is bit-identical to Goal::Cost.
            objective = objective.with_slas(&p.slas);
        }

        let plan = match self.options.mode {
            Mode::CoOptimize => {
                // Every parallelism routes through the portfolio entry
                // point: at parallelism 1 it degrades to the plain seeded
                // single chain (bit-identical to calling `anneal` with
                // `Rng::new(seed)` directly), and it is also where the
                // troublesome-seed knob derives the DAGPS-seeded start.
                let r = portfolio_anneal(
                    p,
                    &objective,
                    &default_assignment,
                    &self.options.params,
                    self.options.parallelism,
                    self.options.seed,
                );
                Plan {
                    makespan: r.makespan,
                    cost: r.cost,
                    schedule: r.schedule.clone(),
                    overhead: t0.elapsed(),
                    anneal: Some(r),
                }
            }
            Mode::PredictorOnly => {
                // Pick each task's individually best config for the goal,
                // then schedule with the plain critical-path order (no
                // schedule optimization).
                let assignment = per_task_best(p, self.options.goal);
                let prio =
                    super::sgs::priorities(p, &assignment, super::sgs::Rule::CriticalPath);
                let schedule = super::sgs::serial_sgs(p, &assignment, &prio)
                    .expect("per-task-best assignments draw from Problem::feasible");
                finish_plan(p, schedule, t0)
            }
            Mode::SchedulerOnly => {
                // Default configs, exact schedule optimization. The
                // cp_ladder knob swaps in the destructive UB-ladder solve.
                let (schedule, _) = self
                    .one_shot_solve(p, &default_assignment)
                    .expect("the default configuration must fit the cluster capacity");
                finish_plan(p, schedule, t0)
            }
            Mode::Separate => {
                // Ernest-then-schedule: independently chosen configs, then
                // exact schedule for those configs (no feedback loop).
                let assignment = per_task_best(p, self.options.goal);
                let (schedule, _) = self
                    .one_shot_solve(p, &assignment)
                    .expect("per-task-best assignments draw from Problem::feasible");
                finish_plan(p, schedule, t0)
            }
        };
        plan
    }

    /// One-shot schedule optimization for the scheduler-only/separate
    /// ablations: the default full-budget CP descent, or — with the
    /// `cp_ladder` knob on — the destructive UB-ladder solve.
    fn one_shot_solve(
        &self,
        p: &Problem,
        assignment: &[usize],
    ) -> anyhow::Result<(Schedule, super::cp::Stats)> {
        if self.options.params.cp_ladder {
            CpSolver::new(Limits::ladder()).solve_ladder(p, assignment)
        } else {
            CpSolver::new(Limits::default()).solve(p, assignment)
        }
    }
}

fn finish_plan(p: &Problem, schedule: Schedule, t0: std::time::Instant) -> Plan {
    let makespan = schedule.makespan(p);
    let cost = schedule.cost(p);
    Plan {
        schedule,
        makespan,
        cost,
        overhead: t0.elapsed(),
        anneal: None,
    }
}

/// Per-task greedy config choice — what a task-local optimizer (Ernest)
/// does: no view of the DAG or the cluster contention.
pub fn per_task_best(p: &Problem, goal: Goal) -> Vec<usize> {
    let w = goal.weight();
    (0..p.len())
        .map(|t| {
            // Normalize duration and cost against the best achievable for
            // THIS task so the blend is scale-free (the per-task analogue
            // of Eq. 1's percentage terms).
            let min_d = p
                .feasible
                .iter()
                .map(|&c| p.duration(t, c))
                .fold(f64::INFINITY, f64::min)
                .max(1e-9);
            let min_cost = p
                .feasible
                .iter()
                .map(|&c| p.cost(t, c))
                .fold(f64::INFINITY, f64::min)
                .max(1e-9);
            let score = |c: usize| {
                w * p.duration(t, c) / min_d + (1.0 - w) * p.cost(t, c) / min_cost
            };
            *p.feasible
                .iter()
                .min_by(|&&a, &&b| score(a).total_cmp(&score(b)))
                .unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::workloads::{dag1, dag2};
    use crate::predictor::{bootstrap_history, default_profiling_configs};
    use crate::util::Rng;

    fn problem(dag_fn: fn() -> Dag) -> Problem {
        let dags = vec![dag_fn()];
        let mut rng = Rng::new(33);
        let logs: Vec<EventLog> = dags[0]
            .tasks
            .iter()
            .map(|t| {
                bootstrap_history(&t.name, &t.profile, &default_profiling_configs(), &mut rng)
            })
            .collect();
        Agora::build_problem(
            &dags,
            &[0.0],
            &logs,
            Capacity::micro(),
            ConfigSpace::standard(),
            CostModel::OnDemand,
        )
    }

    fn run(mode: Mode, goal: Goal, p: &Problem) -> Plan {
        let agora = Agora::new(AgoraOptions {
            goal,
            mode,
            params: AnnealParams::fast(),
            ..Default::default()
        });
        agora.optimize(p)
    }

    #[test]
    fn all_modes_produce_valid_schedules() -> anyhow::Result<()> {
        use anyhow::Context;
        let p = problem(dag1);
        for mode in [
            Mode::CoOptimize,
            Mode::PredictorOnly,
            Mode::SchedulerOnly,
            Mode::Separate,
        ] {
            let plan = run(mode, Goal::Balanced, &p);
            plan.schedule
                .validate(&p)
                .with_context(|| format!("{mode:?}"))?;
            assert!(plan.makespan > 0.0);
            assert!(plan.cost > 0.0);
        }
        Ok(())
    }

    #[test]
    fn parallelism_one_is_bit_identical_to_seeded_single_chain() {
        // The portfolio refactor must not perturb the deterministic
        // single-chain path: optimize() at parallelism = 1 reproduces the
        // exact seeded plan (makespan, cost, assignment, schedule order)
        // of the reference pipeline the seed crate ran.
        use crate::solver::anneal::anneal;
        use crate::solver::objective::Objective;
        use crate::solver::cp::CpSolver;

        let p = problem(dag1);
        let seed = 0xA60BAu64;
        let options = AgoraOptions {
            goal: Goal::Balanced,
            mode: Mode::CoOptimize,
            params: AnnealParams::fast(),
            seed,
            parallelism: 1,
            ..Default::default()
        };
        let plan = Agora::new(options.clone()).optimize(&p);

        // Reference: the historical single-chain pipeline, inlined.
        let default_cfg = Agora::default_config(&p.space);
        let default_assignment = vec![default_cfg; p.len()];
        let solver = CpSolver::new(options.params.inner_limits.clone());
        let (base_sched, _) = solver.solve(&p, &default_assignment).unwrap();
        let objective = Objective::new(
            options.goal,
            base_sched.makespan(&p),
            base_sched.cost(&p),
        );
        let mut rng = Rng::new(seed);
        let r = anneal(&p, &objective, &default_assignment, &options.params, &mut rng);

        assert_eq!(plan.makespan, r.makespan);
        assert_eq!(plan.cost, r.cost);
        assert_eq!(plan.schedule.assignment, r.schedule.assignment);
        assert_eq!(plan.schedule.start, r.schedule.start);
    }

    #[test]
    fn portfolio_optimize_is_valid_and_not_worse() {
        let p = problem(dag2);
        let single = Agora::new(AgoraOptions {
            goal: Goal::Balanced,
            params: AnnealParams::fast(),
            parallelism: 1,
            ..Default::default()
        })
        .optimize(&p);
        let portfolio = Agora::new(AgoraOptions {
            goal: Goal::Balanced,
            params: AnnealParams::fast(),
            parallelism: 4,
            ..Default::default()
        })
        .optimize(&p);
        portfolio.schedule.validate(&p).unwrap();
        let a = portfolio.anneal.as_ref().expect("portfolio telemetry");
        assert!(a.stats.iterations > 0);
        // Both searched the same problem from the same baseline; the
        // portfolio includes the exploiter chain family, so it must land
        // in the same quality regime (generous 10% slack for the
        // different chain seeds).
        let norm = |plan: &Plan| {
            0.5 * plan.makespan / single.makespan + 0.5 * plan.cost / single.cost
        };
        assert!(
            norm(&portfolio) <= 1.10,
            "portfolio {:.3} much worse than single-chain baseline",
            norm(&portfolio)
        );
    }

    #[test]
    fn cooptimize_beats_separate_on_balanced_goal() {
        // The paper's core claim (§5.2): AGORA > AGORA-separate.
        for p in [problem(dag1), problem(dag2)] {
            let co = run(Mode::CoOptimize, Goal::Balanced, &p);
            let sep = run(Mode::Separate, Goal::Balanced, &p);
            let norm = |plan: &Plan| {
                0.5 * plan.makespan / sep.makespan + 0.5 * plan.cost / sep.cost
            };
            assert!(
                norm(&co) <= norm(&sep) + 0.05,
                "co-optimize {:.3} should be <= separate {:.3}",
                norm(&co),
                norm(&sep)
            );
        }
    }

    #[test]
    fn goal_shifts_the_tradeoff() {
        let p = problem(dag2);
        let runtime = run(Mode::CoOptimize, Goal::Runtime, &p);
        let cost = run(Mode::CoOptimize, Goal::Cost, &p);
        assert!(
            runtime.makespan <= cost.makespan + 1e-6,
            "runtime goal should be faster: {} vs {}",
            runtime.makespan,
            cost.makespan
        );
        assert!(
            cost.cost <= runtime.cost + 1e-6,
            "cost goal should be cheaper: {} vs {}",
            cost.cost,
            runtime.cost
        );
    }

    #[test]
    fn overhead_is_recorded() {
        let p = problem(dag1);
        let plan = run(Mode::CoOptimize, Goal::Balanced, &p);
        assert!(plan.overhead > Duration::ZERO);
        assert!(plan.anneal.is_some());
    }
}
