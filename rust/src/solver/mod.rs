//! The AGORA optimization engine (§4): extended-RCPSP problem model,
//! the shared block-indexed capacity-timeline kernel, CP-style
//! exact/anytime schedule solver, simulated-annealing outer loop
//! (Algorithm 1), brute-force reference, and the co-optimizer facade.

pub mod anneal;
pub mod brute_force;
pub mod cooptimizer;
pub mod cp;
pub mod objective;
pub mod rcpsp;
pub mod schedule;
pub mod sgs;
pub mod timeline;

pub use anneal::{anneal, portfolio_anneal, AnnealParams, AnnealResult};
pub use cooptimizer::{Agora, AgoraOptions, Mode, Plan};
pub use cp::{CpSolver, Limits};
pub use objective::{Goal, Objective, Sla};
pub use rcpsp::{Problem, Reservation};
pub use schedule::Schedule;
pub use timeline::{Mark, Timeline};
