//! Optimization objective — Eq. 1 of the paper, with budgets (Eq. 7–8).
//!
//!   minimize  w * (M_opt - M)/M + (1 - w) * (C_opt - C)/C
//!
//! where (M, C) are the baseline makespan/cost (the incumbent the
//! improvement is measured against) and w slides between pure-cost
//! (w = 0) and pure-runtime (w = 1) optimization.

/// Named goals used across the evaluation (§5.1/§5.3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Goal {
    /// w = 0: lowest cost.
    Cost,
    /// w = 0.5: balanced.
    Balanced,
    /// w = 1: shortest runtime.
    Runtime,
    /// Arbitrary weight in [0, 1].
    Weighted(f64),
    /// w = 0 **subject to deadlines**: minimize cost with per-DAG SLA
    /// deadlines enforced as Eq. 7 makespan budgets (hard SLAs) and
    /// accounted as dollar penalties folded into the cost term (soft
    /// SLAs). With no bounded SLA attached this degenerates to
    /// [`Goal::Cost`] bit-for-bit — the same w = 0 arithmetic with an
    /// empty penalty schedule.
    DeadlineCost,
}

impl Goal {
    /// The w of Eq. 1 for this goal.
    pub fn weight(&self) -> f64 {
        match self {
            Goal::Cost => 0.0,
            Goal::Balanced => 0.5,
            Goal::Runtime => 1.0,
            Goal::Weighted(w) => w.clamp(0.0, 1.0),
            Goal::DeadlineCost => 0.0,
        }
    }

    /// Stable name used by reports and the CLI.
    pub fn name(&self) -> String {
        match self {
            Goal::Cost => "cost".into(),
            Goal::Balanced => "balanced".into(),
            Goal::Runtime => "runtime".into(),
            Goal::Weighted(w) => format!("w={w:.2}"),
            Goal::DeadlineCost => "deadline-cost".into(),
        }
    }

    /// Parse a CLI spelling (`cost` | `balanced` | `runtime` |
    /// `deadline-cost` | `w=<0..1>`).
    pub fn parse(s: &str) -> Option<Goal> {
        match s {
            "cost" => Some(Goal::Cost),
            "balanced" => Some(Goal::Balanced),
            "runtime" => Some(Goal::Runtime),
            "deadline-cost" => Some(Goal::DeadlineCost),
            _ => s.strip_prefix("w=")?.parse().ok().map(Goal::Weighted),
        }
    }
}

/// A per-DAG service-level agreement: a completion deadline in the
/// problem's time base, a dollar penalty rate for soft misses, and a
/// hardness flag that arms admission control and deadline-at-risk spot
/// migration. The default ([`Sla::none`]) is unbounded and inert —
/// attaching it changes nothing anywhere.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sla {
    /// Completion deadline (seconds, problem time base); infinity = no
    /// deadline.
    pub deadline: f64,
    /// Dollars accrued per second past the deadline on a soft miss.
    pub penalty_per_sec: f64,
    /// Hard SLA: admission may reject or defer the DAG when its
    /// completion lower bound provably exceeds the deadline.
    pub hard: bool,
}

impl Default for Sla {
    fn default() -> Self {
        Sla::none()
    }
}

impl Sla {
    /// No SLA: infinite deadline, zero penalty, soft.
    pub fn none() -> Sla {
        Sla {
            deadline: f64::INFINITY,
            penalty_per_sec: 0.0,
            hard: false,
        }
    }

    /// A soft SLA: misses accrue `penalty_per_sec` dollars per second.
    pub fn soft(deadline: f64, penalty_per_sec: f64) -> Sla {
        Sla {
            deadline,
            penalty_per_sec,
            hard: false,
        }
    }

    /// A hard SLA: admission control may reject/defer, and the replan
    /// path migrates at-risk tasks off spot capacity.
    pub fn hard(deadline: f64) -> Sla {
        Sla {
            deadline,
            penalty_per_sec: 0.0,
            hard: true,
        }
    }

    /// Whether this SLA constrains nothing (infinite deadline).
    pub fn is_unbounded(&self) -> bool {
        self.deadline == f64::INFINITY
    }

    /// Whether a realized completion meets the deadline.
    pub fn met(&self, completion: f64) -> bool {
        completion <= self.deadline
    }

    /// Dollar penalty for a realized completion: 0 at or before the
    /// deadline (and always 0 when unbounded), linear in the overshoot
    /// after it.
    pub fn penalty(&self, completion: f64) -> f64 {
        if completion <= self.deadline {
            0.0
        } else {
            (completion - self.deadline) * self.penalty_per_sec
        }
    }
}

/// The Eq. 1 objective with baselines and budgets.
#[derive(Debug, Clone)]
pub struct Objective {
    /// The runtime/cost trade-off being optimized.
    pub goal: Goal,
    /// Baseline makespan M (original, pre-optimization).
    pub base_makespan: f64,
    /// Baseline cost C.
    pub base_cost: f64,
    /// M_budget (Eq. 7); infinity when unset.
    pub makespan_budget: f64,
    /// C_budget (Eq. 8); infinity when unset.
    pub cost_budget: f64,
    /// Soft-SLA penalty schedule `(deadline, penalty_per_sec)` applied
    /// to the candidate makespan (the completion upper bound of every
    /// DAG in the problem): dollars past each deadline are folded into
    /// the cost term before normalization. Empty when no bounded soft
    /// SLA is attached — and then [`Objective::energy`] is bit-identical
    /// to the SLA-free arithmetic.
    pub soft_slas: Vec<(f64, f64)>,
}

impl Objective {
    /// Objective against a baseline (M, C), with no budgets.
    pub fn new(goal: Goal, base_makespan: f64, base_cost: f64) -> Self {
        Objective {
            goal,
            base_makespan: base_makespan.max(1e-9),
            base_cost: base_cost.max(1e-9),
            makespan_budget: f64::INFINITY,
            cost_budget: f64::INFINITY,
            soft_slas: Vec::new(),
        }
    }

    /// Attach hard Eq. 7-8 budgets (infinity = unconstrained).
    pub fn with_budgets(mut self, makespan_budget: f64, cost_budget: f64) -> Self {
        self.makespan_budget = makespan_budget;
        self.cost_budget = cost_budget;
        self
    }

    /// Attach per-DAG SLAs: every bounded **hard** deadline tightens the
    /// Eq. 7 makespan budget (makespan <= the earliest hard deadline
    /// implies every DAG meets its own), and every bounded **soft**
    /// deadline joins the penalty schedule folded into the cost term by
    /// [`Objective::energy`]. Unbounded SLAs change nothing: with only
    /// [`Sla::none`] entries this is a no-op and the energy arithmetic
    /// stays bit-identical.
    pub fn with_slas(mut self, slas: &[Sla]) -> Self {
        for sla in slas {
            if sla.is_unbounded() {
                continue;
            }
            if sla.hard {
                self.makespan_budget = self.makespan_budget.min(sla.deadline);
            }
            if sla.penalty_per_sec > 0.0 {
                self.soft_slas.push((sla.deadline, sla.penalty_per_sec));
            }
        }
        self
    }

    /// The energy of a candidate (lower is better). Budget violations
    /// (Eq. 7–8) are infeasible: +infinity energy. Soft-SLA penalties
    /// (dollars past each deadline, with the makespan standing in as the
    /// completion upper bound of every DAG) are added to the cost before
    /// normalization.
    pub fn energy(&self, makespan: f64, cost: f64) -> f64 {
        if makespan > self.makespan_budget || cost > self.cost_budget {
            return f64::INFINITY;
        }
        let mut cost = cost;
        for &(deadline, rate) in &self.soft_slas {
            if makespan > deadline {
                cost += (makespan - deadline) * rate;
            }
        }
        let w = self.goal.weight();
        w * (makespan - self.base_makespan) / self.base_makespan
            + (1.0 - w) * (cost - self.base_cost) / self.base_cost
    }

    /// Feasibility test alone (for filtering candidates).
    pub fn within_budgets(&self, makespan: f64, cost: f64) -> bool {
        makespan <= self.makespan_budget && cost <= self.cost_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn goal_weights() {
        assert_eq!(Goal::Cost.weight(), 0.0);
        assert_eq!(Goal::Balanced.weight(), 0.5);
        assert_eq!(Goal::Runtime.weight(), 1.0);
        assert_eq!(Goal::Weighted(0.3).weight(), 0.3);
        assert_eq!(Goal::Weighted(7.0).weight(), 1.0); // clamped
    }

    #[test]
    fn goal_parse_roundtrip() {
        assert_eq!(Goal::parse("cost"), Some(Goal::Cost));
        assert_eq!(Goal::parse("balanced"), Some(Goal::Balanced));
        assert_eq!(Goal::parse("runtime"), Some(Goal::Runtime));
        assert_eq!(Goal::parse("w=0.25"), Some(Goal::Weighted(0.25)));
        assert_eq!(Goal::parse("speed"), None);
    }

    #[test]
    fn runtime_goal_ignores_cost() {
        let o = Objective::new(Goal::Runtime, 100.0, 10.0);
        // halving makespan at double cost is still -0.5 energy
        assert!((o.energy(50.0, 20.0) - (-0.5)).abs() < 1e-12);
    }

    #[test]
    fn cost_goal_ignores_makespan() {
        let o = Objective::new(Goal::Cost, 100.0, 10.0);
        assert!((o.energy(200.0, 5.0) - (-0.5)).abs() < 1e-12);
    }

    #[test]
    fn balanced_mixes_both() {
        let o = Objective::new(Goal::Balanced, 100.0, 10.0);
        let e = o.energy(80.0, 8.0); // both improved 20%
        assert!((e - (-0.2)).abs() < 1e-12);
    }

    #[test]
    fn budgets_are_hard() {
        let o = Objective::new(Goal::Balanced, 100.0, 10.0).with_budgets(90.0, 12.0);
        assert!(o.energy(95.0, 5.0).is_infinite());
        assert!(o.energy(80.0, 13.0).is_infinite());
        assert!(o.energy(85.0, 11.0).is_finite());
        assert!(o.within_budgets(90.0, 12.0));
        assert!(!o.within_budgets(90.1, 12.0));
    }

    #[test]
    fn deadline_cost_goal_parses_names_and_weights_like_cost() {
        assert_eq!(Goal::DeadlineCost.weight(), 0.0);
        assert_eq!(Goal::DeadlineCost.name(), "deadline-cost");
        assert_eq!(Goal::parse("deadline-cost"), Some(Goal::DeadlineCost));
    }

    #[test]
    fn unbounded_sla_is_inert() {
        let sla = Sla::none();
        assert!(sla.is_unbounded());
        assert!(sla.met(1e12));
        assert_eq!(sla.penalty(1e12), 0.0);
        assert_eq!(Sla::default(), sla);
    }

    #[test]
    fn soft_sla_penalty_is_linear_in_overshoot() {
        let sla = Sla::soft(100.0, 0.5);
        assert_eq!(sla.penalty(100.0), 0.0);
        assert!((sla.penalty(130.0) - 15.0).abs() < 1e-12);
        assert!(sla.met(100.0));
        assert!(!sla.met(100.1));
    }

    #[test]
    fn with_slas_tightens_budget_and_schedules_penalties() {
        let o = Objective::new(Goal::DeadlineCost, 100.0, 10.0)
            .with_slas(&[Sla::hard(80.0), Sla::soft(60.0, 1.0), Sla::none()]);
        assert_eq!(o.makespan_budget, 80.0);
        assert_eq!(o.soft_slas, vec![(60.0, 1.0)]);
        // Past the hard deadline: infeasible.
        assert!(o.energy(81.0, 1.0).is_infinite());
        // Past the soft deadline: 10 seconds late at $1/s = $10 extra cost.
        let on_time = o.energy(60.0, 5.0);
        let late = o.energy(70.0, 5.0);
        assert!((late - on_time - 10.0 / 10.0).abs() < 1e-12);
    }

    #[test]
    fn deadline_cost_without_bounded_slas_is_bit_identical_to_cost() {
        let cost = Objective::new(Goal::Cost, 137.0, 9.25);
        let dc = Objective::new(Goal::DeadlineCost, 137.0, 9.25)
            .with_slas(&[Sla::none(), Sla::none()]);
        for (m, c) in [(137.0, 9.25), (88.5, 4.125), (250.0, 31.0)] {
            assert_eq!(cost.energy(m, c).to_bits(), dc.energy(m, c).to_bits());
        }
    }

    #[test]
    fn improvement_is_negative_energy() {
        let o = Objective::new(Goal::Balanced, 100.0, 10.0);
        assert!(o.energy(90.0, 9.0) < 0.0);
        assert!(o.energy(110.0, 11.0) > 0.0);
        assert_eq!(o.energy(100.0, 10.0), 0.0);
    }
}
