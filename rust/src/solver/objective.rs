//! Optimization objective — Eq. 1 of the paper, with budgets (Eq. 7–8).
//!
//!   minimize  w * (M_opt - M)/M + (1 - w) * (C_opt - C)/C
//!
//! where (M, C) are the baseline makespan/cost (the incumbent the
//! improvement is measured against) and w slides between pure-cost
//! (w = 0) and pure-runtime (w = 1) optimization.

/// Named goals used across the evaluation (§5.1/§5.3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Goal {
    /// w = 0: lowest cost.
    Cost,
    /// w = 0.5: balanced.
    Balanced,
    /// w = 1: shortest runtime.
    Runtime,
    /// Arbitrary weight in [0, 1].
    Weighted(f64),
}

impl Goal {
    /// The w of Eq. 1 for this goal.
    pub fn weight(&self) -> f64 {
        match self {
            Goal::Cost => 0.0,
            Goal::Balanced => 0.5,
            Goal::Runtime => 1.0,
            Goal::Weighted(w) => w.clamp(0.0, 1.0),
        }
    }

    /// Stable name used by reports and the CLI.
    pub fn name(&self) -> String {
        match self {
            Goal::Cost => "cost".into(),
            Goal::Balanced => "balanced".into(),
            Goal::Runtime => "runtime".into(),
            Goal::Weighted(w) => format!("w={w:.2}"),
        }
    }

    /// Parse a CLI spelling (`cost` | `balanced` | `runtime` | `w=<0..1>`).
    pub fn parse(s: &str) -> Option<Goal> {
        match s {
            "cost" => Some(Goal::Cost),
            "balanced" => Some(Goal::Balanced),
            "runtime" => Some(Goal::Runtime),
            _ => s.strip_prefix("w=")?.parse().ok().map(Goal::Weighted),
        }
    }
}

/// The Eq. 1 objective with baselines and budgets.
#[derive(Debug, Clone)]
pub struct Objective {
    /// The runtime/cost trade-off being optimized.
    pub goal: Goal,
    /// Baseline makespan M (original, pre-optimization).
    pub base_makespan: f64,
    /// Baseline cost C.
    pub base_cost: f64,
    /// M_budget (Eq. 7); infinity when unset.
    pub makespan_budget: f64,
    /// C_budget (Eq. 8); infinity when unset.
    pub cost_budget: f64,
}

impl Objective {
    /// Objective against a baseline (M, C), with no budgets.
    pub fn new(goal: Goal, base_makespan: f64, base_cost: f64) -> Self {
        Objective {
            goal,
            base_makespan: base_makespan.max(1e-9),
            base_cost: base_cost.max(1e-9),
            makespan_budget: f64::INFINITY,
            cost_budget: f64::INFINITY,
        }
    }

    /// Attach hard Eq. 7-8 budgets (infinity = unconstrained).
    pub fn with_budgets(mut self, makespan_budget: f64, cost_budget: f64) -> Self {
        self.makespan_budget = makespan_budget;
        self.cost_budget = cost_budget;
        self
    }

    /// The energy of a candidate (lower is better). Budget violations
    /// (Eq. 7–8) are infeasible: +infinity energy.
    pub fn energy(&self, makespan: f64, cost: f64) -> f64 {
        if makespan > self.makespan_budget || cost > self.cost_budget {
            return f64::INFINITY;
        }
        let w = self.goal.weight();
        w * (makespan - self.base_makespan) / self.base_makespan
            + (1.0 - w) * (cost - self.base_cost) / self.base_cost
    }

    /// Feasibility test alone (for filtering candidates).
    pub fn within_budgets(&self, makespan: f64, cost: f64) -> bool {
        makespan <= self.makespan_budget && cost <= self.cost_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn goal_weights() {
        assert_eq!(Goal::Cost.weight(), 0.0);
        assert_eq!(Goal::Balanced.weight(), 0.5);
        assert_eq!(Goal::Runtime.weight(), 1.0);
        assert_eq!(Goal::Weighted(0.3).weight(), 0.3);
        assert_eq!(Goal::Weighted(7.0).weight(), 1.0); // clamped
    }

    #[test]
    fn goal_parse_roundtrip() {
        assert_eq!(Goal::parse("cost"), Some(Goal::Cost));
        assert_eq!(Goal::parse("balanced"), Some(Goal::Balanced));
        assert_eq!(Goal::parse("runtime"), Some(Goal::Runtime));
        assert_eq!(Goal::parse("w=0.25"), Some(Goal::Weighted(0.25)));
        assert_eq!(Goal::parse("speed"), None);
    }

    #[test]
    fn runtime_goal_ignores_cost() {
        let o = Objective::new(Goal::Runtime, 100.0, 10.0);
        // halving makespan at double cost is still -0.5 energy
        assert!((o.energy(50.0, 20.0) - (-0.5)).abs() < 1e-12);
    }

    #[test]
    fn cost_goal_ignores_makespan() {
        let o = Objective::new(Goal::Cost, 100.0, 10.0);
        assert!((o.energy(200.0, 5.0) - (-0.5)).abs() < 1e-12);
    }

    #[test]
    fn balanced_mixes_both() {
        let o = Objective::new(Goal::Balanced, 100.0, 10.0);
        let e = o.energy(80.0, 8.0); // both improved 20%
        assert!((e - (-0.2)).abs() < 1e-12);
    }

    #[test]
    fn budgets_are_hard() {
        let o = Objective::new(Goal::Balanced, 100.0, 10.0).with_budgets(90.0, 12.0);
        assert!(o.energy(95.0, 5.0).is_infinite());
        assert!(o.energy(80.0, 13.0).is_infinite());
        assert!(o.energy(85.0, 11.0).is_finite());
        assert!(o.within_budgets(90.0, 12.0));
        assert!(!o.within_budgets(90.1, 12.0));
    }

    #[test]
    fn improvement_is_negative_energy() {
        let o = Objective::new(Goal::Balanced, 100.0, 10.0);
        assert!(o.energy(90.0, 9.0) < 0.0);
        assert!(o.energy(110.0, 11.0) > 0.0);
        assert_eq!(o.energy(100.0, 10.0), 0.0);
    }
}
