//! Launcher configuration: JSON file + CLI overrides.
//!
//! The `agora` binary reads an optional JSON config (`--config file`),
//! then applies CLI flags on top, so experiments are reproducible from a
//! single checked-in file while staying easy to tweak interactively.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::cluster::{Capacity, ConfigSpace, CostModel};
use crate::coordinator::{Admission, SlaPolicy};
use crate::sim::{CapacityOutage, ReplanPolicy};
use crate::solver::anneal::AnnealParams;
use crate::solver::{Goal, Mode};
use crate::util::{Args, Json};

pub use crate::util::cli::Args as CliArgs;

/// Fully resolved launcher configuration.
#[derive(Debug, Clone)]
pub struct AppConfig {
    /// Optimization goal (Eq. 1 trade-off).
    pub goal: Goal,
    /// Which parts of AGORA are active (ablations).
    pub mode: Mode,
    /// Simulated cluster capacity.
    pub capacity: Capacity,
    /// RNG seed of the run.
    pub seed: u64,
    /// Directory holding the AOT artifacts (PJRT path).
    pub artifacts_dir: PathBuf,
    /// Use the PJRT predictor path (requires artifacts) instead of host.
    pub use_pjrt: bool,
    /// Hard Eq. 7 budget in seconds (infinity = unconstrained).
    pub makespan_budget: f64,
    /// Hard Eq. 8 budget in dollars (infinity = unconstrained).
    pub cost_budget: f64,
    /// Annealing hyper-parameters.
    pub anneal: AnnealParams,
    /// Portfolio co-optimizer chains (1 = deterministic single chain).
    pub parallelism: usize,
    /// Mid-flight re-planning + divergence injection for `execute`-style
    /// runs (off by default: bit-identical to the open-loop executor).
    pub replan: ReplanPolicy,
    /// Coordinator admission mode for `trace`/`serve`: round-barrier
    /// (default, the historical behaviour) or continuous admission onto
    /// the occupied-cluster timeline.
    pub admission: Admission,
    /// Number of ~1000-task large-scale DAGs
    /// ([`crate::dag::generator::large_scale_dag`]) appended to the
    /// `trace` workload (0 = off). Widens scenario diversity beyond the
    /// figure-sized DAGs; expect a noticeably longer run.
    pub trace_large: usize,
    /// Search the heterogeneous instance market
    /// ([`ConfigSpace::market`]: m5/c5/r5 x on-demand/spot) instead of
    /// the historical m5-only space, priced by [`CostModel::Market`].
    pub market: bool,
    /// Per-DAG deadline slack for `trace`/`serve` as a multiple of each
    /// DAG's critical-path completion lower bound (0 = SLAs off). When
    /// armed, the coordinator attaches deadlines and admission control
    /// rejects or defers DAGs that provably cannot meet them.
    pub deadline_frac: f64,
    /// Soft-SLA penalty in dollars per second past a missed deadline.
    /// `0` keeps deadlines hard (admission-enforced); `> 0` switches to
    /// soft SLAs that are accounted as `penalty_cost` instead.
    pub sla_penalty: f64,
    /// Optimization worker threads for `serve` (1 = the deterministic
    /// legacy serial stream).
    pub workers: usize,
    /// Per-tenant ingress queue bound for `serve` (0 = unbounded; a full
    /// queue rejects submissions with explicit backpressure).
    pub queue_bound: usize,
    /// Status-ticker period for `serve` in milliseconds (0 = off).
    pub status_interval_ms: u64,
    /// Chatty output.
    pub verbose: bool,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            goal: Goal::Balanced,
            mode: Mode::CoOptimize,
            capacity: Capacity::micro(),
            seed: 0xA60BA,
            artifacts_dir: PathBuf::from("artifacts"),
            use_pjrt: false,
            makespan_budget: f64::INFINITY,
            cost_budget: f64::INFINITY,
            anneal: AnnealParams::default(),
            parallelism: 1,
            replan: ReplanPolicy::off(),
            admission: Admission::Rounds,
            trace_large: 0,
            market: false,
            deadline_frac: 0.0,
            sla_penalty: 0.0,
            workers: 1,
            queue_bound: 0,
            status_interval_ms: 0,
            verbose: false,
        }
    }
}

impl AppConfig {
    /// Flags understood by the launcher (also used for usage output).
    pub const FLAGS: &'static [(&'static str, &'static str)] = &[
        ("config", "JSON config file"),
        ("goal", "cost | balanced | runtime | deadline-cost | w=<0..1>"),
        ("mode", "agora | predictor-only | scheduler-only | agora-separate"),
        ("seed", "RNG seed (u64)"),
        ("vcpus", "cluster vCPU capacity"),
        ("memory-gb", "cluster memory capacity (GiB)"),
        ("artifacts", "artifact directory (default ./artifacts)"),
        ("pjrt", "run predictions through the AOT/PJRT path"),
        ("makespan-budget", "Eq. 7 budget in seconds"),
        ("cost-budget", "Eq. 8 budget in dollars"),
        ("max-iters", "annealing iteration cap"),
        ("sa-target-accept", "calibrate T0 to this start-acceptance ratio (statistical cooling)"),
        ("sa-equilibrium", "hold SA temperature for equilibrium-length inner loops"),
        ("sa-stall-iters", "SA restart-on-stall patience in iterations (0 = off)"),
        ("sa-reheat", "restart reheat as a fraction of the starting temperature"),
        ("cp-ladder", "run one-shot/polish CP solves as a destructive UB ladder"),
        ("sa-troublesome-seed", "seed one portfolio chain from the DAGPS troublesome-first reseed"),
        ("parallelism", "portfolio annealing chains (1 = deterministic single chain)"),
        ("admission", "rounds | continuous (trace/serve batch admission)"),
        ("workers", "serve: optimization worker threads (1 = deterministic legacy stream)"),
        ("queue-bound", "serve: per-tenant ingress queue bound (0 = unbounded)"),
        ("status-interval", "serve: status ticker period in ms (0 = off)"),
        ("trace-large", "append N ~1000-task large-scale DAGs to the trace workload"),
        ("market", "search the heterogeneous instance market (m5/c5/r5 + spot)"),
        ("deadline-frac", "per-DAG deadline as a multiple of its critical-path bound (0 = off)"),
        ("sla-penalty", "soft-SLA dollars per second past the deadline (0 = hard SLAs)"),
        ("spot-rate", "expected spot interruptions per node-hour (0 = reliable spot)"),
        ("spot-max", "realized preemptions per task before fallback (planner always prices 2)"),
        ("replan-max", "max mid-flight suffix replans per execution (0 = off)"),
        ("replan-threshold", "completion divergence fraction that triggers a replan"),
        ("replan-iters", "annealing iterations per suffix replan"),
        ("replan-seed", "seed for the replan search + divergence injection"),
        ("replan-straggler-prob", "injected per-task straggler probability"),
        ("replan-straggler-factor", "runtime multiplier for straggling tasks"),
        ("replan-fail-prob", "injected per-task failure probability (one retry)"),
        ("replan-outage-at", "capacity outage start in seconds"),
        ("replan-outage-duration", "capacity outage length in seconds (0 = none)"),
        ("replan-outage-cpu", "fraction of cluster vCPUs lost during the outage"),
        ("replan-outage-mem", "fraction of cluster memory lost during the outage"),
        ("replan-troublesome", "order the replan cone troublesome-first (DAGPS subgraph boosts)"),
        ("verbose", "chatty output"),
    ];

    /// Parse a JSON config file's contents over the defaults.
    pub fn from_json(v: &Json) -> Result<AppConfig> {
        let mut c = AppConfig::default();
        if let Some(goal) = v.opt("goal") {
            c.goal = parse_goal(goal.as_str()?)?;
        }
        if let Some(mode) = v.opt("mode") {
            c.mode = parse_mode(mode.as_str()?)?;
        }
        if let Some(x) = v.opt("seed") {
            c.seed = x.as_f64()? as u64;
        }
        if let Some(x) = v.opt("vcpus") {
            c.capacity.vcpus = x.as_f64()?;
        }
        if let Some(x) = v.opt("memory_gb") {
            c.capacity.memory_gb = x.as_f64()?;
        }
        if let Some(x) = v.opt("artifacts") {
            c.artifacts_dir = PathBuf::from(x.as_str()?);
        }
        if let Some(x) = v.opt("pjrt") {
            c.use_pjrt = x.as_bool()?;
        }
        if let Some(x) = v.opt("makespan_budget") {
            c.makespan_budget = x.as_f64()?;
        }
        if let Some(x) = v.opt("cost_budget") {
            c.cost_budget = x.as_f64()?;
        }
        if let Some(x) = v.opt("max_iters") {
            c.anneal.max_iters = x.as_usize()?;
        }
        if let Some(x) = v.opt("sa_target_accept") {
            c.anneal.target_acceptance = Some(x.as_f64()?);
        }
        if let Some(x) = v.opt("sa_equilibrium") {
            c.anneal.equilibrium = x.as_bool()?;
        }
        if let Some(x) = v.opt("sa_stall_iters") {
            c.anneal.stall_iters = x.as_usize()?;
        }
        if let Some(x) = v.opt("sa_reheat") {
            c.anneal.reheat = x.as_f64()?;
        }
        if let Some(x) = v.opt("cp_ladder") {
            c.anneal.cp_ladder = x.as_bool()?;
        }
        if let Some(x) = v.opt("sa_troublesome_seed") {
            c.anneal.troublesome_seed = x.as_bool()?;
        }
        if let Some(x) = v.opt("parallelism") {
            c.parallelism = x.as_usize()?.max(1);
        }
        if let Some(x) = v.opt("admission") {
            c.admission = parse_admission(x.as_str()?)?;
        }
        if let Some(x) = v.opt("trace_large") {
            c.trace_large = x.as_usize()?;
        }
        if let Some(x) = v.opt("market") {
            c.market = x.as_bool()?;
        }
        if let Some(x) = v.opt("deadline_frac") {
            c.deadline_frac = x.as_f64()?;
        }
        if let Some(x) = v.opt("sla_penalty") {
            c.sla_penalty = x.as_f64()?;
        }
        if let Some(x) = v.opt("workers") {
            c.workers = x.as_usize()?.max(1);
        }
        if let Some(x) = v.opt("queue_bound") {
            c.queue_bound = x.as_usize()?;
        }
        if let Some(x) = v.opt("status_interval_ms") {
            c.status_interval_ms = x.as_f64()? as u64;
        }
        if let Some(x) = v.opt("spot_rate") {
            c.replan.divergence.spot_rate = x.as_f64()?;
        }
        if let Some(x) = v.opt("spot_max") {
            c.replan.divergence.spot_max = x.as_usize()? as u32;
        }
        if let Some(x) = v.opt("replan_max") {
            c.replan.max_replans = x.as_usize()?;
        }
        if let Some(x) = v.opt("replan_threshold") {
            c.replan.threshold = x.as_f64()?;
        }
        if let Some(x) = v.opt("replan_iters") {
            c.replan.iters = x.as_usize()?;
        }
        if let Some(x) = v.opt("replan_seed") {
            let seed = x.as_f64()? as u64;
            c.replan.seed = seed;
            c.replan.divergence.seed = seed;
        }
        if let Some(x) = v.opt("replan_straggler_prob") {
            c.replan.divergence.straggler_prob = x.as_f64()?;
        }
        if let Some(x) = v.opt("replan_straggler_factor") {
            c.replan.divergence.straggler_factor = x.as_f64()?;
        }
        if let Some(x) = v.opt("replan_fail_prob") {
            c.replan.divergence.fail_prob = x.as_f64()?;
        }
        if let Some(x) = v.opt("replan_outage_at") {
            outage_mut(&mut c.replan).at = x.as_f64()?;
        }
        if let Some(x) = v.opt("replan_outage_duration") {
            outage_mut(&mut c.replan).duration = x.as_f64()?;
        }
        if let Some(x) = v.opt("replan_outage_cpu") {
            outage_mut(&mut c.replan).cpu_fraction = x.as_f64()?;
        }
        if let Some(x) = v.opt("replan_outage_mem") {
            outage_mut(&mut c.replan).mem_fraction = x.as_f64()?;
        }
        if let Some(x) = v.opt("replan_troublesome") {
            c.replan.troublesome_cone = x.as_bool()?;
        }
        Ok(c)
    }

    /// Load a JSON config file over the defaults.
    pub fn load(path: &Path) -> Result<AppConfig> {
        Self::from_json(&Json::parse_file(path)?)
    }

    /// Apply CLI flags on top of the (file-loaded or default) config.
    pub fn apply_args(mut self, args: &Args) -> Result<AppConfig> {
        if let Some(goal) = args.get("goal") {
            self.goal = parse_goal(goal)?;
        }
        if let Some(mode) = args.get("mode") {
            self.mode = parse_mode(mode)?;
        }
        self.seed = args.u64_or("seed", self.seed)?;
        self.capacity.vcpus = args.f64_or("vcpus", self.capacity.vcpus)?;
        self.capacity.memory_gb = args.f64_or("memory-gb", self.capacity.memory_gb)?;
        if let Some(dir) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(dir);
        }
        self.use_pjrt = args.bool_or("pjrt", self.use_pjrt)?;
        self.makespan_budget = args.f64_or("makespan-budget", self.makespan_budget)?;
        self.cost_budget = args.f64_or("cost-budget", self.cost_budget)?;
        self.anneal.max_iters = args.usize_or("max-iters", self.anneal.max_iters)?;
        if args.has("sa-target-accept") {
            self.anneal.target_acceptance =
                Some(args.f64_or("sa-target-accept", 0.8)?);
        }
        self.anneal.equilibrium = args.bool_or("sa-equilibrium", self.anneal.equilibrium)?;
        self.anneal.stall_iters =
            args.usize_or("sa-stall-iters", self.anneal.stall_iters)?;
        self.anneal.reheat = args.f64_or("sa-reheat", self.anneal.reheat)?;
        self.anneal.cp_ladder = args.bool_or("cp-ladder", self.anneal.cp_ladder)?;
        self.anneal.troublesome_seed =
            args.bool_or("sa-troublesome-seed", self.anneal.troublesome_seed)?;
        self.parallelism = args.usize_or("parallelism", self.parallelism)?.max(1);
        if let Some(s) = args.get("admission") {
            self.admission = parse_admission(s)?;
        }
        self.trace_large = args.usize_or("trace-large", self.trace_large)?;
        self.market = args.bool_or("market", self.market)?;
        self.deadline_frac = args.f64_or("deadline-frac", self.deadline_frac)?;
        self.sla_penalty = args.f64_or("sla-penalty", self.sla_penalty)?;
        self.workers = args.usize_or("workers", self.workers)?.max(1);
        self.queue_bound = args.usize_or("queue-bound", self.queue_bound)?;
        self.status_interval_ms = args.u64_or("status-interval", self.status_interval_ms)?;
        self.replan.divergence.spot_rate =
            args.f64_or("spot-rate", self.replan.divergence.spot_rate)?;
        self.replan.divergence.spot_max =
            args.usize_or("spot-max", self.replan.divergence.spot_max as usize)? as u32;
        self.replan.max_replans = args.usize_or("replan-max", self.replan.max_replans)?;
        self.replan.threshold = args.f64_or("replan-threshold", self.replan.threshold)?;
        self.replan.iters = args.usize_or("replan-iters", self.replan.iters)?;
        if args.has("replan-seed") {
            let seed = args.u64_or("replan-seed", self.replan.seed)?;
            self.replan.seed = seed;
            self.replan.divergence.seed = seed;
        }
        self.replan.divergence.straggler_prob =
            args.f64_or("replan-straggler-prob", self.replan.divergence.straggler_prob)?;
        self.replan.divergence.straggler_factor = args.f64_or(
            "replan-straggler-factor",
            self.replan.divergence.straggler_factor,
        )?;
        self.replan.divergence.fail_prob =
            args.f64_or("replan-fail-prob", self.replan.divergence.fail_prob)?;
        if args.has("replan-outage-at") {
            outage_mut(&mut self.replan).at = args.f64_or("replan-outage-at", 0.0)?;
        }
        if args.has("replan-outage-duration") {
            outage_mut(&mut self.replan).duration =
                args.f64_or("replan-outage-duration", 0.0)?;
        }
        if args.has("replan-outage-cpu") {
            outage_mut(&mut self.replan).cpu_fraction =
                args.f64_or("replan-outage-cpu", 0.0)?;
        }
        if args.has("replan-outage-mem") {
            outage_mut(&mut self.replan).mem_fraction =
                args.f64_or("replan-outage-mem", 0.0)?;
        }
        self.replan.troublesome_cone =
            args.bool_or("replan-troublesome", self.replan.troublesome_cone)?;
        self.verbose = args.bool_or("verbose", self.verbose)?;
        Ok(self)
    }

    /// Resolve: defaults -> optional --config file -> CLI flags.
    pub fn resolve(args: &Args) -> Result<AppConfig> {
        let base = match args.get("config") {
            Some(path) => AppConfig::load(Path::new(path))?,
            None => AppConfig::default(),
        };
        base.apply_args(args)
    }

    /// The candidate configuration space this run searches: the
    /// heterogeneous market under `--market`, else the historical
    /// m5-only space.
    pub fn space(&self) -> ConfigSpace {
        if self.market {
            ConfigSpace::market()
        } else {
            ConfigSpace::standard()
        }
    }

    /// The deadline/SLA policy of this run: off until `--deadline-frac`
    /// arms it; `--sla-penalty > 0` switches from hard (admission
    /// rejects/defers) to soft (misses accounted as `penalty_cost`)
    /// deadlines.
    pub fn sla(&self) -> SlaPolicy {
        SlaPolicy {
            deadline_frac: self.deadline_frac,
            penalty_per_sec: self.sla_penalty,
            hard: self.sla_penalty == 0.0,
            enforce: true,
        }
    }

    /// The pricing model this run plans and accounts with:
    /// [`CostModel::Market`] (per-row catalog prices, spot rows carrying
    /// the `--spot-rate` interruption expectation) under `--market`,
    /// else plain on-demand.
    pub fn cost_model(&self) -> CostModel {
        if self.market {
            CostModel::Market {
                interrupt_rate: self.replan.divergence.spot_rate,
            }
        } else {
            CostModel::OnDemand
        }
    }
}

/// The outage knobs compose onto one optional window: the first
/// `replan-outage-*` key materializes a default-off window (duration 0),
/// later keys refine it.
fn outage_mut(policy: &mut ReplanPolicy) -> &mut CapacityOutage {
    policy.divergence.outage.get_or_insert(CapacityOutage {
        at: 0.0,
        duration: 0.0,
        cpu_fraction: 0.5,
        mem_fraction: 0.5,
    })
}

/// Parse an admission-mode spelling (`rounds` | `continuous`).
pub fn parse_admission(s: &str) -> Result<Admission> {
    Admission::parse(s)
        .ok_or_else(|| anyhow::anyhow!("invalid admission {s:?}; expected rounds | continuous"))
}

/// Parse a goal spelling (`cost` | `balanced` | `runtime` | `w=<0..1>`).
pub fn parse_goal(s: &str) -> Result<Goal> {
    Goal::parse(s).ok_or_else(|| {
        anyhow::anyhow!("invalid goal {s:?}; expected cost | balanced | runtime | w=<0..1>")
    })
}

/// Parse an ablation-mode spelling (see [`AppConfig::FLAGS`]).
pub fn parse_mode(s: &str) -> Result<Mode> {
    match s {
        "agora" => Ok(Mode::CoOptimize),
        "predictor-only" => Ok(Mode::PredictorOnly),
        "scheduler-only" => Ok(Mode::SchedulerOnly),
        "agora-separate" => Ok(Mode::Separate),
        _ => bail!("invalid mode {s:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(argv: &[&str]) -> Args {
        Args::parse(argv.iter().map(|s| s.to_string()), AppConfig::FLAGS).unwrap()
    }

    #[test]
    fn defaults_then_cli_overrides() {
        let c = AppConfig::resolve(&args(&["optimize", "--goal", "cost", "--seed", "9"])).unwrap();
        assert_eq!(c.goal, Goal::Cost);
        assert_eq!(c.seed, 9);
        assert_eq!(c.capacity, Capacity::micro());
    }

    #[test]
    fn json_config_parses() {
        let v = Json::parse(
            r#"{"goal": "runtime", "mode": "agora-separate", "vcpus": 64,
                "memory_gb": 256, "seed": 3, "max_iters": 10}"#,
        )
        .unwrap();
        let c = AppConfig::from_json(&v).unwrap();
        assert_eq!(c.goal, Goal::Runtime);
        assert_eq!(c.mode, Mode::Separate);
        assert_eq!(c.capacity.vcpus, 64.0);
        assert_eq!(c.anneal.max_iters, 10);
    }

    #[test]
    fn cli_overrides_file_values() {
        let v = Json::parse(r#"{"goal": "runtime"}"#).unwrap();
        let base = AppConfig::from_json(&v).unwrap();
        let c = base.apply_args(&args(&["run", "--goal", "cost"])).unwrap();
        assert_eq!(c.goal, Goal::Cost);
    }

    #[test]
    fn invalid_goal_rejected() {
        assert!(AppConfig::resolve(&args(&["run", "--goal", "fastest"])).is_err());
    }

    #[test]
    fn weighted_goal_parses() {
        let c = AppConfig::resolve(&args(&["run", "--goal", "w=0.75"])).unwrap();
        assert_eq!(c.goal, Goal::Weighted(0.75));
    }

    #[test]
    fn replan_flags_parse_from_cli_and_json() {
        // Default: fully off — the executor stays bit-identical.
        assert!(AppConfig::default().replan.is_off());

        let c = AppConfig::resolve(&args(&[
            "execute",
            "--replan-max",
            "2",
            "--replan-threshold",
            "0.3",
            "--replan-iters",
            "50",
            "--replan-seed",
            "99",
            "--replan-straggler-prob",
            "0.25",
            "--replan-straggler-factor",
            "5",
            "--replan-fail-prob",
            "0.1",
        ]))
        .unwrap();
        assert_eq!(c.replan.max_replans, 2);
        assert_eq!(c.replan.threshold, 0.3);
        assert_eq!(c.replan.iters, 50);
        assert_eq!(c.replan.seed, 99);
        assert_eq!(c.replan.divergence.seed, 99);
        assert_eq!(c.replan.divergence.straggler_prob, 0.25);
        assert_eq!(c.replan.divergence.straggler_factor, 5.0);
        assert_eq!(c.replan.divergence.fail_prob, 0.1);
        assert!(c.replan.divergence.outage.is_none());

        let v = Json::parse(
            r#"{"replan_max": 1, "replan_threshold": 0.15,
                "replan_straggler_prob": 0.4,
                "replan_outage_at": 100, "replan_outage_duration": 60,
                "replan_outage_cpu": 0.25}"#,
        )
        .unwrap();
        let c = AppConfig::from_json(&v).unwrap();
        assert_eq!(c.replan.max_replans, 1);
        assert_eq!(c.replan.threshold, 0.15);
        assert_eq!(c.replan.divergence.straggler_prob, 0.4);
        let outage = c.replan.divergence.outage.expect("outage window set");
        assert_eq!(outage.at, 100.0);
        assert_eq!(outage.duration, 60.0);
        assert_eq!(outage.cpu_fraction, 0.25);
    }

    #[test]
    fn cli_replan_flags_override_json_outage() {
        let v = Json::parse(r#"{"replan_outage_duration": 60}"#).unwrap();
        let base = AppConfig::from_json(&v).unwrap();
        let c = base
            .apply_args(&args(&["run", "--replan-outage-duration", "120"]))
            .unwrap();
        assert_eq!(c.replan.divergence.outage.unwrap().duration, 120.0);
    }

    #[test]
    fn trace_large_parses_from_cli_and_json() {
        assert_eq!(AppConfig::default().trace_large, 0);
        let c = AppConfig::resolve(&args(&["trace", "--trace-large", "2"])).unwrap();
        assert_eq!(c.trace_large, 2);
        let v = Json::parse(r#"{"trace_large": 3}"#).unwrap();
        let base = AppConfig::from_json(&v).unwrap();
        assert_eq!(base.trace_large, 3);
        // CLI overrides the file value.
        let c = base.apply_args(&args(&["trace", "--trace-large", "1"])).unwrap();
        assert_eq!(c.trace_large, 1);
    }

    #[test]
    fn admission_parses_from_cli_and_json() {
        // Default: the historical round-barrier mode.
        assert_eq!(AppConfig::default().admission, Admission::Rounds);
        let c = AppConfig::resolve(&args(&["trace", "--admission", "continuous"])).unwrap();
        assert_eq!(c.admission, Admission::Continuous);
        let c = AppConfig::resolve(&args(&["trace", "--admission", "rounds"])).unwrap();
        assert_eq!(c.admission, Admission::Rounds);
        let v = Json::parse(r#"{"admission": "continuous"}"#).unwrap();
        assert_eq!(AppConfig::from_json(&v).unwrap().admission, Admission::Continuous);
        // CLI overrides the file value; unknown spellings are rejected.
        let base = AppConfig::from_json(&v).unwrap();
        let c = base.apply_args(&args(&["trace", "--admission", "rounds"])).unwrap();
        assert_eq!(c.admission, Admission::Rounds);
        assert!(AppConfig::resolve(&args(&["trace", "--admission", "overlap"])).is_err());
    }

    #[test]
    fn market_and_spot_flags_parse_from_cli_and_json() {
        // Defaults: m5-only space, on-demand pricing, reliable spot.
        let c = AppConfig::default();
        assert!(!c.market);
        assert_eq!(c.replan.divergence.spot_rate, 0.0);
        assert_eq!(c.replan.divergence.spot_max, 2);
        assert!(!c.space().has_spot());
        assert!(matches!(c.cost_model(), CostModel::OnDemand));

        let c = AppConfig::resolve(&args(&[
            "optimize",
            "--market",
            "--spot-rate",
            "1.5",
            "--spot-max",
            "3",
        ]))
        .unwrap();
        assert!(c.market);
        assert_eq!(c.replan.divergence.spot_rate, 1.5);
        assert_eq!(c.replan.divergence.spot_max, 3);
        assert!(c.space().has_spot());
        match c.cost_model() {
            CostModel::Market { interrupt_rate } => assert_eq!(interrupt_rate, 1.5),
            other => panic!("expected Market cost model, got {other:?}"),
        }

        // JSON path + CLI override.
        let v = Json::parse(r#"{"market": true, "spot_rate": 0.5}"#).unwrap();
        let base = AppConfig::from_json(&v).unwrap();
        assert!(base.market);
        assert_eq!(base.replan.divergence.spot_rate, 0.5);
        let c = base.apply_args(&args(&["trace", "--spot-rate", "2.0"])).unwrap();
        assert_eq!(c.replan.divergence.spot_rate, 2.0);
        assert!(c.market);
    }

    #[test]
    fn serve_control_plane_flags_parse_from_cli_and_json() {
        // Defaults: one worker, unbounded queues, ticker off.
        let c = AppConfig::default();
        assert_eq!(c.workers, 1);
        assert_eq!(c.queue_bound, 0);
        assert_eq!(c.status_interval_ms, 0);

        let c = AppConfig::resolve(&args(&[
            "serve",
            "--workers",
            "4",
            "--queue-bound",
            "16",
            "--status-interval",
            "500",
        ]))
        .unwrap();
        assert_eq!(c.workers, 4);
        assert_eq!(c.queue_bound, 16);
        assert_eq!(c.status_interval_ms, 500);
        // 0 workers clamps to the deterministic single worker.
        let c = AppConfig::resolve(&args(&["serve", "--workers", "0"])).unwrap();
        assert_eq!(c.workers, 1);

        // JSON path + CLI override.
        let v = Json::parse(
            r#"{"workers": 2, "queue_bound": 8, "status_interval_ms": 250}"#,
        )
        .unwrap();
        let base = AppConfig::from_json(&v).unwrap();
        assert_eq!(base.workers, 2);
        assert_eq!(base.queue_bound, 8);
        assert_eq!(base.status_interval_ms, 250);
        let c = base.apply_args(&args(&["serve", "--queue-bound", "4"])).unwrap();
        assert_eq!(c.queue_bound, 4);
        assert_eq!(c.workers, 2);
    }

    #[test]
    fn deadline_flags_parse_from_cli_and_json() {
        // Default: SLAs fully off.
        let c = AppConfig::default();
        assert_eq!(c.deadline_frac, 0.0);
        assert_eq!(c.sla_penalty, 0.0);
        assert!(c.sla().is_off());

        // deadline-frac alone arms hard SLAs.
        let c = AppConfig::resolve(&args(&["trace", "--deadline-frac", "1.5"])).unwrap();
        assert_eq!(c.deadline_frac, 1.5);
        let sla = c.sla();
        assert!(!sla.is_off());
        assert!(sla.hard && sla.enforce);

        // A penalty rate switches to soft SLAs.
        let c = AppConfig::resolve(&args(&[
            "trace",
            "--deadline-frac",
            "2.0",
            "--sla-penalty",
            "0.01",
        ]))
        .unwrap();
        let sla = c.sla();
        assert!(!sla.hard);
        assert_eq!(sla.penalty_per_sec, 0.01);

        // JSON path + CLI override; deadline-cost goal spelling parses.
        let v = Json::parse(r#"{"deadline_frac": 1.2, "sla_penalty": 0.5,
                                "goal": "deadline-cost"}"#)
            .unwrap();
        let base = AppConfig::from_json(&v).unwrap();
        assert_eq!(base.deadline_frac, 1.2);
        assert_eq!(base.sla_penalty, 0.5);
        assert_eq!(base.goal, Goal::DeadlineCost);
        let c = base
            .apply_args(&args(&["trace", "--deadline-frac", "3.0"]))
            .unwrap();
        assert_eq!(c.deadline_frac, 3.0);
        assert_eq!(c.sla_penalty, 0.5);
    }

    #[test]
    fn adaptive_search_flags_parse_from_cli_and_json() {
        // Defaults: every adaptive-search knob off — the legacy engine.
        let c = AppConfig::default();
        assert_eq!(c.anneal.target_acceptance, None);
        assert!(!c.anneal.equilibrium);
        assert_eq!(c.anneal.stall_iters, 0);
        assert_eq!(c.anneal.reheat, 0.5);
        assert!(!c.anneal.cp_ladder);

        let c = AppConfig::resolve(&args(&[
            "optimize",
            "--sa-target-accept",
            "0.7",
            "--sa-equilibrium",
            "--sa-stall-iters",
            "120",
            "--sa-reheat",
            "0.25",
            "--cp-ladder",
        ]))
        .unwrap();
        assert_eq!(c.anneal.target_acceptance, Some(0.7));
        assert!(c.anneal.equilibrium);
        assert_eq!(c.anneal.stall_iters, 120);
        assert_eq!(c.anneal.reheat, 0.25);
        assert!(c.anneal.cp_ladder);

        // JSON path + CLI override.
        let v = Json::parse(
            r#"{"sa_target_accept": 0.9, "sa_equilibrium": true,
                "sa_stall_iters": 64, "sa_reheat": 0.75, "cp_ladder": true}"#,
        )
        .unwrap();
        let base = AppConfig::from_json(&v).unwrap();
        assert_eq!(base.anneal.target_acceptance, Some(0.9));
        assert!(base.anneal.equilibrium);
        assert_eq!(base.anneal.stall_iters, 64);
        assert_eq!(base.anneal.reheat, 0.75);
        assert!(base.anneal.cp_ladder);
        let c = base
            .apply_args(&args(&["optimize", "--sa-stall-iters", "32"]))
            .unwrap();
        assert_eq!(c.anneal.stall_iters, 32);
        assert_eq!(c.anneal.target_acceptance, Some(0.9));
    }

    #[test]
    fn troublesome_flags_parse_from_cli_and_json() {
        // Defaults: both topology-aware knobs off — historical behaviour.
        let c = AppConfig::default();
        assert!(!c.anneal.troublesome_seed);
        assert!(!c.replan.troublesome_cone);

        let c = AppConfig::resolve(&args(&[
            "optimize",
            "--sa-troublesome-seed",
            "--replan-troublesome",
        ]))
        .unwrap();
        assert!(c.anneal.troublesome_seed);
        assert!(c.replan.troublesome_cone);

        // JSON path + CLI leaves the file's setting alone when absent.
        let v = Json::parse(r#"{"sa_troublesome_seed": true, "replan_troublesome": true}"#)
            .unwrap();
        let base = AppConfig::from_json(&v).unwrap();
        assert!(base.anneal.troublesome_seed);
        assert!(base.replan.troublesome_cone);
        let c = base.apply_args(&args(&["optimize"])).unwrap();
        assert!(c.anneal.troublesome_seed);
        assert!(c.replan.troublesome_cone);
    }

    #[test]
    fn parallelism_parses_and_clamps() {
        let c = AppConfig::resolve(&args(&["run", "--parallelism", "4"])).unwrap();
        assert_eq!(c.parallelism, 4);
        // 0 is clamped to the deterministic single chain.
        let c = AppConfig::resolve(&args(&["run", "--parallelism", "0"])).unwrap();
        assert_eq!(c.parallelism, 1);
        // JSON path.
        let v = Json::parse(r#"{"parallelism": 8}"#).unwrap();
        assert_eq!(AppConfig::from_json(&v).unwrap().parallelism, 8);
        // default
        assert_eq!(AppConfig::default().parallelism, 1);
    }
}
