//! AWS instance catalog — paper Table 1 (prices valid 2022-01-27).

/// One purchasable VM instance type.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceType {
    /// AWS instance-type name, e.g. `m5.4xlarge`.
    pub name: &'static str,
    /// vCPUs per node.
    pub vcpus: u32,
    /// Memory per node in GiB.
    pub memory_gb: u32,
    /// On-demand price in $ per hour.
    pub hourly_cost: f64,
    /// Relative per-vCPU throughput vs the m5 baseline (1.0 for the m5
    /// family; extension point for other families / spot degradation).
    pub speed_factor: f64,
}

impl InstanceType {
    /// $ per vCPU-hour — constant within the m5 family, which is exactly
    /// why the co-optimization is about *granularity* (fewer, larger nodes
    /// trade contention against packing flexibility), not raw unit price.
    pub fn cost_per_vcpu_hour(&self) -> f64 {
        self.hourly_cost / self.vcpus as f64
    }

    /// GiB of memory per vCPU (4.0 across the m5 family).
    pub fn memory_per_vcpu(&self) -> f64 {
        self.memory_gb as f64 / self.vcpus as f64
    }
}

/// Table 1 of the paper.
pub const M5_CATALOG: &[InstanceType] = &[
    InstanceType {
        name: "m5.4xlarge",
        vcpus: 16,
        memory_gb: 64,
        hourly_cost: 0.768,
        speed_factor: 1.0,
    },
    InstanceType {
        name: "m5.8xlarge",
        vcpus: 32,
        memory_gb: 128,
        hourly_cost: 1.536,
        speed_factor: 1.0,
    },
    InstanceType {
        name: "m5.12xlarge",
        vcpus: 48,
        memory_gb: 192,
        hourly_cost: 2.304,
        speed_factor: 1.0,
    },
    InstanceType {
        name: "m5.16xlarge",
        vcpus: 64,
        memory_gb: 256,
        hourly_cost: 3.072,
        speed_factor: 1.0,
    },
];

/// Look up an instance type by name.
pub fn by_name(name: &str) -> Option<&'static InstanceType> {
    M5_CATALOG.iter().find(|it| it.name == name)
}

/// Render Table 1 (used as the header of every bench report).
pub fn table1() -> String {
    let mut s = String::from(
        "Table 1. Selected instance types from AWS (prices of 2022-01-27)\n\
         Instance       vCPUs  Memory  Cost ($/h)\n",
    );
    for it in M5_CATALOG {
        s.push_str(&format!(
            "{:<14} {:>5}  {:>6}  {:>9.3}\n",
            it.name, it.vcpus, it.memory_gb, it.hourly_cost
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_table1() {
        assert_eq!(M5_CATALOG.len(), 4);
        let m54 = by_name("m5.4xlarge").unwrap();
        assert_eq!(m54.vcpus, 16);
        assert_eq!(m54.memory_gb, 64);
        assert!((m54.hourly_cost - 0.768).abs() < 1e-12);
        let m516 = by_name("m5.16xlarge").unwrap();
        assert_eq!(m516.vcpus, 64);
        assert!((m516.hourly_cost - 3.072).abs() < 1e-12);
    }

    #[test]
    fn m5_family_has_uniform_unit_price() {
        let base = M5_CATALOG[0].cost_per_vcpu_hour();
        for it in M5_CATALOG {
            assert!((it.cost_per_vcpu_hour() - base).abs() < 1e-9, "{}", it.name);
            assert!((it.memory_per_vcpu() - 4.0).abs() < 1e-9, "{}", it.name);
        }
    }

    #[test]
    fn unknown_instance_is_none() {
        assert!(by_name("p4d.24xlarge").is_none());
    }

    #[test]
    fn table1_renders_all_rows() {
        let t = table1();
        for it in M5_CATALOG {
            assert!(t.contains(it.name));
        }
    }
}
