//! AWS instance catalog — paper Table 1 (prices valid 2022-01-27), plus
//! the heterogeneous **market extension**: compute-optimized (c5) and
//! memory-optimized (r5) families and spot-market variants of each, the
//! paper's §2 "heterogeneous cloud" axis the m5-only seed never explored.
//!
//! Index contract: [`FULL_CATALOG`] begins with the four [`M5_CATALOG`]
//! rows **in the same order**, so `Config { instance: 0..4, .. }` means
//! the same machine in both the historical m5-only space and the market
//! space — every pinned test and seeded search over the m5 space is
//! bit-identical to the pre-market code.

/// Instance family — the heterogeneity axis of the market extension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    /// General purpose (4 GiB/vCPU): the paper's Table 1 baseline.
    M5,
    /// Compute optimized (2 GiB/vCPU, faster cores, cheaper per vCPU).
    C5,
    /// Memory optimized (8 GiB/vCPU, slightly slower cores, pricier).
    R5,
}

impl Family {
    /// Number of families in the catalog (sizes the per-family
    /// multiplier array of the learned predictor).
    pub const COUNT: usize = 3;

    /// Dense index in `0..Family::COUNT` (m5 first — the baseline).
    pub fn index(self) -> usize {
        match self {
            Family::M5 => 0,
            Family::C5 => 1,
            Family::R5 => 2,
        }
    }

    /// Stable lowercase name (`m5` | `c5` | `r5`).
    pub fn name(self) -> &'static str {
        match self {
            Family::M5 => "m5",
            Family::C5 => "c5",
            Family::R5 => "r5",
        }
    }
}

/// Purchasing option of a catalog row.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Purchase {
    /// Pay the listed price, keep the capacity until released.
    OnDemand,
    /// Deep market discount; capacity can be preempted at any time
    /// (realized as `DivergenceSpec` spot interruptions by the executor,
    /// priced as expected re-run overhead by `CostModel`).
    Spot,
}

impl Purchase {
    /// Stable lowercase name (`on-demand` | `spot`).
    pub fn name(self) -> &'static str {
        match self {
            Purchase::OnDemand => "on-demand",
            Purchase::Spot => "spot",
        }
    }
}

/// One purchasable VM instance type.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceType {
    /// AWS instance-type name, e.g. `m5.4xlarge`; spot-market rows carry
    /// a `:spot` suffix (`m5.4xlarge:spot`).
    pub name: &'static str,
    /// vCPUs per node.
    pub vcpus: u32,
    /// Memory per node in GiB.
    pub memory_gb: u32,
    /// Price in $ per hour (the spot-market price for spot rows).
    pub hourly_cost: f64,
    /// Relative per-vCPU throughput vs the m5 baseline (1.0 for the m5
    /// family; c5 cores are faster, r5 cores slightly slower).
    pub speed_factor: f64,
    /// Instance family of this row.
    pub family: Family,
    /// Purchasing option of this row.
    pub purchase: Purchase,
}

impl InstanceType {
    /// $ per vCPU-hour — constant within one (family, purchase) group,
    /// which is exactly why intra-family co-optimization is about
    /// *granularity*; across families and purchase options the unit
    /// price itself becomes a decision variable.
    pub fn cost_per_vcpu_hour(&self) -> f64 {
        self.hourly_cost / self.vcpus as f64
    }

    /// GiB of memory per vCPU (4.0 m5, 2.0 c5, 8.0 r5).
    pub fn memory_per_vcpu(&self) -> f64 {
        self.memory_gb as f64 / self.vcpus as f64
    }

    /// Whether this row buys preemptible spot capacity.
    pub fn is_spot(&self) -> bool {
        self.purchase == Purchase::Spot
    }
}

// Row constants compose into both catalogs without duplicating values.
const M5_4XL: InstanceType = InstanceType {
    name: "m5.4xlarge",
    vcpus: 16,
    memory_gb: 64,
    hourly_cost: 0.768,
    speed_factor: 1.0,
    family: Family::M5,
    purchase: Purchase::OnDemand,
};
const M5_8XL: InstanceType = InstanceType {
    name: "m5.8xlarge",
    vcpus: 32,
    memory_gb: 128,
    hourly_cost: 1.536,
    speed_factor: 1.0,
    family: Family::M5,
    purchase: Purchase::OnDemand,
};
const M5_12XL: InstanceType = InstanceType {
    name: "m5.12xlarge",
    vcpus: 48,
    memory_gb: 192,
    hourly_cost: 2.304,
    speed_factor: 1.0,
    family: Family::M5,
    purchase: Purchase::OnDemand,
};
const M5_16XL: InstanceType = InstanceType {
    name: "m5.16xlarge",
    vcpus: 64,
    memory_gb: 256,
    hourly_cost: 3.072,
    speed_factor: 1.0,
    family: Family::M5,
    purchase: Purchase::OnDemand,
};

const C5_4XL: InstanceType = InstanceType {
    name: "c5.4xlarge",
    vcpus: 16,
    memory_gb: 32,
    hourly_cost: 0.680,
    speed_factor: 1.18,
    family: Family::C5,
    purchase: Purchase::OnDemand,
};
const C5_9XL: InstanceType = InstanceType {
    name: "c5.9xlarge",
    vcpus: 36,
    memory_gb: 72,
    hourly_cost: 1.530,
    speed_factor: 1.18,
    family: Family::C5,
    purchase: Purchase::OnDemand,
};
const C5_12XL: InstanceType = InstanceType {
    name: "c5.12xlarge",
    vcpus: 48,
    memory_gb: 96,
    hourly_cost: 2.040,
    speed_factor: 1.18,
    family: Family::C5,
    purchase: Purchase::OnDemand,
};
const C5_18XL: InstanceType = InstanceType {
    name: "c5.18xlarge",
    vcpus: 72,
    memory_gb: 144,
    hourly_cost: 3.060,
    speed_factor: 1.18,
    family: Family::C5,
    purchase: Purchase::OnDemand,
};

const R5_4XL: InstanceType = InstanceType {
    name: "r5.4xlarge",
    vcpus: 16,
    memory_gb: 128,
    hourly_cost: 1.008,
    speed_factor: 0.95,
    family: Family::R5,
    purchase: Purchase::OnDemand,
};
const R5_8XL: InstanceType = InstanceType {
    name: "r5.8xlarge",
    vcpus: 32,
    memory_gb: 256,
    hourly_cost: 2.016,
    speed_factor: 0.95,
    family: Family::R5,
    purchase: Purchase::OnDemand,
};
const R5_12XL: InstanceType = InstanceType {
    name: "r5.12xlarge",
    vcpus: 48,
    memory_gb: 384,
    hourly_cost: 3.024,
    speed_factor: 0.95,
    family: Family::R5,
    purchase: Purchase::OnDemand,
};
const R5_16XL: InstanceType = InstanceType {
    name: "r5.16xlarge",
    vcpus: 64,
    memory_gb: 512,
    hourly_cost: 4.032,
    speed_factor: 0.95,
    family: Family::R5,
    purchase: Purchase::OnDemand,
};

// Spot rows: small and large size of each family. Discounts follow
// 2022-era market depth — m5 65% off, c5 60% off (popular, hot market),
// r5 75% off (cold market). Same silicon, so speed factors match the
// on-demand rows; the price is what you trade for preemption risk.
const M5_4XL_SPOT: InstanceType = InstanceType {
    name: "m5.4xlarge:spot",
    vcpus: 16,
    memory_gb: 64,
    hourly_cost: 0.2688,
    speed_factor: 1.0,
    family: Family::M5,
    purchase: Purchase::Spot,
};
const M5_16XL_SPOT: InstanceType = InstanceType {
    name: "m5.16xlarge:spot",
    vcpus: 64,
    memory_gb: 256,
    hourly_cost: 1.0752,
    speed_factor: 1.0,
    family: Family::M5,
    purchase: Purchase::Spot,
};
const C5_4XL_SPOT: InstanceType = InstanceType {
    name: "c5.4xlarge:spot",
    vcpus: 16,
    memory_gb: 32,
    hourly_cost: 0.272,
    speed_factor: 1.18,
    family: Family::C5,
    purchase: Purchase::Spot,
};
const C5_18XL_SPOT: InstanceType = InstanceType {
    name: "c5.18xlarge:spot",
    vcpus: 72,
    memory_gb: 144,
    hourly_cost: 1.224,
    speed_factor: 1.18,
    family: Family::C5,
    purchase: Purchase::Spot,
};
const R5_4XL_SPOT: InstanceType = InstanceType {
    name: "r5.4xlarge:spot",
    vcpus: 16,
    memory_gb: 128,
    hourly_cost: 0.252,
    speed_factor: 0.95,
    family: Family::R5,
    purchase: Purchase::Spot,
};
const R5_16XL_SPOT: InstanceType = InstanceType {
    name: "r5.16xlarge:spot",
    vcpus: 64,
    memory_gb: 512,
    hourly_cost: 1.008,
    speed_factor: 0.95,
    family: Family::R5,
    purchase: Purchase::Spot,
};

/// Table 1 of the paper: the m5 family, the historical (and default)
/// search space.
pub const M5_CATALOG: &[InstanceType] = &[M5_4XL, M5_8XL, M5_12XL, M5_16XL];

/// The full heterogeneous instance market: m5 (rows 0-3, identical to
/// [`M5_CATALOG`]), c5, r5, then the spot variants. `Config.instance`
/// always indexes this catalog.
pub const FULL_CATALOG: &[InstanceType] = &[
    M5_4XL,
    M5_8XL,
    M5_12XL,
    M5_16XL,
    C5_4XL,
    C5_9XL,
    C5_12XL,
    C5_18XL,
    R5_4XL,
    R5_8XL,
    R5_12XL,
    R5_16XL,
    M5_4XL_SPOT,
    M5_16XL_SPOT,
    C5_4XL_SPOT,
    C5_18XL_SPOT,
    R5_4XL_SPOT,
    R5_16XL_SPOT,
];

/// Look up an instance type by name (full market, spot rows included).
pub fn by_name(name: &str) -> Option<&'static InstanceType> {
    FULL_CATALOG.iter().find(|it| it.name == name)
}

/// Catalog index of an instance type by name.
pub fn index_by_name(name: &str) -> Option<usize> {
    FULL_CATALOG.iter().position(|it| it.name == name)
}

/// The counterpart row with the other purchasing option (same family and
/// shape): `m5.4xlarge` <-> `m5.4xlarge:spot`. `None` when no
/// counterpart is listed (only the smallest and largest size of each
/// family trade on the spot market).
///
/// Implemented as a fixed index table (this sits on the SA proposal
/// path); `catalog::tests::purchase_toggle_table_matches_names` pins the
/// table against the name-derived relation.
pub fn purchase_toggle(instance: usize) -> Option<usize> {
    const PAIRS: &[(usize, usize)] = &[(0, 12), (3, 13), (4, 14), (7, 15), (8, 16), (11, 17)];
    PAIRS.iter().find_map(|&(od, spot)| {
        if od == instance {
            Some(spot)
        } else if spot == instance {
            Some(od)
        } else {
            None
        }
    })
}

/// Render Table 1 (used as the header of every bench report).
pub fn table1() -> String {
    let mut s = String::from(
        "Table 1. Selected instance types from AWS (prices of 2022-01-27)\n\
         Instance       vCPUs  Memory  Cost ($/h)\n",
    );
    for it in M5_CATALOG {
        s.push_str(&format!(
            "{:<14} {:>5}  {:>6}  {:>9.3}\n",
            it.name, it.vcpus, it.memory_gb, it.hourly_cost
        ));
    }
    s
}

/// Render the full heterogeneous market (family, purchase, speed).
pub fn market_table() -> String {
    let mut s = String::from(
        "Instance market (m5/c5/r5 x on-demand/spot)\n\
         Instance           Fam  Purchase   vCPUs  Memory  Cost ($/h)  Speed\n",
    );
    for it in FULL_CATALOG {
        s.push_str(&format!(
            "{:<18} {:<4} {:<9} {:>6}  {:>6}  {:>10.4}  {:>5.2}\n",
            it.name,
            it.family.name(),
            it.purchase.name(),
            it.vcpus,
            it.memory_gb,
            it.hourly_cost,
            it.speed_factor
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_table1() {
        assert_eq!(M5_CATALOG.len(), 4);
        let m54 = by_name("m5.4xlarge").unwrap();
        assert_eq!(m54.vcpus, 16);
        assert_eq!(m54.memory_gb, 64);
        assert!((m54.hourly_cost - 0.768).abs() < 1e-12);
        let m516 = by_name("m5.16xlarge").unwrap();
        assert_eq!(m516.vcpus, 64);
        assert!((m516.hourly_cost - 3.072).abs() < 1e-12);
    }

    #[test]
    fn m5_family_has_uniform_unit_price() {
        let base = M5_CATALOG[0].cost_per_vcpu_hour();
        for it in M5_CATALOG {
            assert!((it.cost_per_vcpu_hour() - base).abs() < 1e-9, "{}", it.name);
            assert!((it.memory_per_vcpu() - 4.0).abs() < 1e-9, "{}", it.name);
        }
    }

    #[test]
    fn unknown_instance_is_none() {
        assert!(by_name("p4d.24xlarge").is_none());
    }

    #[test]
    fn table1_renders_all_rows() {
        let t = table1();
        for it in M5_CATALOG {
            assert!(t.contains(it.name));
        }
    }

    #[test]
    fn full_catalog_prefix_is_the_m5_catalog() {
        // The index contract every Config literal in the repo relies on.
        assert!(FULL_CATALOG.len() > M5_CATALOG.len());
        for (i, it) in M5_CATALOG.iter().enumerate() {
            assert_eq!(&FULL_CATALOG[i], it, "row {i} drifted");
        }
    }

    #[test]
    fn families_have_uniform_unit_price_per_purchase() {
        use std::collections::HashMap;
        let mut groups: HashMap<(usize, bool), Vec<f64>> = HashMap::new();
        for it in FULL_CATALOG {
            groups
                .entry((it.family.index(), it.is_spot()))
                .or_default()
                .push(it.cost_per_vcpu_hour());
        }
        for (key, prices) in groups {
            for p in &prices {
                assert!((p - prices[0]).abs() < 1e-9, "group {key:?} not uniform");
            }
        }
    }

    #[test]
    fn spot_rows_are_discounted_same_shape() {
        let mut spot_rows = 0;
        for (i, it) in FULL_CATALOG.iter().enumerate() {
            if !it.is_spot() {
                continue;
            }
            spot_rows += 1;
            let od_idx = purchase_toggle(i).expect("every spot row has an on-demand twin");
            let od = &FULL_CATALOG[od_idx];
            assert!(!od.is_spot());
            assert_eq!(od.vcpus, it.vcpus, "{}", it.name);
            assert_eq!(od.memory_gb, it.memory_gb, "{}", it.name);
            assert_eq!(od.family, it.family, "{}", it.name);
            assert_eq!(od.speed_factor, it.speed_factor, "{}", it.name);
            assert!(it.hourly_cost < od.hourly_cost, "{} not discounted", it.name);
            // Toggle round-trips.
            assert_eq!(purchase_toggle(od_idx), Some(i));
        }
        assert_eq!(spot_rows, 6);
    }

    #[test]
    fn family_memory_ratios() {
        for it in FULL_CATALOG {
            let want = match it.family {
                Family::M5 => 4.0,
                Family::C5 => 2.0,
                Family::R5 => 8.0,
            };
            assert!((it.memory_per_vcpu() - want).abs() < 1e-9, "{}", it.name);
        }
    }

    #[test]
    fn toggle_is_none_for_mid_sizes() {
        let m58 = index_by_name("m5.8xlarge").unwrap();
        assert_eq!(purchase_toggle(m58), None);
        assert_eq!(purchase_toggle(9999), None);
    }

    #[test]
    fn purchase_toggle_table_matches_names() {
        // The index table is the fast path; the `:spot` name suffix is
        // the ground truth it must agree with, row by row.
        for (i, it) in FULL_CATALOG.iter().enumerate() {
            let by_names = match it.purchase {
                Purchase::OnDemand => index_by_name(&format!("{}:spot", it.name)),
                Purchase::Spot => it.name.strip_suffix(":spot").and_then(index_by_name),
            };
            assert_eq!(purchase_toggle(i), by_names, "row {i} ({})", it.name);
        }
    }

    #[test]
    fn market_table_renders_all_rows() {
        let t = market_table();
        for it in FULL_CATALOG {
            assert!(t.contains(it.name), "{} missing", it.name);
        }
    }
}
