//! Cost model — Eq. 6 of the paper, with the spot-pricing extension the
//! paper sketches ("AGORA can be easily modified to include these details
//! by defining the C_m variable more accurately").
//!
//! ## Spot interruption closed form
//!
//! Spot capacity is preempted by a Poisson process of `interrupt_rate`
//! arrivals per node-hour; each preemption loses the in-flight work
//! (uniformly distributed over the run, so half a run in expectation),
//! and after **two** preemptions the coordinator falls back to stable
//! capacity, capping the loss. With `N ~ Poisson(lambda)` arrivals over
//! the task (`lambda = rate x nodes x secs / 3600`) the expected re-run
//! overhead multiplier is
//!
//! ```text
//! overhead(lambda) = 1 + 0.5 * E[min(N, 2)]
//!                  = 1 + 0.5 * (2 - e^-lambda * (2 + lambda))
//! ```
//!
//! The historical closed form used `min(E[N], 2)` instead of
//! `E[min(N, 2)]` — an over-estimate near and past the cap (Jensen): at
//! `lambda = 3` it charges 2.0 interruptions where the realized process
//! only averages 1.75. The Monte-Carlo differential test in
//! `rust/tests/market.rs` pins this form against the executor's realized
//! spot costs; the executor's [`DivergenceSpec`](crate::sim::DivergenceSpec)
//! realizes exactly this process.

use super::config::Config;

/// The canonical preemption cap the market prices: after this many spot
/// preemptions the platform falls back to stable capacity, bounding the
/// lost work. The cost model's closed form is always evaluated at this
/// cap; [`DivergenceSpec::spot_max`](crate::sim::DivergenceSpec) defaults
/// to it, and setting that executor-side knob to a different value
/// deliberately stresses planner-model error (realized costs then
/// diverge from the priced expectation — by design, not by accident).
pub const SPOT_PREEMPTION_CAP: u32 = 2;

/// `E[min(N, 2)]` for `N ~ Poisson(lambda)`: the expected number of
/// *charged* spot preemptions under the [`SPOT_PREEMPTION_CAP`]
/// fallback. `2 - e^-lambda (2 + lambda)`; ~`lambda` for small `lambda`,
/// saturating at 2.
pub fn expected_capped_interruptions(lambda: f64) -> f64 {
    if lambda <= 0.0 {
        return 0.0;
    }
    2.0 - (-lambda).exp() * (2.0 + lambda)
}

/// Expected spot re-run overhead multiplier on runtime (and therefore
/// cost): `1 + 0.5 * E[min(N, 2)]`, in `[1, 2]`.
pub fn expected_spot_overhead(lambda: f64) -> f64 {
    1.0 + 0.5 * expected_capped_interruptions(lambda)
}

/// Poisson intensity of spot preemptions for a configuration held for
/// `secs` seconds: `rate x nodes x secs / 3600` (any node of the gang
/// being reclaimed preempts the task).
pub fn spot_lambda(config: &Config, secs: f64, rate_per_node_hour: f64) -> f64 {
    rate_per_node_hour * config.nodes as f64 * secs / 3600.0
}

/// Pricing policy for a task occupying a configuration for a duration.
#[derive(Debug, Clone)]
pub enum CostModel {
    /// On-demand: cost = nodes x hourly price x hours (Eq. 6 with the
    /// paper's simplification that storage etc. is configuration-invariant).
    OnDemand,
    /// Global spot ablation: *every* configuration priced at the
    /// on-demand price scaled by a market discount, plus the expected
    /// interruption overhead (see the module docs). `discount` in
    /// (0, 1], `interrupt_rate` in expected interruptions per node-hour.
    Spot {
        /// Spot price as a fraction of the on-demand price.
        discount: f64,
        /// Expected interruptions per node-hour.
        interrupt_rate: f64,
    },
    /// Per-second billing with a minimum billable duration (e.g. EMR-style
    /// 60 s minimum) — exposes scheduling decisions to billing granularity.
    PerSecond {
        /// Minimum billable seconds per task.
        min_billable_secs: f64,
    },
    /// The heterogeneous market: each configuration is priced at its own
    /// catalog row (spot rows carry the market discount already).
    /// Durations handed to [`CostModel::cost`] are expected to include
    /// the spot interruption overhead — [`Problem::new`](crate::solver::Problem)
    /// inflates the prediction grid of spot configurations by
    /// [`expected_spot_overhead`] under this model, so Eq. 1 sees both
    /// the price advantage and the preemption risk of spot capacity.
    Market {
        /// Expected spot interruptions per node-hour (0 = reliable spot).
        interrupt_rate: f64,
    },
}

impl CostModel {
    /// Dollar cost of *planning to hold* `config` for `secs` seconds —
    /// the Eq. 6 term the optimizer minimizes, expected interruption
    /// overhead included.
    pub fn cost(&self, config: &Config, secs: f64) -> f64 {
        let hourly = config.hourly_cost();
        match self {
            CostModel::OnDemand => hourly * secs / 3600.0,
            CostModel::Spot {
                discount,
                interrupt_rate,
            } => {
                let overhead =
                    expected_spot_overhead(spot_lambda(config, secs, *interrupt_rate));
                hourly * discount * (secs * overhead) / 3600.0
            }
            CostModel::PerSecond { min_billable_secs } => {
                hourly * secs.max(*min_billable_secs) / 3600.0
            }
            // Spot rows are already discounted in the catalog, and the
            // planner's durations already carry the expected overhead.
            CostModel::Market { .. } => hourly * secs / 3600.0,
        }
    }

    /// Dollar cost of having *actually occupied* `config` for `secs`
    /// realized seconds. Unlike [`CostModel::cost`] no expected
    /// interruption overhead is added: realized durations already
    /// include any re-run work, so the executor pays for exactly the
    /// capacity it held. Identical to `cost` for every model except
    /// `Spot`, whose expectation term would double-charge re-runs.
    pub fn realized_cost(&self, config: &Config, secs: f64) -> f64 {
        match self {
            CostModel::Spot { discount, .. } => {
                config.hourly_cost() * discount * secs / 3600.0
            }
            _ => self.cost(config, secs),
        }
    }

    /// Cost of an entire assignment: sum over (config, duration) pairs —
    /// Eq. 6's sum over tasks (cost is schedule-independent, which is why
    /// the inner CP solver only optimizes makespan; see solver/anneal.rs).
    pub fn total(&self, items: impl IntoIterator<Item = (Config, f64)>) -> f64 {
        items
            .into_iter()
            .map(|(cfg, secs)| self.cost(&cfg, secs))
            .sum()
    }
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::OnDemand
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(nodes: u32) -> Config {
        Config {
            instance: 0,
            nodes,
            spark: 1,
        }
    }

    #[test]
    fn on_demand_eq6() {
        // 10 x m5.4xlarge for 30 minutes = 10 * 0.768 * 0.5
        let c = CostModel::OnDemand.cost(&cfg(10), 1800.0);
        assert!((c - 3.84).abs() < 1e-9);
    }

    #[test]
    fn spot_is_cheaper_for_short_tasks() {
        let od = CostModel::OnDemand.cost(&cfg(4), 600.0);
        let spot = CostModel::Spot {
            discount: 0.3,
            interrupt_rate: 0.05,
        }
        .cost(&cfg(4), 600.0);
        assert!(spot < od);
    }

    #[test]
    fn spot_overhead_grows_with_duration() {
        let m = CostModel::Spot {
            discount: 0.3,
            interrupt_rate: 0.5,
        };
        let short = m.cost(&cfg(1), 600.0) / 600.0;
        let long = m.cost(&cfg(1), 36_000.0) / 36_000.0;
        assert!(long > short, "unit cost should grow with duration");
    }

    #[test]
    fn per_second_minimum_applies() {
        let m = CostModel::PerSecond {
            min_billable_secs: 60.0,
        };
        assert_eq!(m.cost(&cfg(1), 10.0), m.cost(&cfg(1), 60.0));
        assert!(m.cost(&cfg(1), 120.0) > m.cost(&cfg(1), 60.0));
    }

    #[test]
    fn total_sums_tasks() {
        let m = CostModel::OnDemand;
        let total = m.total(vec![(cfg(1), 3600.0), (cfg(2), 1800.0)]);
        assert!((total - (0.768 + 0.768)).abs() < 1e-9);
    }

    #[test]
    fn capped_interruption_expectation_shape() {
        // E[min(N,2)] for Poisson: 0 at 0, ~lambda for small lambda,
        // strictly increasing, saturating below the cap of 2.
        assert_eq!(expected_capped_interruptions(0.0), 0.0);
        assert_eq!(expected_capped_interruptions(-1.0), 0.0);
        let small = expected_capped_interruptions(0.01);
        assert!((small - 0.01).abs() < 1e-3, "small-lambda limit: {small}");
        let mut prev = 0.0;
        for i in 1..200 {
            let v = expected_capped_interruptions(i as f64 * 0.1);
            assert!(v > prev, "not increasing at {i}");
            assert!(v < 2.0);
            prev = v;
        }
        // Deep past the cap: essentially 2 charged interruptions.
        assert!((expected_capped_interruptions(50.0) - 2.0).abs() < 1e-9);
        // Exact value at lambda = 3 (the Jensen gap the fix closes:
        // the old min(E[N], 2) form would charge 2.0 here).
        let at3 = expected_capped_interruptions(3.0);
        assert!((at3 - (2.0 - (-3.0f64).exp() * 5.0)).abs() < 1e-12);
        assert!(at3 < 1.76 && at3 > 1.74, "E[min(N,2)] at 3: {at3}");
    }

    #[test]
    fn spot_overhead_bounded_in_one_to_two() {
        for l in [0.0, 0.1, 1.0, 3.0, 10.0, 1e6] {
            let o = expected_spot_overhead(l);
            assert!((1.0..=2.0 + 1e-12).contains(&o), "overhead({l}) = {o}");
        }
    }

    #[test]
    fn market_prices_each_row_at_catalog_price() {
        let m = CostModel::Market { interrupt_rate: 1.0 };
        // On-demand m5 row: plain Eq. 6.
        assert!((m.cost(&cfg(2), 3600.0) - 2.0 * 0.768).abs() < 1e-9);
        // Spot row: the (discounted) catalog price, no extra overhead —
        // planner durations already carry it.
        let spot_idx = crate::cluster::catalog::index_by_name("m5.4xlarge:spot").unwrap();
        let spot_cfg = Config {
            instance: spot_idx,
            nodes: 2,
            spark: 1,
        };
        assert!((m.cost(&spot_cfg, 3600.0) - 2.0 * 0.2688).abs() < 1e-9);
    }

    #[test]
    fn realized_cost_drops_the_spot_expectation_term() {
        let m = CostModel::Spot {
            discount: 0.3,
            interrupt_rate: 2.0,
        };
        let c = cfg(1);
        // Planner cost charges the expected overhead...
        assert!(m.cost(&c, 3600.0) > m.realized_cost(&c, 3600.0));
        // ...realized cost is exactly price x discount x occupancy.
        assert!((m.realized_cost(&c, 3600.0) - 0.768 * 0.3).abs() < 1e-9);
        // All other models: realized == planned for the same duration.
        for model in [
            CostModel::OnDemand,
            CostModel::PerSecond { min_billable_secs: 60.0 },
            CostModel::Market { interrupt_rate: 2.0 },
        ] {
            assert_eq!(model.cost(&c, 1234.5), model.realized_cost(&c, 1234.5));
        }
    }

    #[test]
    fn spot_lambda_scales_with_nodes_and_time() {
        let l1 = spot_lambda(&cfg(1), 3600.0, 1.0);
        assert!((l1 - 1.0).abs() < 1e-12);
        assert!((spot_lambda(&cfg(4), 3600.0, 1.0) - 4.0).abs() < 1e-12);
        assert!((spot_lambda(&cfg(1), 1800.0, 2.0) - 1.0).abs() < 1e-12);
    }
}
