//! Cost model — Eq. 6 of the paper, with the spot-pricing extension the
//! paper sketches ("AGORA can be easily modified to include these details
//! by defining the C_m variable more accurately").

use super::config::Config;

/// Pricing policy for a task occupying a configuration for a duration.
#[derive(Debug, Clone)]
pub enum CostModel {
    /// On-demand: cost = nodes x hourly price x hours (Eq. 6 with the
    /// paper's simplification that storage etc. is configuration-invariant).
    OnDemand,
    /// Spot: on-demand price scaled by a market discount, plus an expected
    /// interruption overhead that grows with task duration (interrupted
    /// work is re-run). `discount` in (0, 1], `interrupt_rate` is the
    /// expected number of interruptions per hour.
    Spot {
        discount: f64,
        interrupt_rate: f64,
    },
    /// Per-second billing with a minimum billable duration (e.g. EMR-style
    /// 60 s minimum) — exposes scheduling decisions to billing granularity.
    PerSecond { min_billable_secs: f64 },
}

impl CostModel {
    /// Dollar cost of holding `config` for `secs` seconds.
    pub fn cost(&self, config: &Config, secs: f64) -> f64 {
        let hourly = config.hourly_cost();
        match self {
            CostModel::OnDemand => hourly * secs / 3600.0,
            CostModel::Spot {
                discount,
                interrupt_rate,
            } => {
                // Expected re-run overhead: each interruption wastes on
                // average half of the work done since the last checkpoint
                // (modeled as half the task so far, capped at 1 re-run).
                let expected_interrupts = interrupt_rate * secs / 3600.0;
                let overhead = 1.0 + 0.5 * expected_interrupts.min(2.0);
                hourly * discount * (secs * overhead) / 3600.0
            }
            CostModel::PerSecond { min_billable_secs } => {
                hourly * secs.max(*min_billable_secs) / 3600.0
            }
        }
    }

    /// Cost of an entire assignment: sum over (config, duration) pairs —
    /// Eq. 6's sum over tasks (cost is schedule-independent, which is why
    /// the inner CP solver only optimizes makespan; see solver/anneal.rs).
    pub fn total(&self, items: impl IntoIterator<Item = (Config, f64)>) -> f64 {
        items
            .into_iter()
            .map(|(cfg, secs)| self.cost(&cfg, secs))
            .sum()
    }
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::OnDemand
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(nodes: u32) -> Config {
        Config {
            instance: 0,
            nodes,
            spark: 1,
        }
    }

    #[test]
    fn on_demand_eq6() {
        // 10 x m5.4xlarge for 30 minutes = 10 * 0.768 * 0.5
        let c = CostModel::OnDemand.cost(&cfg(10), 1800.0);
        assert!((c - 3.84).abs() < 1e-9);
    }

    #[test]
    fn spot_is_cheaper_for_short_tasks() {
        let od = CostModel::OnDemand.cost(&cfg(4), 600.0);
        let spot = CostModel::Spot {
            discount: 0.3,
            interrupt_rate: 0.05,
        }
        .cost(&cfg(4), 600.0);
        assert!(spot < od);
    }

    #[test]
    fn spot_overhead_grows_with_duration() {
        let m = CostModel::Spot {
            discount: 0.3,
            interrupt_rate: 0.5,
        };
        let short = m.cost(&cfg(1), 600.0) / 600.0;
        let long = m.cost(&cfg(1), 36_000.0) / 36_000.0;
        assert!(long > short, "unit cost should grow with duration");
    }

    #[test]
    fn per_second_minimum_applies() {
        let m = CostModel::PerSecond {
            min_billable_secs: 60.0,
        };
        assert_eq!(m.cost(&cfg(1), 10.0), m.cost(&cfg(1), 60.0));
        assert!(m.cost(&cfg(1), 120.0) > m.cost(&cfg(1), 60.0));
    }

    #[test]
    fn total_sums_tasks() {
        let m = CostModel::OnDemand;
        let total = m.total(vec![(cfg(1), 3600.0), (cfg(2), 1800.0)]);
        assert!((total - (0.768 + 0.768)).abs() < 1e-9);
    }
}
