//! Candidate resource configurations — the decision variables of the
//! co-optimization (instance type x node count x Spark parameters).

use super::catalog::{Family, InstanceType, FULL_CATALOG, M5_CATALOG};

/// Spark-level parameters. The paper found these "directly decide the
/// resource usage per task (e.g. executor memory) and have a big impact on
/// the runtime"; we model the three presets a Spark expert would reach
/// for, following the paper's experimental setup.
#[derive(Debug, Clone, PartialEq)]
pub struct SparkParams {
    /// Preset name (`fat` | `balanced` | `thin`).
    pub name: &'static str,
    /// Executors per node (scales task-level parallelism granularity).
    pub executors_per_node: u32,
    /// Cores handed to each executor.
    pub cores_per_executor: u32,
    /// Fraction of node memory usable by executors (rest is overhead).
    pub memory_fraction: f64,
    /// Relative throughput multiplier: fat executors favour shuffle-heavy
    /// jobs, thin executors favour embarrassingly parallel ones. The
    /// per-task affinity in `dag::TaskProfile` selects which preset wins.
    pub parallel_bias: f64,
}

/// Three expert presets: fat / balanced / thin executors.
pub const SPARK_PRESETS: &[SparkParams] = &[
    SparkParams {
        name: "fat",
        executors_per_node: 1,
        cores_per_executor: 16,
        memory_fraction: 0.90,
        parallel_bias: -1.0,
    },
    SparkParams {
        name: "balanced",
        executors_per_node: 4,
        cores_per_executor: 4,
        memory_fraction: 0.85,
        parallel_bias: 0.0,
    },
    SparkParams {
        name: "thin",
        executors_per_node: 8,
        cores_per_executor: 2,
        memory_fraction: 0.80,
        parallel_bias: 1.0,
    },
];

/// Node-count ladder studied in the paper's Fig. 2 (x-axes run 1..16).
pub const NODE_LADDER: &[u32] = &[1, 2, 4, 6, 8, 10, 12, 16];

/// One fully specified resource configuration for a task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Config {
    /// Index into the instance catalog.
    pub instance: usize,
    /// Number of VM nodes.
    pub nodes: u32,
    /// Index into `SPARK_PRESETS`.
    pub spark: usize,
}

impl Config {
    /// Catalog row of this configuration's instance type. `instance`
    /// indexes [`FULL_CATALOG`]; the first four rows are the m5 family,
    /// so m5-only spaces are index-compatible with the historical code.
    pub fn instance_type(&self) -> &'static InstanceType {
        &FULL_CATALOG[self.instance]
    }

    /// Instance family of this configuration.
    pub fn family(&self) -> Family {
        self.instance_type().family
    }

    /// Whether this configuration runs on preemptible spot capacity.
    pub fn is_spot(&self) -> bool {
        self.instance_type().is_spot()
    }

    /// Spark preset of this configuration.
    pub fn spark_params(&self) -> &'static SparkParams {
        &SPARK_PRESETS[self.spark]
    }

    /// Total vCPU demand while the task runs (whole nodes are billed).
    pub fn vcpus(&self) -> f64 {
        (self.nodes * self.instance_type().vcpus) as f64
    }

    /// Total memory demand in GiB.
    pub fn memory_gb(&self) -> f64 {
        (self.nodes * self.instance_type().memory_gb) as f64
    }

    /// Effective parallelism in units of m5.4xlarge-equivalent nodes —
    /// the `n` fed to the USL / Ernest basis (both sides of the stack use
    /// this same definition; see python/compile/kernels/ref.py).
    pub fn n_eff(&self) -> f64 {
        self.vcpus() / 16.0
    }

    /// $ per hour while the task holds this configuration.
    pub fn hourly_cost(&self) -> f64 {
        self.nodes as f64 * self.instance_type().hourly_cost
    }

    /// Human-readable label, e.g. `4 x m5.4xlarge (balanced)`.
    pub fn label(&self) -> String {
        format!(
            "{} x {} ({})",
            self.nodes,
            self.instance_type().name,
            self.spark_params().name
        )
    }
}

/// The enumerated candidate set handed to the optimizer and the predictor.
#[derive(Debug, Clone)]
pub struct ConfigSpace {
    /// Enumerated candidate configurations.
    pub configs: Vec<Config>,
}

impl ConfigSpace {
    /// The historical (and default) space: the m5 family x node ladder x
    /// Spark preset — the paper's Table 1 study.
    pub fn standard() -> Self {
        Self::with_ladder(NODE_LADDER)
    }

    /// The heterogeneous market space: every [`FULL_CATALOG`] row
    /// (m5/c5/r5, on-demand and spot) x node ladder x Spark preset —
    /// the co-optimizer explores family x size x purchase option
    /// jointly. Strict superset of [`ConfigSpace::standard`].
    pub fn market() -> Self {
        Self::enumerate(FULL_CATALOG.len(), NODE_LADDER)
    }

    /// Restricted space used by brute-force experiments (Fig. 3/4): a
    /// smaller node ladder keeps exhaustive search tractable, exactly as
    /// the paper's motivational study restricts itself to Table 1.
    pub fn with_ladder(ladder: &[u32]) -> Self {
        Self::enumerate(M5_CATALOG.len(), ladder)
    }

    /// Catalog-prefix x ladder x preset enumeration shared by the m5 and
    /// market spaces (instance-major order — the tie-break order every
    /// deterministic argmin in the repo relies on).
    fn enumerate(instances: usize, ladder: &[u32]) -> Self {
        let mut configs = Vec::new();
        for instance in 0..instances {
            for &nodes in ladder {
                for spark in 0..SPARK_PRESETS.len() {
                    configs.push(Config {
                        instance,
                        nodes,
                        spark,
                    });
                }
            }
        }
        ConfigSpace { configs }
    }

    /// Single-instance-type, balanced-spark slice (Ernest's view: it only
    /// picks node counts per instance type).
    pub fn ernest_slice() -> Self {
        let mut configs = Vec::new();
        for instance in 0..M5_CATALOG.len() {
            for &nodes in NODE_LADDER {
                configs.push(Config {
                    instance,
                    nodes,
                    spark: 1,
                });
            }
        }
        ConfigSpace { configs }
    }

    /// Number of candidate configurations.
    pub fn len(&self) -> usize {
        self.configs.len()
    }

    /// One past the largest catalog index present in this space — the
    /// instance-step bound of the SA neighbourhood. Derived from the
    /// space (not the catalog) so m5-only spaces keep the historical
    /// proposal distribution bit-for-bit.
    pub fn instance_count(&self) -> usize {
        self.configs
            .iter()
            .map(|c| c.instance + 1)
            .max()
            .unwrap_or(0)
    }

    /// Whether any candidate runs on spot capacity (arms the SA
    /// purchase-toggle move and the spot sections of reports).
    pub fn has_spot(&self) -> bool {
        self.configs.iter().any(|c| c.is_spot())
    }

    /// Whether the space is empty.
    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    /// Configs whose demand fits within a capacity (infeasible candidates
    /// are excluded before optimization rather than penalized inside it).
    pub fn feasible(&self, cap: &super::Capacity) -> Vec<usize> {
        (0..self.configs.len())
            .filter(|&i| {
                let c = &self.configs[i];
                cap.fits(c.vcpus(), c.memory_gb())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Capacity;

    #[test]
    fn standard_space_size() {
        let cs = ConfigSpace::standard();
        assert_eq!(cs.len(), 4 * NODE_LADDER.len() * 3);
    }

    #[test]
    fn n_eff_in_m54xlarge_units() {
        let c = Config {
            instance: 0,
            nodes: 4,
            spark: 1,
        };
        assert_eq!(c.n_eff(), 4.0);
        let c16 = Config {
            instance: 3,
            nodes: 1,
            spark: 1,
        };
        assert_eq!(c16.n_eff(), 4.0); // one m5.16xlarge = 4 m5.4xlarge-equivalents
    }

    #[test]
    fn hourly_cost_scales_with_nodes() {
        let c = Config {
            instance: 0,
            nodes: 10,
            spark: 0,
        };
        assert!((c.hourly_cost() - 7.68).abs() < 1e-9);
    }

    #[test]
    fn feasible_filters_oversized() {
        let cs = ConfigSpace::standard();
        let cap = Capacity::new(64.0, 256.0);
        let feas = cs.feasible(&cap);
        assert!(!feas.is_empty());
        for &i in &feas {
            assert!(cs.configs[i].vcpus() <= 64.0);
        }
        // 16 x m5.16xlarge must be excluded
        assert!(feas.len() < cs.len());
    }

    #[test]
    fn ernest_slice_has_no_spark_choice() {
        let cs = ConfigSpace::ernest_slice();
        assert!(cs.configs.iter().all(|c| c.spark == 1));
        assert_eq!(cs.len(), 4 * NODE_LADDER.len());
    }

    #[test]
    fn market_space_supersets_standard() {
        let std_space = ConfigSpace::standard();
        let market = ConfigSpace::market();
        assert_eq!(market.len(), FULL_CATALOG.len() * NODE_LADDER.len() * 3);
        for c in &std_space.configs {
            assert!(market.configs.contains(c), "{} missing from market", c.label());
        }
        assert!(market.has_spot());
        assert!(!std_space.has_spot());
        assert_eq!(std_space.instance_count(), M5_CATALOG.len());
        assert_eq!(market.instance_count(), FULL_CATALOG.len());
    }

    #[test]
    fn spot_and_family_helpers() {
        let spot = Config {
            instance: crate::cluster::catalog::index_by_name("c5.4xlarge:spot").unwrap(),
            nodes: 2,
            spark: 1,
        };
        assert!(spot.is_spot());
        assert_eq!(spot.family(), Family::C5);
        let od = Config {
            instance: 0,
            nodes: 2,
            spark: 1,
        };
        assert!(!od.is_spot());
        assert_eq!(od.family(), Family::M5);
    }

    #[test]
    fn labels_are_informative() {
        let c = Config {
            instance: 2,
            nodes: 6,
            spark: 2,
        };
        assert_eq!(c.label(), "6 x m5.12xlarge (thin)");
    }
}
