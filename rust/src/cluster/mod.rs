//! Heterogeneous cloud model: instance catalog (paper Table 1), candidate
//! resource configurations, cluster capacity, and the cost model (Eq. 6).

pub mod catalog;
pub mod config;
pub mod cost;

pub use catalog::{Family, InstanceType, Purchase, FULL_CATALOG, M5_CATALOG};
pub use config::{Config, ConfigSpace, SparkParams, SPARK_PRESETS};
pub use cost::{expected_spot_overhead, spot_lambda, CostModel};

/// Cluster-wide capacity limits — the `R_m` of Eq. 4. Two resources are
/// tracked (vCPUs, memory GiB), matching the paper's formulation where a
/// resource "can be any cluster capacity constraint".
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Capacity {
    /// Total cluster vCPUs available to the batch workload.
    pub vcpus: f64,
    /// Total cluster memory (GiB) available to the batch workload.
    pub memory_gb: f64,
}

impl Capacity {
    /// Capacity from explicit vCPU and memory limits.
    pub fn new(vcpus: f64, memory_gb: f64) -> Self {
        Capacity { vcpus, memory_gb }
    }

    /// Default micro-benchmark cluster: the paper's experiments provision
    /// up to 16 nodes of the largest studied ladder per task with several
    /// tasks in flight; 256 vCPUs (= 16 x m5.4xlarge) with matching memory
    /// reproduces the contention the schedulers must arbitrate.
    pub fn micro() -> Self {
        Capacity::new(256.0, 1024.0)
    }

    /// Whether a demand fits entirely within this capacity.
    pub fn fits(&self, vcpus: f64, memory_gb: f64) -> bool {
        vcpus <= self.vcpus + 1e-9 && memory_gb <= self.memory_gb + 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micro_capacity_matches_16_m54xlarge() {
        let cap = Capacity::micro();
        assert_eq!(cap.vcpus, 16.0 * 16.0);
        assert_eq!(cap.memory_gb, 16.0 * 64.0);
    }

    #[test]
    fn fits_is_inclusive() {
        let cap = Capacity::new(8.0, 32.0);
        assert!(cap.fits(8.0, 32.0));
        assert!(!cap.fits(8.1, 32.0));
        assert!(!cap.fits(8.0, 32.1));
    }
}
